"""Pallas kernel validation: interpret=True vs pure-jnp oracle (ref.py).

Sweeps shapes / dtypes / masks / GQA per the deliverable: every kernel is
checked with assert_allclose against the ref.py oracle, and the custom_vjp
against jax.grad of a plain softmax attention.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import flash_attention as fa
from repro.kernels import ops, ref


def _rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def _mk(key, B, Sq, Skv, H, Hkv, D, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return (
        _rand(k1, B, Sq, H, D, dtype=dtype),
        _rand(k2, B, Skv, Hkv, D, dtype=dtype),
        _rand(k3, B, Skv, Hkv, D, dtype=dtype),
    )


BANDS = {
    "full": (ops.full_band(), 1, 1),
    "causal": ((0, 0, 0, ref.BAND_INF), 1, 1),
    "striped_0": ((2, 1, 0, ref.BAND_INF), 4, 4),  # chunk 2 vs chunk 1, n=4
    "striped_neg": ((1, 2, 0, ref.BAND_INF), 4, 4),  # strictly-below diagonal
    "window": ((0, 0, 0, 7), 1, 1),  # causal sliding window of 8
}


@pytest.mark.parametrize("band_name", list(BANDS))
@pytest.mark.parametrize(
    "B,Sq,Skv,H,Hkv,D,bq,bk",
    [
        (1, 32, 32, 2, 2, 16, 16, 16),
        (2, 64, 32, 4, 1, 8, 32, 16),  # GQA 4:1, rectangular blocks
        (1, 48, 96, 6, 2, 32, 16, 32),  # GQA 3:1, non-square seqs
        (1, 16, 16, 1, 1, 64, 8, 8),
    ],
)
def test_fwd_kernel_vs_ref(band_name, B, Sq, Skv, H, Hkv, D, bq, bk):
    band, sq, skv = BANDS[band_name]
    q, k, v = _mk(jax.random.PRNGKey(hash(band_name) % 2**31), B, Sq, Skv, H, Hkv, D)
    o, lse = fa.flash_attention_fwd(
        q, k, v, jnp.asarray(band, jnp.int32),
        scale=D**-0.5, stride_q=sq, stride_kv=skv,
        block_q=bq, block_kv=bk, interpret=True,
    )
    o_ref, lse_ref = ref.attention_ref(
        q, k, v, scale=D**-0.5, band=band, stride_q=sq, stride_kv=skv
    )
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)
    # only compare lse on non-empty rows (both use NEG_INF sentinels)
    np.testing.assert_allclose(
        np.where(lse_ref < -1e29, 0.0, lse),
        np.where(lse_ref < -1e29, 0.0, lse_ref),
        rtol=2e-5, atol=2e-5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_kernel_dtypes(dtype):
    q, k, v = _mk(jax.random.PRNGKey(0), 1, 64, 64, 2, 2, 32, dtype=dtype)
    o, _ = fa.flash_attention_fwd(
        q, k, v, jnp.asarray(ops.full_band(), jnp.int32),
        scale=32**-0.5, block_q=32, block_kv=32, interpret=True,
    )
    o_ref, _ = ref.attention_ref(q, k, v, scale=32**-0.5)
    assert o.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        o.astype(np.float32), o_ref.astype(np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("band_name", ["full", "causal", "striped_0", "window"])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,Hkv,D,bq,bk",
    [
        (1, 32, 32, 2, 2, 16, 16, 16),
        (1, 64, 32, 4, 2, 8, 32, 16),  # GQA 2:1
        (2, 32, 64, 3, 1, 16, 16, 32),  # GQA 3:1
    ],
)
def test_bwd_kernels_vs_ref(band_name, B, Sq, Skv, H, Hkv, D, bq, bk):
    band, sq, skv = BANDS[band_name]
    key = jax.random.PRNGKey(42)
    q, k, v = _mk(key, B, Sq, Skv, H, Hkv, D)
    do = _rand(jax.random.PRNGKey(7), B, Sq, H, D)
    o, lse = ref.attention_ref(q, k, v, scale=D**-0.5, band=band, stride_q=sq, stride_kv=skv)
    dq, dk, dv = fa.flash_attention_bwd(
        q, k, v, o, lse, do, jnp.asarray(band, jnp.int32),
        scale=D**-0.5, stride_q=sq, stride_kv=skv,
        block_q=bq, block_kv=bk, interpret=True,
    )
    dq_r, dk_r, dv_r = ref.attention_bwd_ref(
        q, k, v, o, lse, do, scale=D**-0.5, band=band, stride_q=sq, stride_kv=skv
    )
    np.testing.assert_allclose(dq, dq_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dk, dk_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dv, dv_r, rtol=2e-4, atol=2e-4)


def _dense_attention(q, k, v, mask):
    H, Hkv = q.shape[2], k.shape[2]
    kr, vr = ref.repeat_kv(k, H), ref.repeat_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * (q.shape[-1] ** -0.5)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


@pytest.mark.parametrize("band_name", ["full", "causal", "window"])
def test_custom_vjp_matches_autodiff(band_name):
    """ops.flash_attention's custom_vjp vs jax.grad through dense softmax."""
    band, sq, skv = BANDS[band_name]
    q, k, v = _mk(jax.random.PRNGKey(3), 1, 32, 32, 4, 2, 16)
    mask = ref.band_mask(32, 32, band, stride_q=sq, stride_kv=skv)

    def loss_flash(q, k, v):
        o = ops.flash_attention(q, k, v, band=band)
        return jnp.sum(o * jnp.cos(o))

    def loss_dense(q, k, v):
        o = _dense_attention(q, k, v, mask)
        return jnp.sum(o * jnp.cos(o))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_combine_partials_equals_joint():
    """lse-weighted combine of two disjoint-KV partials == attention over the
    union — the algebra behind the paper's O reduce-scatter."""
    q, k, v = _mk(jax.random.PRNGKey(5), 2, 16, 64, 2, 2, 8)
    k1, k2 = k[:, :32], k[:, 32:]
    v1, v2 = v[:, :32], v[:, 32:]
    o1, l1 = ref.attention_ref(q, k1, v1, scale=8**-0.5)
    o2, l2 = ref.attention_ref(q, k2, v2, scale=8**-0.5)
    oc, lc = ref.combine_partials(o1, l1, o2, l2)
    o_all, lse_all = ref.attention_ref(q, k, v, scale=8**-0.5)
    np.testing.assert_allclose(oc, o_all, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(lc, lse_all, rtol=1e-5, atol=1e-5)


def test_combine_partials_handles_empty():
    """Fully-masked partials (NEG_INF lse) must be absorbed without NaNs."""
    q, k, v = _mk(jax.random.PRNGKey(5), 1, 8, 8, 1, 1, 4)
    o1, l1 = ref.attention_ref(q, k, v, scale=0.5)
    o2 = jnp.zeros_like(o1)
    l2 = jnp.full_like(l1, ref.NEG_INF)
    oc, lc = ref.combine_partials(o1, l1, o2, l2)
    assert not np.isnan(np.asarray(oc)).any()
    np.testing.assert_allclose(oc, o1, rtol=1e-6)
    np.testing.assert_allclose(lc, l1, rtol=1e-6)
    # both empty stays empty
    oc, lc = ref.combine_partials(o2, l2, o2, l2)
    assert not np.isnan(np.asarray(oc)).any()
    assert (np.asarray(lc) <= -1e29).all()


@given(
    st.sampled_from([8, 16, 32]),
    st.sampled_from([8, 16]),
    st.sampled_from([(1, 1), (2, 1), (4, 2)]),
    st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_property_fwd_random_shapes(seq, d, heads, causal):
    """Hypothesis sweep: kernel == oracle on randomized configurations."""
    H, Hkv = heads
    q, k, v = _mk(jax.random.PRNGKey(seq * d + H), 1, seq, seq, H, Hkv, d)
    band = (0, 0, 0, ref.BAND_INF) if causal else ops.full_band()
    o, _ = fa.flash_attention_fwd(
        q, k, v, jnp.asarray(band, jnp.int32),
        scale=d**-0.5, block_q=8, block_kv=8, interpret=True,
    )
    o_ref, _ = ref.attention_ref(q, k, v, scale=d**-0.5, band=band)
    np.testing.assert_allclose(o, o_ref, rtol=3e-5, atol=3e-5)


def test_band_traced_offsets():
    """Band offsets must work as traced values (axis_index use case)."""
    q, k, v = _mk(jax.random.PRNGKey(9), 1, 16, 16, 2, 2, 8)

    @jax.jit
    def go(qc, kc):
        band = jnp.stack([qc, kc, jnp.int32(0), jnp.int32(ref.BAND_INF)])
        return fa.flash_attention_fwd(
            q, k, v, band, scale=8**-0.5, stride_q=4, stride_kv=4,
            block_q=8, block_kv=8, interpret=True,
        )[0]

    for qc, kc in [(0, 3), (3, 0), (2, 2)]:
        got = go(jnp.int32(qc), jnp.int32(kc))
        want, _ = ref.attention_ref(
            q, k, v, scale=8**-0.5, band=(qc, kc, 0, ref.BAND_INF), stride_q=4, stride_kv=4
        )
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
