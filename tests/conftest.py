"""Test bootstrap: make ``repro`` importable and fall back to the vendored
hypothesis shim when the real package is absent (hermetic containers)."""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    try:
        import repro  # noqa: F401
    except ImportError:
        sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing import hypothesis_shim

    hypothesis_shim.install()
