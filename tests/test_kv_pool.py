"""Paged KV-cache pool: allocator lifecycle, refcounts, prefix sharing,
copy-on-write, admission accounting, the block-table gather oracle, and the
quantized pool's scale bookkeeping (quantize round-trip bound, CoW scale
copies, rollback draining scale entries with pages)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import kv_quant
from repro.serve.kv_pool import PageAllocator, PagedLayout, gather_block_table
from repro.serve.scheduler import Scheduler


def _alloc(num_pages=8, page_size=4, max_pages=4, n=1):
    return PageAllocator(PagedLayout(num_pages, page_size, max_pages, n))


# --------------------------------------------------------------------------
# lifecycle: alloc / append / free / refcount
# --------------------------------------------------------------------------


def test_alloc_append_free_lifecycle():
    a = _alloc()  # chunk = 4 tokens, 8 pages
    prompt = np.arange(6, dtype=np.int32)
    got = a.alloc_slot(0, prompt, max_new_tokens=3)
    assert got.shared_len == 0
    assert a.slot_pages(0) == 2  # ceil(6/4)
    assert a.pages_in_use == 2 and (a.ref[a.block_table[0, :2]] == 1).all()
    # appends inside the tail page allocate nothing
    assert a.ensure_append(0, 6) is None and a.ensure_append(0, 7) is None
    assert a.slot_pages(0) == 2
    # crossing the chunk boundary takes a fresh page
    assert a.ensure_append(0, 8) is None
    assert a.slot_pages(0) == 3 and a.pages_in_use == 3
    a.free_slot(0)
    assert a.pages_in_use == 0 and a.slot_pages(0) == 0
    assert (a.block_table[0] == PageAllocator.FREE).all()
    with pytest.raises(ValueError):  # double-alloc guard needs free_slot first
        a.alloc_slot(1, prompt, 3)
        a.alloc_slot(1, prompt, 3)


def test_non_contiguous_append_rejected():
    a = _alloc()
    a.alloc_slot(0, np.arange(4, dtype=np.int32), 8)
    with pytest.raises(ValueError):
        a.ensure_append(0, 12)  # would skip logical page 1


# --------------------------------------------------------------------------
# prefix sharing + copy-on-write
# --------------------------------------------------------------------------


def test_prefix_sharing_refcounts_and_stale_invalidation():
    a = _alloc(num_pages=16)
    prompt = np.arange(10, dtype=np.int32)  # 2 full chunks + partial third
    a.alloc_slot(0, prompt, 2)
    assert a.fresh_allocs == 3
    got = a.alloc_slot(1, prompt, 2)
    assert got.shared_pages == 2 and got.shared_len == 8
    assert a.shared_hits == 2 and a.fresh_allocs == 4  # only the tail is fresh
    shared = a.block_table[0, :2].copy()
    assert (a.block_table[1, :2] == shared).all()
    assert (a.ref[shared] == 2).all()
    # the owner retiring keeps shared pages alive for the reader
    a.free_slot(0)
    assert (a.ref[shared] == 1).all() and a.pages_in_use == 3
    # a third request can still share against the surviving reader
    got = a.alloc_slot(2, prompt, 2)
    assert got.shared_pages == 2
    a.free_slot(1)
    a.free_slot(2)
    assert a.pages_in_use == 0
    # every reference is gone -> the registry entry is stale and must NOT
    # resurrect freed pages (generation stamp mismatch)
    got = a.alloc_slot(3, prompt, 2)
    assert got.shared_pages == 0 and got.shared_len == 0


def test_copy_on_write_on_shared_page_append():
    a = _alloc(num_pages=8)
    prompt = np.arange(4, dtype=np.int32)  # exactly one chunk, registered
    a.alloc_slot(0, prompt, 4)
    a.alloc_slot(1, prompt, 4)
    pid = int(a.block_table[1, 0])
    assert a.ref[pid] == 2  # shared
    # slot 1 must not write into the shared page: ensure_append hands back a
    # (src, dst) physical copy and repoints slot 1's table at the private dst
    cp = a.ensure_append(1, 2)
    assert cp is not None and cp[0] == pid
    src, dst = cp
    assert int(a.block_table[1, 0]) == dst != pid
    assert a.ref[pid] == 1 and a.ref[dst] == 1 and a.cow_copies == 1
    assert int(a.block_table[0, 0]) == pid  # the owner is untouched
    # refcount 1 -> appends write in place, no further copies
    assert a.ensure_append(1, 3) is None and a.cow_copies == 1


# --------------------------------------------------------------------------
# admission accounting: pages, not rows
# --------------------------------------------------------------------------


def test_pool_exhaustion_rejected_at_admission():
    a = _alloc(num_pages=4)  # 16 tokens of pool, chunk 4
    assert a.can_admit(8, 4)  # 3 pages
    a.alloc_slot(0, np.arange(8, dtype=np.int32), 4)
    # 3 of 4 pages reserved for slot 0's lifetime: a second 8+4 cannot fit
    assert not a.can_admit(8, 4)
    assert a.can_admit(2, 2)  # 1 page does
    with pytest.raises(RuntimeError):
        a.alloc_slot(1, np.arange(8, dtype=np.int32), 4)  # forced past the check
    a.free_slot(0)
    assert a.can_admit(8, 4)


def test_scheduler_defers_admission_until_pages_free():
    a = _alloc(num_pages=4)
    s = Scheduler(4, (16,), 16, allocator=a)
    r0 = s.submit(np.arange(8, dtype=np.int32), 4)  # 3 pages
    r1 = s.submit(np.arange(8, dtype=np.int32), 4)  # won't fit alongside
    assigned = s.admit(0)
    assert [r.rid for _, r in assigned] == [r0.rid]
    a.alloc_slot(assigned[0][0], r0.prompt, 4)
    assert s.admit(1) == []  # held in queue, FIFO, until pages free
    s.retire(assigned[0][0], 1)
    a.free_slot(assigned[0][0])
    assert [r.rid for _, r in s.admit(2)] == [r1.rid]


def test_pool_exhaustion_mid_decode_raises():
    a = _alloc(num_pages=3)
    a.alloc_slot(0, np.arange(8, dtype=np.int32), 0)  # 2 pages
    a.alloc_slot(1, np.arange(2, dtype=np.int32), 0)  # 1 page
    with pytest.raises(RuntimeError):
        a.ensure_append(1, 4)  # appending past its reservation; pool empty


# --------------------------------------------------------------------------
# hypothesis: block-table gather == dense cache for random depths
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    depths=st.lists(st.integers(min_value=1, max_value=16), min_size=1, max_size=4),
    page_size=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_table_gather_matches_dense(depths, page_size, seed):
    """Writing each slot's positions through the allocator's block table and
    gathering them back must reproduce a dense [slots, cap] cache exactly,
    for arbitrary per-slot depths (mixed-depth continuous batching)."""
    rng = np.random.default_rng(seed)
    cap = 16
    max_pages = -(-cap // page_size)
    lay = PagedLayout(
        num_pages=len(depths) * max_pages, page_size=page_size,
        max_pages=max_pages, n=1,
    )
    a = PageAllocator(lay)
    pool = np.zeros((lay.num_pages, page_size, 2), np.float64)
    dense = np.zeros((len(depths), max_pages * page_size, 2), np.float64)
    for slot, d in enumerate(depths):
        # unique prompts so prefix sharing never collapses the comparison
        prompt = rng.integers(0, 2**30, (d,), dtype=np.int32)
        a.alloc_slot(slot, prompt, 0)
        for p in range(d):
            val = rng.normal(size=(2,))
            lp, off = p // page_size, p % page_size
            pool[a.block_table[slot, lp], off] = val
            dense[slot, p] = val
    got = gather_block_table(pool, a.device_table(len(depths)))
    for slot, d in enumerate(depths):
        np.testing.assert_array_equal(got[slot, :d], dense[slot, :d])


# --------------------------------------------------------------------------
# quantized pool: round-trip bound, scale bookkeeping, CoW, rollback
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    shape=st.sampled_from([(3, 2, 8), (1, 4), (5, 1, 1, 16)]),
    scale_mag=st.floats(min_value=-6.0, max_value=6.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_quantize_dequantize_roundtrip_bound(shape, scale_mag, seed):
    """Symmetric per-last-axis quantization: |x - dequant(quantize(x))| must
    stay within REL_ERROR_BOUND * amax elementwise, across magnitudes from
    1e-6 to 1e6 — and exact zeros must round-trip to exact zeros."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32) * (10.0 ** scale_mag)
    x[..., 0] = 0.0  # exercise the zero lane alongside live values
    q, s = kv_quant.quantize(jnp.asarray(x), "int8")
    deq = np.asarray(kv_quant.dequantize(q, s))
    amax = np.abs(x).max(axis=-1, keepdims=True)
    bound = kv_quant.REL_ERROR_BOUND["int8"] * amax
    assert (np.abs(x - deq) <= bound + 1e-30).all()
    zero_rows = amax[..., 0] == 0
    assert (np.asarray(s)[zero_rows] == 0).all()
    assert (deq[np.broadcast_to(amax == 0, x.shape)] == 0).all()


def test_quantized_allocator_tracks_scale_entries():
    """scale_entries_in_use mirrors pages_in_use through the whole lifecycle
    (alloc, shared-prefix admission, CoW, free) — counted independently of
    the free list so drain-together is a real invariant, not a tautology."""
    a = PageAllocator(PagedLayout(8, 4, 4, 1), quantized=True)
    prompt = np.arange(6, dtype=np.int32)
    a.alloc_slot(0, prompt, 2)
    assert a.scale_entries_in_use == a.pages_in_use == 2
    got = a.alloc_slot(1, prompt, 2)  # shares page 0: no new scale entry
    assert got.shared_pages == 1
    assert a.scale_entries_in_use == a.pages_in_use == 3
    cp = a.ensure_append(1, 4)  # CoW off the shared page 1 (partial tail)
    if cp is not None:  # the private copy claims its own scale entry
        assert a.scale_entries_in_use == a.pages_in_use
    a.free_slot(0)
    a.free_slot(1)
    assert a.scale_entries_in_use == 0 and a.pages_in_use == 0
    stats = a.stats()
    assert stats["quantized_pages"] == 0 and stats["scale_entries_in_use"] == 0


def test_cow_copy_includes_scale_tables():
    """The engine's CoW page copy must move the scale side tables in lockstep
    with the pages: a copied int8 page read through stale scales dequantizes
    garbage."""
    from repro.serve.engine import ServeEngine

    L, num_pages, cols, hkv, d = 2, 4, 4, 2, 8
    rng = np.random.default_rng(7)
    cache = {
        "k": jnp.asarray(rng.integers(-127, 128, (L, num_pages, cols, hkv, d)), jnp.int8),
        "v": jnp.asarray(rng.integers(-127, 128, (L, num_pages, cols, hkv, d)), jnp.int8),
        "k_scale": jnp.asarray(rng.random((L, num_pages, cols, hkv)), jnp.float32),
        "v_scale": jnp.asarray(rng.random((L, num_pages, cols, hkv)), jnp.float32),
    }
    src = jnp.asarray([1, 0], jnp.int32)
    dst = jnp.asarray([3, num_pages], jnp.int32)  # second entry: pad, dropped
    out = ServeEngine._copy_pages_traced(None, cache, src, dst)
    for key in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            np.asarray(out[key][:, 3]), np.asarray(cache[key][:, 1])
        )
        # untouched pages (incl. the dropped pad write) stay bitwise put
        np.testing.assert_array_equal(
            np.asarray(out[key][:, :3]), np.asarray(cache[key][:, :3])
        )


def test_rollback_frees_scale_entries_with_pages():
    """Speculative rollback on a quantized allocator drops the rejected tail
    pages AND their scale entries; retiring everything drains both counters
    to zero together."""
    a = PageAllocator(PagedLayout(8, 4, 4, 1), quantized=True)
    a.alloc_slot(0, np.arange(4, dtype=np.int32), 12)
    assert a.scale_entries_in_use == a.pages_in_use == 1
    # a verify span crossing two page boundaries claims two append pages
    copies = a.ensure_span(0, 4, 8)
    assert copies == []
    assert a.scale_entries_in_use == a.pages_in_use == 3
    a.rollback(0, keep_len=5)  # reject back to one token past the prompt
    assert a.scale_entries_in_use == a.pages_in_use == 2
    a.free_slot(0)
    assert a.scale_entries_in_use == 0 and a.pages_in_use == 0
