"""Greedy scheduler (Algorithms 2/3) invariants + quality vs naive baselines."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import schedule as S
from repro.core.am import CommModel
from repro.core.simulator import HardwareModel, make_cost_model, simulate
from repro.core.tiling import factorizations


def _ab_strategy(max_n=36):
    return (
        st.integers(1, max_n)
        .flatmap(lambda n: st.tuples(st.just(n), st.sampled_from([a for a, _ in factorizations(n)])))
        .map(lambda na: (na[1], na[0] // na[1]))
    )


_profiles = st.builds(
    S.Profile,
    c_q=st.floats(0.1, 8.0),
    c_kv=st.floats(0.1, 8.0),
    c_o=st.floats(0.1, 8.0),
    c_odoq=st.floats(0.1, 8.0),
    c_dq=st.floats(0.1, 8.0),
    c_dkv=st.floats(0.1, 8.0),
)


@given(_ab_strategy(), _profiles, st.booleans())
@settings(max_examples=200, deadline=None)
def test_greedy_forward_valid(ab, profile, concurrent):
    a, b = ab
    sched = S.greedy_forward_schedule(a, b, profile, allow_concurrent_rings=concurrent)
    S.validate_schedule(sched, strict_paper=not concurrent)
    assert len(sched.blocks()) == a * b


@given(_ab_strategy(), _profiles, st.booleans())
@settings(max_examples=200, deadline=None)
def test_greedy_backward_valid(ab, profile, concurrent):
    a, b = ab
    sched = S.greedy_backward_schedule(a, b, profile, allow_concurrent_rings=concurrent)
    S.validate_schedule(sched, strict_paper=not concurrent)
    assert len(sched.blocks()) == a * b


@given(_ab_strategy())
@settings(max_examples=100, deadline=None)
def test_naive_forward_valid(ab):
    a, b = ab
    S.validate_schedule(S.naive_forward_schedule(a, b), strict_paper=True)


def test_ring_schedule_is_mesh_a1():
    """Ring-Attention's one-block-per-step schedule is the a=1 special case."""
    ring = S.ring_forward_schedule(8)
    mesh = S.greedy_forward_schedule(1, 8, S.Profile(c_kv=1.0))
    S.validate_schedule(ring, strict_paper=True)
    assert ring.comm_ops() == [S.RECV_KV] * 7
    assert mesh.comm_ops() == [S.RECV_KV] * 7
    assert ring.blocks() == mesh.blocks()


def test_comm_op_counts_match_paper():
    """(a-1) Q + (b-1) KV recvs + (a-1) O sends forward; +dQ/dKV backward."""
    for a, b in [(3, 3), (2, 8), (4, 4), (1, 9), (9, 1)]:
        f = S.greedy_forward_schedule(a, b)
        ops = f.comm_ops()
        assert ops.count(S.RECV_Q) == a - 1
        assert ops.count(S.RECV_KV) == b - 1
        assert ops.count(S.SEND_O) == a - 1
        g = S.greedy_backward_schedule(a, b)
        ops = g.comm_ops()
        assert ops.count(S.RECV_ODOQ) == a - 1
        assert ops.count(S.RECV_KV) == b - 1
        assert ops.count(S.SEND_DQ) == a - 1
        assert ops.count(S.SEND_DKV) == b - 1


def test_local_row_deprioritized():
    """Principle 3: row 0 (the local output) is computed last when possible."""
    sched = S.greedy_forward_schedule(3, 3, S.Profile(c_q=1, c_kv=1, c_o=1))
    blocks = sched.blocks()
    # all row>=1 blocks come before the last row-0 block
    last_row0 = max(i for i, (u, _) in enumerate(blocks) if u == 0)
    first_pending = [i for i, (u, _) in enumerate(blocks) if u != 0]
    assert max(first_pending) < last_row0 or blocks[last_row0][0] == 0


def test_send_o_follows_completed_rows():
    sched = S.greedy_forward_schedule(4, 4)
    done = set()
    sent = 0
    for step in sched.steps:
        for c in step.comms:
            if c == S.SEND_O:
                sent += 1
                assert all((sent, v) in done for v in range(4))
        done.update(step.compute)
    assert sent == 3


def _sim_total(a, b, comm, hw=HardwareModel(), causal=False):
    cost_f = make_cost_model(comm, hw, causal=causal, backward=False)
    cost_b = make_cost_model(comm, hw, causal=causal, backward=True)
    f = S.greedy_forward_schedule(a, b, cost_f.profile())
    g = S.greedy_backward_schedule(a, b, cost_b.profile())
    return simulate(f, cost_f, comm).total + simulate(g, cost_b, comm).total


# A communication-bound cluster like the paper's (§2.2: Ring-Attention waits
# on comm 91.5% of the time at 128 GPUs / 1M tokens): fast chips, slow links.
PAPER_LIKE_HW = HardwareModel(peak_flops=989e12, link_bw=25e9, attn_efficiency=0.5)


def test_greedy_beats_or_ties_naive():
    """Fig. 5: greedy scheduling should never lose to the naive row-first
    schedule under the same cost model."""
    comm = CommModel(seq=1 << 20, hidden=4096, n=16)
    cost = make_cost_model(comm)
    for a in (2, 4, 8):
        b = 16 // a
        greedy = simulate(S.greedy_forward_schedule(a, b, cost.profile()), cost, comm)
        naive = simulate(S.naive_forward_schedule(a, b), cost, comm)
        assert greedy.total <= naive.total * 1.0001


def test_mesh_beats_ring_at_scale():
    """Communication-bound regime (long seq, many devices): the 2-D tile must
    beat Ring-Attention clearly — the paper's headline result (2.9x avg at
    256 GPUs)."""
    n = 256
    comm = CommModel(seq=1 << 20, hidden=4096, n=n)
    ring_total = _sim_total(1, n, comm, PAPER_LIKE_HW)
    mesh_total = _sim_total(16, 16, comm, PAPER_LIKE_HW)
    assert mesh_total < ring_total / 2.0
    # On the TPU default model mesh must still never lose.
    assert _sim_total(16, 16, comm) <= _sim_total(1, n, comm) * 1.0001


def test_concurrent_rings_no_worse():
    comm = CommModel(seq=1 << 18, hidden=4096, n=64)
    cost = make_cost_model(comm)
    strict = simulate(S.greedy_forward_schedule(8, 8, cost.profile()), cost, comm)
    relaxed = simulate(
        S.greedy_forward_schedule(8, 8, cost.profile(), allow_concurrent_rings=True), cost, comm
    )
    assert relaxed.total <= strict.total * 1.0001


def test_validator_catches_bad_schedules():
    # compute before data arrives
    bad = S.Schedule(2, 2, "fwd", (S.Step((S.RECV_Q,), ((1, 0),)),))
    with pytest.raises(ValueError):
        S.validate_schedule(bad)
    # double compute
    bad = S.Schedule(
        1, 1, "fwd", (S.Step((), ((0, 0),)), S.Step((), ((0, 0),)))
    )
    with pytest.raises(ValueError):
        S.validate_schedule(bad)
    # missing comm ops
    bad = S.Schedule(2, 2, "fwd", (S.Step((), ((0, 0),)),))
    with pytest.raises(ValueError):
        S.validate_schedule(bad)
    # restriction (2) in strict mode
    two = S.greedy_forward_schedule(2, 2, allow_concurrent_rings=True)
    if any(len(s.comms) > 1 for s in two.steps):
        with pytest.raises(ValueError):
            S.validate_schedule(two, strict_paper=True)
