"""Tile layout, assignment matrix, Table-1 mappings, striping (paper §3.2/§3.7)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.tiling import (
    TileLayout,
    best_square_a,
    factorizations,
    stripe_permutation,
    striped_causal_offset,
    unstripe_permutation,
)


def _layouts(max_n=36):
    for n in range(1, max_n + 1):
        for a, _ in factorizations(n):
            yield TileLayout(n, a)


def test_factorizations():
    assert factorizations(9) == [(1, 9), (3, 3), (9, 1)]
    assert factorizations(16) == [(1, 16), (2, 8), (4, 4), (8, 2), (16, 1)]
    with pytest.raises(ValueError):
        factorizations(0)


def test_best_square_a():
    assert best_square_a(9) == 3
    assert best_square_a(16) == 4
    assert best_square_a(8) in (2, 4)  # both log-equidistant from sqrt(8)
    assert best_square_a(1) == 1


def test_paper_figure1_example():
    """The 9-GPU (3x3) example from Figure 1(c): AM[i][i] == i everywhere and
    per-device comm is 6 units (2 Q + 2 KVx2... see intro: total 72 units)."""
    lay = TileLayout(9, 3)
    am = lay.assignment_matrix()
    assert (np.diag(am) == np.arange(9)).all()
    chunks = lay.comm_chunks_per_device()
    # 2 Q-recvs (1 unit) + 2 KV-recvs (2 units) + 2 O-sends (1 unit) = 8 units
    per_dev_units = chunks["q"] + 2 * chunks["kv"] + chunks["o"]
    assert per_dev_units == 8
    assert per_dev_units * 9 == 72  # paper: "further reduced to 72"
    # Ring-Attention on 9 GPUs: 16 units/device, 144 total (paper intro)
    ring = TileLayout(9, 1).comm_chunks_per_device()
    assert ring["q"] + 2 * ring["kv"] + ring["o"] == 16


@given(st.integers(1, 64).flatmap(lambda n: st.tuples(st.just(n), st.sampled_from([a for a, _ in factorizations(n)]))))
@settings(max_examples=200, deadline=None)
def test_am_partition_and_locality(na):
    """The tiles partition the AM; each device gets exactly a*b cells; the
    local Q-KV property holds (AM[i][i] == i)."""
    n, a = na
    lay = TileLayout(n, a)
    am = lay.assignment_matrix()
    counts = np.bincount(am.ravel(), minlength=n)
    assert (counts == n).all()  # a*b = n cells per device
    assert (np.diag(am) == np.arange(n)).all()


@given(st.integers(1, 48).flatmap(lambda n: st.tuples(st.just(n), st.sampled_from([a for a, _ in factorizations(n)]))))
@settings(max_examples=200, deadline=None)
def test_table1_mappings_consistent(na):
    """Table-1 slot->chunk maps must enumerate exactly the device's tile:
    its Q-group rows and KV-residue columns, starting at the local chunk."""
    n, a = na
    lay = TileLayout(n, a)
    am = lay.assignment_matrix()
    for i in range(n):
        qs = [lay.q_chunk(i, u) for u in range(a)]
        kvs = [lay.kv_chunk(i, u) for u in range(lay.b)]
        assert qs[0] == i and kvs[0] == i  # slot 0 is local
        assert sorted(qs) == lay.q_group_members(i // a)
        assert sorted(kvs) == sorted(lay.kv_group_members(i % a))
        for qv in qs:
            for kvv in kvs:
                assert am[qv][kvv] == i
        # inverse maps
        for u in range(a):
            assert lay.q_slot_of(i, lay.q_chunk(i, u)) == u
        for u in range(lay.b):
            assert lay.kv_slot_of(i, lay.kv_chunk(i, u)) == u


@given(st.integers(2, 48).flatmap(lambda n: st.tuples(st.just(n), st.sampled_from([a for a, _ in factorizations(n)]))))
@settings(max_examples=100, deadline=None)
def test_rings_are_group_cycles(na):
    n, a = na
    lay = TileLayout(n, a)
    for i in range(n):
        # following succ_q a times returns to start and stays in the Q group
        cur, seen = i, []
        for _ in range(a):
            seen.append(cur)
            cur = lay.succ_q(cur)
            assert lay.q_group(cur) == lay.q_group(i)
        assert cur == i and sorted(seen) == lay.q_group_members(i // a)
        cur, seen = i, []
        for _ in range(lay.b):
            seen.append(cur)
            cur = lay.succ_kv(cur)
            assert lay.kv_group(cur) == lay.kv_group(i)
        assert cur == i and sorted(seen) == sorted(lay.kv_group_members(i % a))
        assert lay.succ_q(lay.pred_q(i)) == i
        assert lay.succ_kv(lay.pred_kv(i)) == i


def test_ring_perm_shapes():
    lay = TileLayout(12, 3)
    qp = lay.q_ring_perm()
    kvp = lay.kv_ring_perm()
    assert len(qp) == 12 and len(kvp) == 12
    assert sorted(d for _, d in qp) == list(range(12))  # a permutation
    assert sorted(d for _, d in kvp) == list(range(12))
    assert TileLayout(12, 1).q_ring_perm() == []  # ring-attention: no Q comm
    assert TileLayout(12, 12).kv_ring_perm() == []


@given(st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_stripe_roundtrip(n, m):
    seq = n * m
    perm = stripe_permutation(seq, n)
    inv = unstripe_permutation(seq, n)
    x = np.arange(seq)
    striped = x[perm]
    assert (striped[inv] == x).all()
    # chunk c holds tokens {c + n*x}
    for c in range(n):
        assert (striped[c * m : (c + 1) * m] == c + n * np.arange(m)).all()


def test_striped_causal_offset_matches_token_mask():
    """Block-level offset must reproduce the token-level causal mask."""
    n, m = 4, 4
    perm = stripe_permutation(n * m, n)
    for qc in range(n):
        for kc in range(n):
            off = striped_causal_offset(qc, kc)
            q_tokens = perm[qc * m : (qc + 1) * m]
            kv_tokens = perm[kc * m : (kc + 1) * m]
            want = q_tokens[:, None] >= kv_tokens[None, :]
            got = (np.arange(m)[:, None] - np.arange(m)[None, :] + off) >= 0
            assert (want == got).all(), (qc, kc)
