"""Multi-device integration tests.

The dry-run rules require the main pytest process to see exactly 1 CPU
device, so these tests launch ``repro.testing.dist_check`` in subprocesses
with ``--xla_force_host_platform_device_count=8`` and assert on the JSON
report.  Checks are batched per subprocess to amortize JAX startup.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_checks(*names, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.dist_check", *names],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    lines = [l for l in proc.stdout.strip().splitlines() if l.startswith("{")]
    assert lines, f"no JSON report\nstdout: {proc.stdout}\nstderr: {proc.stderr[-3000:]}"
    report = json.loads(lines[-1])
    for name in names:
        assert report[name]["ok"], f"{name} failed:\n{report[name].get('tb', report[name])}"
    return report


def test_mesh_attention_forward_and_baselines():
    """Fwd for every (a,b) x mask x GQA; ring == mesh(a=1); ulysses; decode
    (incl. contiguous/window/empty-shard/vector-pos edge cases)."""
    report = _run_checks("mesh_fwd", "ring_eq", "ulysses", "decode", "decode_edge")
    assert max(report["mesh_fwd"]["detail"].values()) < 2e-5


def test_mesh_attention_backward():
    """Alg.-3 custom_vjp vs dense autodiff, all tile shapes x wire modes."""
    report = _run_checks("mesh_bwd")
    assert max(report["mesh_bwd"]["detail"].values()) < 5e-5


def test_mesh_attention_with_pallas_kernels():
    """Pallas kernels (interpret) inside the distributed ring program."""
    _run_checks("mesh_pallas")


def test_distributed_train_and_serve():
    """End-to-end on fake meshes: FSDP+CP training with int8 cross-pod
    gradient compression, injected crash, elastic resume on a different mesh
    shape; distributed serving == single-device generation; a continuous-
    batching mixed-length trace == sequential single-request generation."""
    _run_checks("train_dist", "serve_dist", "serve_stream")


def test_beyond_paper_variants():
    """MLA latent-wire == standard path; segmented-EP MoE == single device;
    Algorithm-1 collective mode == ring decomposition == oracle."""
    _run_checks("mla_wire", "moe_ep", "collective_mode")


def test_pipeline_parallelism():
    """GPipe over a 'pipe' axis == sequential stack, fwd and grads."""
    _run_checks("pipeline")


def test_dispatch_seam():
    """repro.core.dispatch routes every backend (incl. the autotuned mesh
    plan with its on-disk cache) to oracle-identical results."""
    _run_checks("dispatch")


def test_mask_pruning_and_packed_prefill():
    """First-class masks: a document-masked (2,4)-mesh workload prunes
    schedule blocks + comm with BITWISE-identical outputs and grads; packed
    multi-prompt serve prefill == sequential per-request generation."""
    _run_checks("mask_prune", "packed_prefill")


def test_overlap_modes_bitwise_exact():
    """comm_overlap = serial | overlap | bidir are bitwise-equal transports
    on the (2,4) mesh — fwd AND grads, masked/pruned schedules and the
    Algorithm-1 collective mode included."""
    _run_checks("overlap_exact")


def test_paged_serve():
    """Paged KV cache on a (2,4) mesh: block-table decode/update must be
    token-for-token identical to the dense engine on the streaming trace,
    and a shared-prefix pair must allocate strictly fewer pages."""
    _run_checks("paged_serve")


def test_continuous_prefill():
    """Chunked, budgeted prompt ingestion on a (2,4) mesh: the continuous-
    prefill engine == one-shot engine == single-device generation,
    token-for-token, dense and paged (shared prefixes included), with one
    chunk trace and the per-tick budget respected."""
    _run_checks("continuous_prefill")


def test_spec_decode():
    """Speculative multi-token decode on a (2,4) mesh: drafts verified
    through the banded [slots, spec_k] chunk launch commit tokens identical
    to vanilla greedy decode and to single-device generation, dense and
    paged (rollback draining the pool to zero), in one verify trace."""
    _run_checks("spec_decode")


def test_quant_kv():
    """Quantized int8 paged KV pool on a (2,4) mesh: the quantized engine
    replays the mixed streaming trace (prefix sharing + continuous prefill
    + spec_k=4) with per-token logit error inside the documented bound vs
    the fp paged engine (greedy flips only on explained near-ties) and
    pages + scale entries draining to zero."""
    report = _run_checks("quant_kv")
    detail = report["quant_kv"]["detail"]
    assert detail["max_logit_err"] <= detail["logit_bound"]
    assert detail["bytes_per_token_ratio"] <= 0.55


def test_chaos_serve():
    """Fault-tolerant serving on a (2,4) mesh: the oversubscribed engine
    under injected pool pressure preempts-and-recomputes to token streams
    identical to the conservative engine (prefix sharers intact), a chaos
    NaN tick retires exactly one request while the other slots' outputs are
    bitwise-unchanged, and the full seeded fault trace replays
    deterministically with pages and scale entries draining to zero."""
    report = _run_checks("chaos_serve")
    detail = report["chaos_serve"]["detail"]
    assert detail["preemptions"] > 0
    assert detail["deterministic_replay"] is True
