"""Continuous prefill: chunked prompt ingestion == one-shot prefill.

Model level: feeding a prompt through ``tfm.prefill_chunk`` in arbitrary
chunk sizes must leave the same striped cache and produce the same
next-token logits as a single one-shot ``tfm.prefill`` — bitwise on the ref
backend for GQA at aligned prompt lengths (the chunk path reuses the exact
decode einsums and band kernel; ragged lengths differ only by XLA's choice
of reduction association, pinned to a tight atol), token-level for MLA
(absorbed decode math vs non-absorbed prefill math differ in fp
association only).

Engine level: a ``ServeEngine`` with ``ServeConfig.prefill_chunk`` set must
generate token-for-token what the one-shot engine generates, for any chunk
size and token budget, dense and paged, with the budget bounding each
tick's ingested prompt tokens.  Plus the ``ServeConfig`` validation surface
and the legacy-kwarg deprecation shim this PR pins.
"""

import dataclasses
import math
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.masking import prefix_chunk_visibility
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.parallel.context import ParallelCtx
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine

CAP = 64


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _oneshot(cfg, params, ctx, prompt):
    cache = tfm.init_cache(cfg, 1, CAP, dtype=jnp.float32, ctx=ctx)
    S = len(prompt)
    batch = {
        "tokens": jnp.asarray(prompt)[None],
        "positions": jnp.arange(S, dtype=jnp.int32),
    }
    return tfm.prefill(params, cfg, ctx, batch, cache)


def _chunked(cfg, params, ctx, prompt, C):
    cache = tfm.init_cache(cfg, 1, CAP, dtype=jnp.float32, ctx=ctx)
    cache["pos"] = cache["pos"].at[0].set(2**30)  # park: not yet decodable
    S = len(prompt)
    for start in range(0, S, C):
        take = min(C, S - start)
        toks = np.zeros((1, C), np.int32)
        toks[0, :take] = prompt[start:start + take]
        batch = {
            "tokens": jnp.asarray(toks),
            "starts": jnp.asarray([start], jnp.int32),
            "lens": jnp.asarray([take], jnp.int32),
            "write_starts": jnp.asarray([0], jnp.int32),
            "pos_set": jnp.asarray([S if start + take >= S else -1], jnp.int32),
        }
        logits, cache = tfm.prefill_chunk(params, cfg, ctx, batch, cache)
    return logits, cache


def _assert_pair(cfg, params, prompt, C, atol=None):
    """atol=None: bitwise logits + cache.  atol=float: same token, logits
    and cache within atol (XLA picks a different reduction association for
    the [S, S] one-shot matmul vs the banded chunk path when S is ragged —
    fp-order noise, not a visibility difference)."""
    ctx = ParallelCtx()
    l1, c1 = _oneshot(cfg, params, ctx, prompt)
    l2, c2 = _chunked(cfg, params, ctx, prompt, C)
    l1 = np.asarray(l1).reshape(-1)
    l2 = np.asarray(l2).reshape(-1)
    assert int(np.argmax(l1)) == int(np.argmax(l2))
    for a, b in [(l1, l2)] + [
        (np.asarray(c1[k]), np.asarray(c2[k]))
        for k in c1 if k not in ("pos", "bt")
    ]:
        if atol is None:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, atol=atol, rtol=1e-5)
    assert int(c1["pos"][0]) == int(c2["pos"][0]) == len(prompt)


# --------------------------------------------------------------------------
# model level: chunked == one-shot on the live cache
# --------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    S=st.integers(min_value=1, max_value=28),
    C=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_chunked_prefill_matches_oneshot(granite, S, C, seed):
    """Any chunking of the prompt selects the same next token and leaves the
    cache equal to fp-reassociation tolerance, for arbitrary (S, C)."""
    cfg, params = granite
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, (S,), dtype=np.int32)
    ops.set_backend("ref")
    try:
        _assert_pair(cfg, params, prompt, C, atol=1e-5)
    finally:
        ops.set_backend("auto")


@pytest.mark.parametrize("S", [8, 16, 24, 32])
@pytest.mark.parametrize("C", [5, 8, 16])
def test_chunked_prefill_bitwise_on_aligned_lengths(granite, S, C):
    """On the ref backend both paths run the same einsums and band kernel,
    so aligned prompt lengths (where XLA keeps one reduction association
    for both launch shapes) are BITWISE identical — logits and cache."""
    cfg, params = granite
    rng = np.random.default_rng(S * 31 + C)
    prompt = rng.integers(0, cfg.vocab_size, (S,), dtype=np.int32)
    ops.set_backend("ref")
    try:
        _assert_pair(cfg, params, prompt, C)
    finally:
        ops.set_backend("auto")


def test_chunked_prefill_windowed_arch_bitwise(granite):
    """Sliding-window attention: the chunk band widens only the schedule
    prune, not the visibility, so windowed archs stay bitwise too."""
    cfg, params = granite
    wcfg = dataclasses.replace(cfg, window=8)
    wparams = tfm.init_params(wcfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, wcfg.vocab_size, (24,), dtype=np.int32)
    ops.set_backend("ref")
    try:
        for C in (5, 8):
            _assert_pair(wcfg, wparams, prompt, C)
    finally:
        ops.set_backend("auto")


def test_chunked_prefill_mla_token_equal():
    """MLA chunks through the absorbed decode einsums while one-shot prefill
    uses the non-absorbed form: same math, different fp association —
    token-level equal, logits close."""
    cfg = get_config("minicpm3-4b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (20,), dtype=np.int32)
    ctx = ParallelCtx()
    ops.set_backend("ref")
    try:
        l1, _ = _oneshot(cfg, params, ctx, prompt)
        l2, _ = _chunked(cfg, params, ctx, prompt, 8)
    finally:
        ops.set_backend("auto")
    l1, l2 = np.asarray(l1).reshape(-1), np.asarray(l2).reshape(-1)
    assert int(np.argmax(l1)) == int(np.argmax(l2))
    np.testing.assert_allclose(l1, l2, atol=1e-4, rtol=1e-4)


def test_chunked_prefill_then_decode_token_for_token(granite):
    """Decode from a chunk-built cache must emit the same tokens as decode
    from a one-shot cache — the cache states are interchangeable."""
    cfg, params = granite
    ctx = ParallelCtx()
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, (23,), dtype=np.int32)
    ops.set_backend("ref")
    try:
        l1, c1 = _oneshot(cfg, params, ctx, prompt)
        l2, c2 = _chunked(cfg, params, ctx, prompt, 6)
        t1 = jnp.asarray([[int(np.argmax(np.asarray(l1)))]], jnp.int32)
        t2 = jnp.asarray([[int(np.argmax(np.asarray(l2)))]], jnp.int32)
        s1, s2 = [], []
        for _ in range(5):
            t1, c1, _ = tfm.decode_step(params, c1, t1, cfg, ctx)
            t2, c2, _ = tfm.decode_step(params, c2, t2, cfg, ctx)
            s1.append(int(t1[0, 0]))
            s2.append(int(t2[0, 0]))
    finally:
        ops.set_backend("auto")
    assert s1 == s2


def test_prefill_chunk_rejects_non_attention_arch():
    cfg = get_config("mamba2-370m").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ctx = ParallelCtx()
    cache = tfm.init_cache(cfg, 1, CAP, dtype=jnp.float32, ctx=ctx)
    batch = {
        "tokens": jnp.zeros((1, 4), jnp.int32),
        "starts": jnp.zeros((1,), jnp.int32),
        "lens": jnp.full((1,), 4, jnp.int32),
        "write_starts": jnp.zeros((1,), jnp.int32),
        "pos_set": jnp.full((1,), 4, jnp.int32),
    }
    with pytest.raises(ValueError, match="attention-only"):
        tfm.prefill_chunk(params, cfg, ctx, batch, cache)
    with pytest.raises(ValueError, match="attention-only"):
        ServeEngine(cfg, params,
                    serve=ServeConfig(max_seq=32, num_slots=1, prefill_chunk=4))


# --------------------------------------------------------------------------
# engine level: chunked serving == one-shot serving
# --------------------------------------------------------------------------

_PROMPT_LENS = (9, 22, 13, 30)
_ARRIVALS = (0, 0, 2, 3)
_NEW = 5


def _serve(cfg, params, serve, prompts):
    eng = ServeEngine(cfg, params, serve=serve)
    rids = [eng.submit(p, _NEW, arrival_tick=a)
            for p, a in zip(prompts, _ARRIVALS)]
    out = eng.run()
    return eng, [out[r] for r in rids]


@pytest.fixture(scope="module")
def engine_ref(granite):
    cfg, params = granite
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32)
               for ln in _PROMPT_LENS]
    _, results = _serve(cfg, params, ServeConfig(max_seq=CAP, num_slots=2),
                        prompts)
    return prompts, results


@pytest.mark.parametrize("chunk,budget", [(4, None), (8, 12), (64, None)])
def test_engine_chunked_matches_oneshot(granite, engine_ref, chunk, budget):
    cfg, params = granite
    prompts, ref = engine_ref
    eng, got = _serve(
        cfg, params,
        ServeConfig(max_seq=CAP, num_slots=2,
                    prefill_chunk=chunk, tick_token_budget=budget),
        prompts,
    )
    for r, g in zip(ref, got):
        assert g.generated == r.generated
    assert eng.chunk_trace_count == 1  # one [slots, C] trace serves every tick
    if chunk == 64 and budget is None:
        # every prompt fits one chunk and nothing is deferred: tick parity
        # with the one-shot engine, not just token parity
        for r, g in zip(ref, got):
            assert g.first_token_tick == r.first_token_tick
            assert g.finish_tick == r.finish_tick


def test_engine_chunked_paged_shared_prefix(granite):
    cfg, params = granite
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (ln,),
                                            dtype=np.int32)]).astype(np.int32)
               for ln in (6, 14, 9, 11)]
    _, ref = _serve(cfg, params,
                    ServeConfig(max_seq=CAP, num_slots=2, paged=True), prompts)
    eng, got = _serve(
        cfg, params,
        ServeConfig(max_seq=CAP, num_slots=2, paged=True,
                    prefill_chunk=8, tick_token_budget=16),
        prompts,
    )
    for r, g in zip(ref, got):
        assert g.generated == r.generated
    assert eng.allocator.stats()["shared_hits"] > 0


def test_budget_bounds_tick_prefill_tokens(granite):
    """No tick ingests more prompt tokens than the budget allows (the
    head-of-line chunk is always granted, so the bound is
    max(budget, chunk))."""
    cfg, params = granite
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32)
               for ln in _PROMPT_LENS]
    budget, chunk = 6, 4
    eng, _ = _serve(
        cfg, params,
        ServeConfig(max_seq=CAP, num_slots=2,
                    prefill_chunk=chunk, tick_token_budget=budget),
        prompts,
    )
    stats = eng.tick_stats()
    assert sum(stats["prefill_tokens"]) == sum(_PROMPT_LENS)
    assert max(stats["prefill_tokens"]) <= max(budget, chunk)
    assert sum(stats["decode_tokens"]) == len(_PROMPT_LENS) * _NEW


def test_request_result_surface(granite, engine_ref):
    cfg, params = granite
    prompts, _ = engine_ref
    chunk = 8
    _, got = _serve(
        cfg, params,
        ServeConfig(max_seq=CAP, num_slots=2, prefill_chunk=chunk),
        prompts,
    )
    for p, r in zip(prompts, got):
        assert list(r.tokens) == r.generated
        assert len(r.token_ticks) == len(r.generated) == _NEW
        assert list(r.token_ticks) == sorted(r.token_ticks)
        assert r.ttft_ticks == r.first_token_tick - r.arrival_tick + 1
        assert r.chunks == math.ceil(len(p) / chunk)
        assert r.first_chunk_tick <= r.first_token_tick
        assert r.done


# --------------------------------------------------------------------------
# ServeConfig surface: validation + the legacy-kwarg shim
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs", [
    dict(max_seq=0),
    dict(num_slots=0),
    dict(pack_plan="fastest"),
    dict(decode_kernel="magic"),
    dict(prefill_buckets=(0,)),
    dict(page_size=8),  # requires paged=True
    dict(paged=True, page_size=0),
    dict(prefill_chunk=0),
    dict(tick_token_budget=8),  # requires prefill_chunk
    dict(prefill_chunk=4, tick_token_budget=0),
])
def test_serve_config_rejects_bad_combinations(kwargs):
    with pytest.raises(ValueError):
        ServeConfig(**kwargs)


def test_legacy_kwargs_warn_and_map(granite):
    cfg, params = granite
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        eng = ServeEngine(cfg, params, max_seq=32, num_slots=1)
    assert eng.serve == ServeConfig(max_seq=32, num_slots=1)
    with pytest.raises(TypeError, match="unknown ServeEngine kwargs"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ServeEngine(cfg, params, max_sequence=32)
    with pytest.raises(TypeError, match="not both"):
        ServeEngine(cfg, params, serve=ServeConfig(), max_seq=32)


# --------------------------------------------------------------------------
# masking: chunk-vs-prefix visibility classification
# --------------------------------------------------------------------------


def test_prefix_chunk_visibility_classification():
    # a chunk at [8, 16) over prefix keys [0, 8): all causal-visible
    assert prefix_chunk_visibility(8, 16, 0, 8) == "full"
    # keys overlapping the chunk's own rows: partial (diagonal inside)
    assert prefix_chunk_visibility(8, 16, 8, 16) == "partial"
    # keys entirely in the future (bounds inclusive, so k_lo=16 would still
    # touch the diagonal at q=16): empty
    assert prefix_chunk_visibility(8, 16, 17, 24) == "empty"
    assert prefix_chunk_visibility(8, 16, 16, 24) == "partial"
    # window clips the oldest keys for the newest rows
    assert prefix_chunk_visibility(8, 16, 0, 8, window=4) == "partial"
    # window wide enough to keep the whole prefix: full again
    assert prefix_chunk_visibility(8, 16, 7, 8, window=16) == "full"
    # keys too old for every row under the window: empty
    assert prefix_chunk_visibility(32, 40, 0, 8, window=4) == "empty"
    # single-position ranges are valid (bounds inclusive): the diagonal
    # pair (q=8, k=8) is causal-visible
    assert prefix_chunk_visibility(8, 8, 8, 8) == "full"
    with pytest.raises(ValueError):
        prefix_chunk_visibility(8, 7, 0, 8)
    with pytest.raises(ValueError):
        prefix_chunk_visibility(8, 16, 0, 8, window=0)
