"""Per-architecture smoke tests: reduced configs, single device.

Every assigned arch instantiates a family-preserving reduced config and runs
one forward + one gradient step on CPU, asserting output shapes and no NaNs.
Serving continuity (prefill -> decode == teacher-forced forward) is checked
for one representative arch per family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, PAPER_ARCH, get_config
from repro.data.pipeline import make_batch
from repro.models import transformer as tfm
from repro.parallel.context import ParallelCtx

CTX = ParallelCtx()
SEQ, BATCH = 32, 2


def _setup(name):
    cfg = get_config(name).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SEQ, BATCH, ctx=CTX)
    return cfg, params, batch


@pytest.mark.parametrize("name", ALL_ARCHS + [PAPER_ARCH])
def test_forward_and_grad_step(name):
    cfg, params, batch = _setup(name)
    logits, aux = jax.jit(lambda p: tfm.forward(p, cfg, CTX, batch))(params)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any(), "NaNs in logits"

    def loss(p):
        return tfm.loss_fn(p, cfg, CTX, batch)[0]

    l0, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # one SGD step must reduce loss locally
    lr = 1e-2 / (float(gnorm) + 1e-6)
    new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = jax.jit(loss)(new_params)
    assert float(l1) < float(l0) + 1e-3, (float(l0), float(l1))


@pytest.mark.parametrize(
    "name",
    ["granite-8b", "minicpm3-4b", "mixtral-8x7b", "mamba2-370m", "hymba-1.5b", "whisper-base"],
)
def test_prefill_decode_matches_forward(name):
    """Teacher-forced decode after prefill must reproduce the forward logits
    (exercises KV/latent/SSM caches end-to-end).

    MoE capacity is pinned high: capacity dropping depends on the total token
    count (C = ceil(S·k·cf/E)), so a truncated forward legitimately drops
    differently — drop behaviour is tested separately in test_moe_capacity.
    """
    import dataclasses

    cfg, params, batch = _setup(name)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    S = SEQ
    logits_full, _ = jax.jit(lambda p: tfm.forward(p, cfg, CTX, batch))(params)

    S0 = S // 2
    pre_batch = {
        k: (v[:, :S0] if k in ("tokens", "labels") else (v[:S0] if k == "positions" else v))
        for k, v in batch.items()
    }
    cache = tfm.init_cache(cfg, BATCH, S, dtype=jnp.float32)
    logits_pre, cache = jax.jit(
        lambda p, c: tfm.prefill(p, cfg, CTX, pre_batch, c)
    )(params, cache)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(logits_full[:, S0 - 1], np.float32),
        rtol=2e-4, atol=2e-4,
    )
    # teacher-forced decode over the second half
    step = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg, CTX))
    for t in range(S0, min(S0 + 4, S)):
        tok = batch["tokens"][:, t : t + 1]
        _, cache, logits_t = step(params, cache, tok)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0], np.float32),
            np.asarray(logits_full[:, t], np.float32),
            rtol=3e-4, atol=3e-4,
        )


def test_moe_ep_tp_equivalence():
    """EP and TP MoE modes are distributions of the same math — outputs must
    match on a single device."""
    import dataclasses

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SEQ, BATCH, ctx=CTX)
    logits_ep, _ = tfm.forward(params, cfg, CTX, batch)
    cfg_tp = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, mode="tp"))
    logits_tp, _ = tfm.forward(params, cfg_tp, CTX, batch)
    np.testing.assert_allclose(logits_ep, logits_tp, rtol=1e-6, atol=1e-6)


def test_moe_capacity_drops_tokens():
    """Capacity must bind: shrinking cf changes outputs (tokens dropped) while
    a huge cf reproduces the dropless result."""
    import dataclasses

    cfg = get_config("mixtral-8x7b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SEQ, BATCH, ctx=CTX)
    big = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    tiny = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    l_big, _ = tfm.forward(params, big, CTX, batch)
    l_big2, _ = tfm.forward(params, big, CTX, batch)
    l_tiny, _ = tfm.forward(params, tiny, CTX, batch)
    np.testing.assert_allclose(l_big, l_big2)  # deterministic
    assert float(jnp.max(jnp.abs(l_big - l_tiny))) > 1e-3  # drops happened
    assert not np.isnan(np.asarray(l_tiny)).any()


def test_ssd_chunked_equals_sequential():
    """The chunked SSD dual form must equal the sequential recurrence."""
    from repro.kernels.ref import ssd_ref
    from repro.models.ssm import ssd_scan

    B, S, H, P, G, N = 2, 32, 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    Bh = jnp.repeat(Bm, H // G, axis=2)
    Ch = jnp.repeat(Cm, H // G, axis=2)
    for chunk in (4, 8, 16, 32):
        y, hT = ssd_scan(x, dt, A, Bh, Ch, chunk)
        y_ref, hT_ref = ssd_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(hT, hT_ref, rtol=2e-4, atol=2e-4)
    # nonzero initial state path (used by the cross-device correction)
    h0 = jax.random.normal(jax.random.PRNGKey(9), (B, H, P, N))
    y, hT = ssd_scan(x, dt, A, Bh, Ch, 8, h0=h0)
    y_ref, hT_ref = ssd_ref(x, dt, A, Bm, Cm, initial_state=h0)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(hT, hT_ref, rtol=2e-4, atol=2e-4)
