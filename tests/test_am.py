"""Communication-volume analytics vs the paper's Table 2 / §3.8 formulas."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import am
from repro.core.tiling import factorizations


def test_ring_volume():
    assert am.ring_volume(9) == pytest.approx(2 - 2 / 9)
    # paper: ~2Nd asymptotically
    assert am.ring_volume(4096) == pytest.approx(2.0, abs=1e-3)


def test_mesh_volume_formula():
    # (2a/n + 2/a - 4/n) Nd
    for n in (9, 16, 64, 256):
        for a, b in factorizations(n):
            want = 2 * a / n + 2 / a - 4 / n
            assert am.mesh_volume(n, a) == pytest.approx(want)


def test_mesh_optimum_sqrt_n():
    """AM-GM: volume minimized at a = sqrt(n) -> ~4/sqrt(n) Nd."""
    for n in (16, 64, 256, 1024):
        r = int(math.isqrt(n))
        vols = {a: am.mesh_volume(n, a) for a, _ in factorizations(n)}
        assert min(vols, key=vols.get) == r
        assert vols[r] == pytest.approx(4 / r - 4 / n)


def test_mesh_covers_ring_special_case():
    for n in (4, 9, 256):
        assert am.mesh_volume(n, 1) == pytest.approx(am.ring_volume(n))


def test_paper_256gpu_reduction():
    """Paper §4.5: ~78-85% comm reduction at 256 GPUs (fwd theory: 1-4/sqrt(n)/2)."""
    n = 256
    red = 1 - am.mesh_volume(n) / am.ring_volume(n)
    assert 0.85 <= red <= 0.90  # theory: 1 - (4/16-4/256)/(2-2/256) = 0.877


def test_table2_ordering():
    """At any realistic n: ulysses < mesh < startrail < ring (per Table 2)."""
    for n in (64, 256, 1024):
        t = am.table2(n)
        assert t["ulysses"] < t["mesh"] < t["startrail"] < t["ring"]


@given(st.integers(4, 1024))
@settings(max_examples=80, deadline=None)
def test_scaling_property(n):
    """Mesh per-device volume decreases ~1/sqrt(n); Ring stays ~constant
    (paper §4.5 observation)."""
    assert am.mesh_volume(4 * n) < am.mesh_volume(n) + 1e-12
    assert abs(am.ring_volume(4 * n) - am.ring_volume(n)) < 0.5


def test_comm_model_bytes():
    m = am.CommModel(seq=8192, hidden=4096, n=16, kv_hidden=1024, bytes_per_elem=2)
    chunk = 8192 // 16 * 2  # tokens * bytes
    assert m.chunk_bytes("q") == chunk * 4096
    assert m.chunk_bytes("kv") == chunk * 2 * 1024
    assert m.chunk_bytes("odoq") == chunk * 3 * 4096
    # fwd bytes at a=4: 3 Q + 3 KV + 3 O
    assert m.fwd_bytes(4) == 3 * m.chunk_bytes("q") + 3 * m.chunk_bytes("kv") + 3 * m.chunk_bytes("o")
    # ring = (n-1) KV chunks
    assert m.ring_fwd_bytes() == 15 * m.chunk_bytes("kv")


def test_gqa_shifts_optimum_toward_smaller_a():
    """GQA (small KV) makes KV cheap relative to Q/O, so the byte-optimal tile
    gets flatter (smaller a) — the §4.7 effect."""
    mha = am.CommModel(seq=1 << 20, hidden=4096, n=64)
    gqa8 = am.CommModel(seq=1 << 20, hidden=4096, n=64, kv_hidden=4096 // 8)
    assert gqa8.best_a() <= mha.best_a()
    assert mha.best_a() == 8  # sqrt(64) for symmetric traffic
