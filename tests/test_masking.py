"""First-class masks: MaskSpec classification vs the dense oracle, schedule
pruning invariants, packed-document kernels vs the per-document oracle, and
the mask-keyed plan cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import schedule as S
from repro.core.masking import EMPTY, FULL, PARTIAL, MaskSpec
from repro.core.tiling import TileLayout, factorizations
from repro.kernels import ops, ref

# --------------------------------------------------------------------------
# MaskSpec construction + basic semantics
# --------------------------------------------------------------------------


def test_mask_spec_validation():
    with pytest.raises(ValueError):
        MaskSpec(kind="nope")
    with pytest.raises(ValueError):
        MaskSpec(kind="full", window=4)  # window needs a causal kind
    with pytest.raises(ValueError):
        MaskSpec.document(())
    with pytest.raises(ValueError):
        MaskSpec.block_sparse(((True, False),))  # not square
    with pytest.raises(ValueError):
        MaskSpec.from_flags(False, window=4)
    assert MaskSpec.from_flags(True).kind == "causal"
    assert MaskSpec.from_flags(True, 8).window == 8
    assert MaskSpec.from_flags(False).kind == "full"
    # hashable (rides on jit-static configs) and signature-stable
    assert hash(MaskSpec.document((4, 4))) == hash(MaskSpec.document((4, 4)))
    assert MaskSpec.document((4, 4)).signature() != MaskSpec.causal().signature()
    assert MaskSpec.causal(8).signature() != MaskSpec.causal().signature()


def test_dense_mask_shapes():
    spec = MaskSpec.document((3, 5))
    dm = spec.dense_mask(8)
    assert dm.shape == (8, 8)
    assert not dm[:3, 3:].any() and not dm[3:, :3].any()  # cross-document
    assert dm[4, 3] and not dm[3, 4]  # causal within doc
    with pytest.raises(ValueError):
        MaskSpec.segment().dense_mask(8)  # runtime ids required
    bs = MaskSpec.block_sparse(((True, False), (False, True)))
    dmb = bs.dense_mask(4)
    assert dmb[:2, :2].all() and not dmb[:2, 2:].any()


# --------------------------------------------------------------------------
# block_visibility vs the dense oracle (the pruning soundness property)
# --------------------------------------------------------------------------


def _spec_strategy():
    return st.sampled_from(["full", "causal", "window", "document", "segment"])


@given(
    st.integers(1, 12).flatmap(
        lambda n: st.tuples(st.just(n), st.sampled_from([a for a, _ in factorizations(n)]))
    ),
    _spec_strategy(),
    st.sampled_from(["striped", "contiguous"]),
    st.integers(1, 4),
)
@settings(max_examples=120, deadline=None)
def test_block_visibility_matches_dense_oracle(na, kind, layout, m):
    """EMPTY must mean empty on EVERY device; FULL full on every device.
    PARTIAL is the conservative remainder."""
    n, a = na
    b = n // a
    seq = n * m
    if kind == "full":
        spec = MaskSpec.full()
    elif kind == "causal":
        spec = MaskSpec.causal()
    elif kind == "window":
        spec = MaskSpec.causal(window=max(1, seq // 3))
    elif kind == "document":
        d1 = max(1, seq // 3)
        spec = MaskSpec.document((d1, seq - d1)) if seq > 1 else MaskSpec.document((seq,))
    else:
        spec = MaskSpec.segment()
    dm = spec.dense_mask(seq, segments=np.zeros(seq, np.int32) if kind == "segment" else None)
    lay = TileLayout(n, a)
    vis = spec.block_visibility(a, b, layout=layout, n=n, seq=seq)
    for (u, v), cls in vis.items():
        per_dev = []
        for i in range(n):
            qc, kc = lay.q_chunk(i, u), lay.kv_chunk(i, v)
            if layout == "striped":
                qpos, kpos = qc + n * np.arange(m), kc + n * np.arange(m)
            else:
                qpos, kpos = qc * m + np.arange(m), kc * m + np.arange(m)
            sub = dm[np.ix_(qpos, kpos)]
            per_dev.append("full" if sub.all() else ("empty" if not sub.any() else "partial"))
        if cls == EMPTY:
            # soundness: pruning never drops a block any device needs
            assert all(p == "empty" for p in per_dev), (u, v, per_dev)
        elif cls == FULL:
            # segment masks can't prove fullness statically, but the dense
            # oracle with one segment may still be full — only check the
            # static kinds
            assert all(p == "full" for p in per_dev), (u, v, per_dev)
        else:
            assert cls == PARTIAL


# --------------------------------------------------------------------------
# schedule pruning invariants
# --------------------------------------------------------------------------


@given(
    st.integers(2, 16).flatmap(
        lambda n: st.tuples(st.just(n), st.sampled_from([a for a, _ in factorizations(n)]))
    ),
    st.integers(0, 1000),
    st.booleans(),
)
@settings(max_examples=150, deadline=None)
def test_pruned_schedules_stay_valid(na, seed, concurrent):
    """Any mask-shaped skip set (random blocks minus (0,0)) yields schedules
    that validate, compute exactly the surviving blocks, and never use MORE
    comm than the unpruned schedule."""
    n, a = na
    b = n // a
    rng = np.random.default_rng(seed)
    blocks = [(u, v) for u in range(a) for v in range(b) if (u, v) != (0, 0)]
    k = int(rng.integers(0, len(blocks) + 1)) if blocks else 0
    skip = frozenset(
        tuple(blocks[i]) for i in rng.choice(len(blocks), size=k, replace=False)
    ) if k else frozenset()
    for gen in (S.greedy_forward_schedule, S.greedy_backward_schedule):
        pruned = gen(a, b, allow_concurrent_rings=concurrent, skip_blocks=skip)
        full = gen(a, b, allow_concurrent_rings=concurrent)
        S.validate_schedule(pruned, strict_paper=not concurrent)
        assert set(pruned.blocks()) == set(full.blocks()) - skip
        assert len(pruned.comm_ops()) <= len(full.comm_ops())
        assert set(pruned.skip) == set(skip)
        # round-trips through the plan-cache JSON with its skip set
        rt = S.schedule_from_json(S.schedule_to_json(pruned))
        assert rt == pruned


def test_skip_of_local_block_rejected():
    with pytest.raises(ValueError):
        S.greedy_forward_schedule(2, 2, skip_blocks={(0, 0)})


def test_comm_requirements_counts():
    # unpruned: the paper's (a-1, b-1, a-1) forward counts
    req = S.comm_requirements(3, 4, "fwd", ())
    assert req == {S.RECV_Q: 2, S.RECV_KV: 3, S.SEND_O: 2}
    # KV slots 2,3 unused everywhere -> trailing recvs pruned; row 1 fully
    # empty -> its (leading) send pruned
    skip = {(u, v) for u in range(3) for v in range(4) if v >= 2 or u == 1}
    assert S.comm_requirements(3, 4, "fwd", skip) == {
        S.RECV_Q: 2, S.RECV_KV: 1, S.SEND_O: 1,
    }
    # backward mirrors: dQ sends lose the row-1 prefix; dKV sends keep all 3
    # (col 1 is still used, and sends carry an accumulation chain)
    assert S.comm_requirements(3, 4, "bwd", skip) == {
        S.RECV_ODOQ: 2, S.RECV_KV: 1, S.SEND_DQ: 1, S.SEND_DKV: 3,
    }


# --------------------------------------------------------------------------
# packed documents: forward + grad == per-document dense oracle
# --------------------------------------------------------------------------


def _doc_split(seq, frac):
    d1 = min(max(1, int(seq * frac)), seq - 1)
    return (d1, seq - d1)


@given(st.integers(0, 6), st.floats(0.15, 0.85))
@settings(max_examples=10, deadline=None)
def test_packed_two_documents_match_per_document_oracle(seed, frac):
    """flash_attention with segment ids over a packed two-document row ==
    each document attended alone, for the output AND all three gradients."""
    B, Ssum, H, Hkv, D = 2, 24, 4, 2, 8
    lens = _doc_split(Ssum, frac)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (B, Ssum, H, D))
    k = jax.random.normal(kk, (B, Ssum, Hkv, D))
    v = jax.random.normal(kv, (B, Ssum, Hkv, D))
    seg = jnp.asarray(np.repeat(np.arange(2, dtype=np.int32), lens))

    def loss_packed(q, k, v):
        return jnp.sum(jnp.sin(ops.flash_attention(q, k, v, causal=True, seg_q=seg)))

    def loss_oracle(q, k, v):
        tot = 0.0
        off = 0
        for ln in lens:
            sl = slice(off, off + ln)
            kr = ref.repeat_kv(k[:, sl], H)
            vr = ref.repeat_kv(v[:, sl], H)
            s = jnp.einsum("bqhd,bkhd->bhqk", q[:, sl], kr) * (D**-0.5)
            mask = jnp.tril(jnp.ones((ln, ln), bool))
            s = jnp.where(mask[None, None], s, -1e30)
            o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, axis=-1), vr)
            tot = tot + jnp.sum(jnp.sin(o))
            off += ln
        return tot

    o_p = ops.flash_attention(q, k, v, causal=True, seg_q=seg)
    o_docs = []
    off = 0
    for ln in lens:
        o_docs.append(ops.flash_attention(q[:, off:off + ln], k[:, off:off + ln],
                                          v[:, off:off + ln], causal=True))
        off += ln
    np.testing.assert_allclose(
        np.asarray(o_p), np.asarray(jnp.concatenate(o_docs, axis=1)), atol=2e-5
    )
    g_p = jax.jit(jax.grad(loss_packed, argnums=(0, 1, 2)))(q, k, v)
    g_o = jax.jit(jax.grad(loss_oracle, argnums=(0, 1, 2)))(q, k, v)
    for a_, b_ in zip(g_p, g_o):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_), atol=5e-5)


def test_packed_ref_matches_pallas_interpret():
    """Segment-masked Pallas kernels (interpret) == jnp reference, fwd+bwd."""
    B, Ssum, H, Hkv, D = 1, 16, 2, 1, 8
    lens = (6, 10)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kq, (B, Ssum, H, D))
    k = jax.random.normal(kk, (B, Ssum, Hkv, D))
    v = jax.random.normal(kv, (B, Ssum, Hkv, D))
    seg = jnp.asarray(np.repeat(np.arange(2, dtype=np.int32), lens))

    def loss(q, k, v):
        return jnp.sum(jnp.sin(ops.flash_attention(q, k, v, causal=True, seg_q=seg)))

    ops.set_backend("ref")
    try:
        o_ref_ = ops.flash_attention(q, k, v, causal=True, seg_q=seg)
        g_ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    finally:
        ops.set_backend("pallas")
    try:
        o_pal = ops.flash_attention(q, k, v, causal=True, seg_q=seg)
        g_pal = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    finally:
        ops.set_backend("auto")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref_), atol=2e-5)
    for a_, b_ in zip(g_pal, g_ref):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_), atol=5e-5)


# --------------------------------------------------------------------------
# mask-aware cost model + plan-cache key
# --------------------------------------------------------------------------


def test_visible_fraction_matches_dense_mean():
    for spec, seq in [
        (MaskSpec.full(), 16),
        (MaskSpec.causal(), 16),
        (MaskSpec.causal(window=5), 16),
        (MaskSpec.document((6, 10)), 16),
        (MaskSpec.block_sparse(((True, False), (True, True))), 16),
    ]:
        dm = spec.dense_mask(seq)
        assert spec.visible_fraction(seq) == pytest.approx(dm.mean(), rel=1e-6), spec


def test_mask_signature_enters_plan_cache_key():
    """Masked and unmasked plans for the SAME geometry must never collide."""
    from repro.core.am import CommModel
    from repro.core.dispatch import AttentionPlanConfig, _plan_key
    from repro.core.simulator import HardwareModel

    comm = CommModel(seq=64, hidden=128, n=4, kv_hidden=64, bytes_per_elem=4, batch=2)
    hw = HardwareModel()
    base = dict(backend="mesh", axis_name="sp", n=4, a=2, layout="contiguous")
    k_causal, _ = _plan_key(AttentionPlanConfig(causal=True, **base), comm, hw)
    k_doc, _ = _plan_key(
        AttentionPlanConfig(mask=MaskSpec.document((32, 32)), **base), comm, hw
    )
    k_doc2, _ = _plan_key(
        AttentionPlanConfig(mask=MaskSpec.document((16, 48)), **base), comm, hw
    )
    k_win, _ = _plan_key(AttentionPlanConfig(mask=MaskSpec.causal(8), **base), comm, hw)
    assert len({k_causal, k_doc, k_doc2, k_win}) == 4
    # layout is load-bearing for pruning and must key too
    k_striped, _ = _plan_key(
        AttentionPlanConfig(
            mask=MaskSpec.document((32, 32)),
            **{**base, "layout": "striped"},
        ),
        comm, hw,
    )
    assert k_striped != k_doc


def test_autotune_prunes_with_document_mask():
    from repro.core.am import CommModel
    from repro.core.autotune import plan_for

    comm = CommModel(seq=64, hidden=128, n=4, kv_hidden=64, bytes_per_elem=4, batch=2)
    masked = plan_for(comm, 2, mask=MaskSpec.document((32, 32)), layout="contiguous")
    unmasked = plan_for(comm, 2, causal=True, layout="contiguous")
    assert masked.comm_bytes < unmasked.comm_bytes
    assert len(masked.fwd.comm_ops()) < len(unmasked.fwd.comm_ops())
    assert set(masked.fwd.skip)  # blocks actually pruned


def test_legacy_config_flags_still_work():
    """Back-compat: causal/window booleans normalize to the same MaskSpec."""
    from repro.core.dispatch import AttentionPlanConfig
    from repro.core.mesh_attention import MeshAttentionConfig

    c = MeshAttentionConfig(axis_name="sp", n=4, a=2, causal=True, window=8)
    assert c.mask_spec() == MaskSpec.causal(8)
    p = AttentionPlanConfig(causal=True)
    assert p.mask_spec() == MaskSpec.causal()
    with pytest.raises(ValueError):
        MeshAttentionConfig(axis_name="sp", n=4, a=2, causal=True, mask=MaskSpec.causal())
    with pytest.raises(ValueError):
        AttentionPlanConfig(causal=True, mask=MaskSpec.causal())
