"""Substrate tests: optimizer, checkpointing/fault-tolerance, compression,
straggler monitor, serving engine, end-to-end training loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, make_schedule
from repro.parallel.compression import CompressionConfig, compress_grads, init_error_state
from repro.parallel.context import ParallelCtx
from repro.train import checkpoint as ckpt
from repro.train.loop import TrainConfig, fit
from repro.train.monitor import StepMonitor, StragglerPolicy

CTX = ParallelCtx()


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=300, warmup_steps=1, schedule="constant")
    state = init_opt_state(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_clipping_and_schedule():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=10, total_steps=100)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    big = {"w": jnp.full(4, 1e6)}
    params, state, m = adamw_update(params, big, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    assert np.isfinite(np.asarray(params["w"])).all()
    sched = make_schedule(cfg)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0, rel=0.05)
    assert float(sched(jnp.int32(100))) < 0.01


# --------------------------------------------------------------------------
# checkpointing / fault tolerance
# --------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": {"c": jnp.ones(4)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    ckpt.save(d, 5, tree)
    restored, step = ckpt.restore(d, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_keep_k_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, _tree(), keep=2)
    assert ckpt.latest_step(d) == 4
    assert sorted(ckpt._list_steps(d)) == [3, 4]


def test_checkpoint_corruption_detected(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    ckpt.save(d, 2, _tree())
    # corrupt the newest
    with open(os.path.join(d, "step_000000002", "arrays.npz"), "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00garbage\x00")
    # latest_step must skip the corrupt one
    assert ckpt.latest_step(d) == 1
    restored, step = ckpt.restore(d, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree()))
    assert step == 1


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    saver = ckpt.AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        saver.save(s, _tree())
    saver.wait()
    assert ckpt.latest_step(d) == 3


def test_train_resume_after_injected_failure(tmp_path):
    """Train 6 steps with a crash at step 4; resume must continue from the
    checkpoint and produce the SAME final loss as an uninterrupted run
    (bitwise-deterministic data pipeline + state restore)."""
    cfg = get_config("granite-8b").reduced()
    d = str(tmp_path / "ck")
    tcfg = TrainConfig(steps=6, seq=16, batch=2, ckpt_dir=d, ckpt_every=2, log_every=100)
    with pytest.raises(RuntimeError):
        fit(cfg, CTX, tcfg, hooks={"fail_at": 4})
    assert ckpt.latest_step(d) == 4
    out = fit(cfg, CTX, tcfg)  # resumes from step 4
    assert out["step"] == 6 and not out["interrupted"]

    ref = fit(cfg, CTX, TrainConfig(steps=6, seq=16, batch=2, ckpt_dir=None))
    np.testing.assert_allclose(out["history"][-1], ref["history"][-1], rtol=1e-5)


# --------------------------------------------------------------------------
# compression
# --------------------------------------------------------------------------


def test_int8_error_feedback_unbiased():
    """With error feedback, the cumulative transmitted signal tracks the
    cumulative true gradient (residual stays bounded)."""
    key = jax.random.PRNGKey(0)
    cfg = CompressionConfig(kind="int8")
    g_true = {"w": jax.random.normal(key, (64,))}
    err = init_error_state(g_true)
    total_sent = jnp.zeros(64)
    for i in range(50):
        g = {"w": g_true["w"] * (1 + 0.01 * i)}
        sent, err = compress_grads(g, err, cfg)
        total_sent = total_sent + sent["w"]
    resid = jnp.abs(err["w"])
    assert float(jnp.max(resid)) < float(jnp.max(jnp.abs(g_true["w"]))) * 0.2


def test_topk_sparsity():
    cfg = CompressionConfig(kind="topk", topk_frac=0.1, error_feedback=False)
    g = {"w": jnp.arange(100.0) + 1.0}  # tie-free magnitudes
    sent, _ = compress_grads(g, init_error_state(g), cfg)
    assert int(jnp.sum(sent["w"] != 0)) == 10


def test_compressed_training_matches_uncompressed():
    """int8+EF training loss within a few percent of exact after 40 steps."""
    cfg = get_config("granite-8b").reduced()
    t = TrainConfig(steps=25, seq=16, batch=2)
    exact = fit(cfg, CTX, t)["history"]
    # single-device: compression config is a no-op path-wise (no pod axis),
    # so emulate by compressing grads in a custom hook-free run below
    from repro.models import transformer as tfm
    from repro.optim.adamw import AdamWConfig

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    comp = CompressionConfig(kind="int8")
    errs = init_error_state(params)
    from repro.data.pipeline import make_batch

    hist = []
    ocfg = AdamWConfig(total_steps=25)
    for step in range(25):
        batch = make_batch(cfg, 16, 2, step=step)
        (loss, _), grads = jax.value_and_grad(
            lambda p: tfm.loss_fn(p, cfg, CTX, batch), has_aux=True
        )(params)
        grads, errs = compress_grads(grads, errs, comp)
        params, opt, _ = adamw_update(params, grads, opt, ocfg)
        hist.append(float(loss))
    assert abs(hist[-1] - exact[-1]) / exact[-1] < 0.05


# --------------------------------------------------------------------------
# straggler monitor
# --------------------------------------------------------------------------


def test_straggler_detection():
    mon = StepMonitor(StragglerPolicy(sigma=3.0, patience=2, action="remesh"))
    for _ in range(20):
        assert mon.record(1.0) is None
    assert mon.is_straggler(3.0)
    assert mon.record(3.0) is None  # patience 1
    assert mon.record(3.0) == "remesh"  # escalates
    assert len(mon.events) == 2


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------


def test_serve_engine_greedy_consistency():
    """Engine generation must equal naive forward-argmax re-encoding."""
    from repro.models import transformer as tfm
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, max_seq=64)
    prompts = np.arange(8, dtype=np.int32).reshape(1, 8) % cfg.vocab_size
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (1, 4)

    # oracle: repeatedly run the full forward and take argmax
    toks = list(prompts[0])
    for _ in range(4):
        batch = {
            "tokens": jnp.asarray([toks], jnp.int32),
            "positions": jnp.arange(len(toks), dtype=jnp.int32),
        }
        logits, _ = tfm.forward(params, cfg, CTX, batch)
        toks.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(out[0], np.asarray(toks[8:]))
