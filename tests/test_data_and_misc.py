"""Data-pipeline determinism/striping, pipeline-stage bookkeeping, and
misc substrate edge cases (property-style, fast)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.data.pipeline import batch_spec_shapes, make_batch
from repro.parallel.context import ParallelCtx


def test_batch_deterministic_in_seed_and_step():
    cfg = get_config("granite-8b").reduced()
    a = make_batch(cfg, 32, 2, seed=7, step=3)
    b = make_batch(cfg, 32, 2, seed=7, step=3)
    c = make_batch(cfg, 32, 2, seed=7, step=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens_under_striping():
    """labels[j] must be the token following tokens[j] in TRUE positions,
    whatever the layout permutation."""

    class FakeCtx(ParallelCtx):
        pass

    cfg = get_config("granite-8b").reduced()
    # striping only activates with sp>1; emulate by calling the permutation
    from repro.core.tiling import stripe_permutation

    n, S = 4, 32
    batch = make_batch(cfg, S, 2, seed=0)
    perm = stripe_permutation(S, n)
    striped_tokens = np.asarray(batch["tokens"])[:, perm]
    striped_labels = np.asarray(batch["labels"])[:, perm]
    # invariant: for every striped index j, label == original next token
    tokens, labels = np.asarray(batch["tokens"]), np.asarray(batch["labels"])
    for j in range(S):
        p = perm[j]
        assert (striped_tokens[:, j] == tokens[:, p]).all()
        assert (striped_labels[:, j] == labels[:, p]).all()


def test_batch_spec_shapes_cover_frontends():
    for arch, key in [("whisper-base", "frames"), ("pixtral-12b", "patches")]:
        cfg = get_config(arch)
        shapes = batch_spec_shapes(cfg, 64, 2)
        assert key in shapes
        assert shapes["tokens"][0] == (2, 64)


@given(st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_eff_batch_axes_divisibility(pod, data):
    """The chosen batch-axis subset's size product always divides the batch."""
    import jax

    if pod * data > jax.device_count():
        # mesh construction needs real devices; emulate with math-only check
        return
    mesh = jax.make_mesh((pod, data), ("pod", "data"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("pod", "data"), sp_axis=None)
    for b in (1, 2, 3, 4, 6, 8, 12, 16):
        axes = ctx.eff_batch_axes(b)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        assert b % prod == 0


def test_pipeline_stages_reshape_and_errors():
    from repro.parallel.pipeline import pipeline_stages

    p = {"w": jnp.zeros((8, 3, 3))}
    staged = pipeline_stages(p, 4)
    assert staged["w"].shape == (4, 2, 3, 3)
    with pytest.raises(ValueError):
        pipeline_stages({"w": jnp.zeros((7, 3))}, 4)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    from repro.train import checkpoint as ckpt

    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_reduced_configs_preserve_family_features():
    """reduced() must keep the family-defining switches intact."""
    for arch in ("mixtral-8x7b", "qwen2-moe-a2.7b"):
        r = get_config(arch).reduced()
        assert r.moe is not None and r.moe.top_k >= 1
    assert get_config("mamba2-370m").reduced().ssm is not None
    h = get_config("hymba-1.5b").reduced()
    assert h.hybrid and h.ssm is not None and h.window
    assert get_config("minicpm3-4b").reduced().mla is not None
    w = get_config("whisper-base").reduced()
    assert w.encoder_layers > 0 and not w.mlp_gated and w.norm == "layernorm"
    assert get_config("pixtral-12b").reduced().num_patches > 0


def test_sharding_spec_rules():
    """Spec rules on an AbstractMesh (no devices needed): serve = row/col
    parallel over model; train = largest-dim FSDP; expert weights follow the
    EP/TP divisibility rule; the stacked layer dim is never sharded."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import abstract_mesh
    from repro.parallel import sharding as shd

    mesh = abstract_mesh((16, 16), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model")
    params = {
        "embed": jnp.zeros((4096, 512)),
        "layers": {
            "attn": {"wq": jnp.zeros((4, 512, 1024)), "wo": jnp.zeros((4, 1024, 512))},
            "moe": {
                "we1": jnp.zeros((4, 64, 512, 352)),  # E=64 % 16 == 0 -> EP
                "we2": jnp.zeros((4, 64, 352, 512)),
            },
        },
    }
    serve = shd.param_specs(params, ctx, "serve")
    assert serve["layers"]["attn"]["wq"] == P(None, None, "model")  # column
    assert serve["layers"]["attn"]["wo"] == P(None, "model", None)  # row
    assert serve["embed"] == P("model", None)
    train = shd.param_specs(params, ctx, "train")
    assert train["layers"]["attn"]["wq"][0] is None  # L never sharded
    assert train["layers"]["moe"]["we1"][1] == "model"  # EP expert dim
    # TP fallback when experts don't divide the axis (E=8 on 16)
    tp = shd.param_specs({"we1": jnp.zeros((4, 8, 512, 352))}, ctx, "train")
    assert tp["we1"][1] is None and tp["we1"][3] == "model"


def test_stripe_window_mask_composition():
    """Striped + sliding-window band == token-level windowed causal mask."""
    from repro.core.tiling import stripe_permutation, striped_causal_offset
    from repro.kernels.ref import band_mask

    n, m, W = 4, 8, 5
    S = n * m
    perm = stripe_permutation(S, n)
    for qc in range(n):
        for kc in range(n):
            got = np.asarray(
                band_mask(m, m, (qc, kc, 0, W - 1), stride_q=n, stride_kv=n)
            )
            qt = perm[qc * m : (qc + 1) * m]
            kt = perm[kc * m : (kc + 1) * m]
            want = (qt[:, None] >= kt[None, :]) & (qt[:, None] - kt[None, :] < W)
            assert (got == want).all(), (qc, kc)
