"""comm_overlap (serial | overlap | bidir) cost-model + accounting properties.

The bitwise equality of the three transports is checked on fake devices in
``repro.testing.dist_check overlap_exact`` (tests/test_distributed.py); here
we pin the single-process contracts:

  * overlapped step cost <= serial step cost, with equality exactly when the
    step's communication payload or its compute is zero;
  * bidir prices transfers at per-direction bandwidth (same bytes, smaller
    transfer time -> smaller scheduler Profile constants);
  * the three modes never share a plan-cache entry;
  * HLO collective-permute accounting: a bidirectional half-payload pair is
    one logical step's traffic (bytes summed, steps not double-counted).
"""

import dataclasses

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import am
from repro.core import schedule as S
from repro.core.dispatch import AttentionPlanConfig, _plan_key
from repro.core.mesh_attention import MeshAttentionConfig
from repro.core.simulator import CostModel, HardwareModel, make_cost_model, simulate
from repro.launch.hlo_analysis import collective_bytes


def _geom(n, a, seq_mult, hidden):
    comm = am.CommModel(seq=n * seq_mult, hidden=hidden, n=n, kv_hidden=hidden // 2)
    sched = S.greedy_forward_schedule(a, n // a)
    return comm, sched


@given(
    st.sampled_from([(4, 1), (4, 2), (8, 2), (8, 4), (16, 4)]),
    st.integers(1, 64),
    st.sampled_from([64, 256, 1024]),
)
@settings(max_examples=60, deadline=None)
def test_overlap_cost_never_exceeds_serial(na, seq_mult, hidden):
    """Per step, serial - overlap = min(payload, compute) >= 0; summed over
    the schedule the overlapped total can never exceed the serial total."""
    n, a = na
    comm, sched = _geom(n, a, seq_mult, hidden)
    hw = HardwareModel()
    cost = make_cost_model(comm, hw, comm_overlap="overlap")
    r_serial = simulate(sched, cost, comm, comm_overlap="serial")
    r_overlap = simulate(sched, cost, comm, comm_overlap="overlap")
    assert r_overlap.total <= r_serial.total + 1e-15
    assert r_overlap.exposed_comm <= r_serial.exposed_comm + 1e-15
    # same schedule, same cost model -> identical bytes and compute
    assert r_overlap.comm_bytes == r_serial.comm_bytes == comm.fwd_bytes(a)
    assert r_overlap.compute == r_serial.compute

    # bidir: same bytes move at per-direction bandwidth -> <= overlap
    cost_bi = make_cost_model(comm, hw, comm_overlap="bidir")
    r_bidir = simulate(sched, cost_bi, comm, comm_overlap="bidir")
    assert r_bidir.total <= r_overlap.total + 1e-15
    assert r_bidir.comm_bytes == r_overlap.comm_bytes


def test_overlap_equals_serial_iff_comm_or_compute_zero():
    """Equality holds exactly when every step's payload or compute is zero."""
    sched = S.greedy_forward_schedule(2, 2)
    zero_comm = {k: 0.0 for k in
                 (S.RECV_Q, S.RECV_KV, S.SEND_O, S.RECV_ODOQ, S.SEND_DQ, S.SEND_DKV)}

    # no communication time at all -> both modes are pure compute
    c = CostModel(t_block=1.0, t_chunk=zero_comm, block_flops=1.0, t_launch=0.0)
    assert (simulate(sched, c, comm_overlap="overlap").total
            == simulate(sched, c, comm_overlap="serial").total)

    # no compute time -> nothing can hide the payload, totals equal
    some_comm = {k: 2.0 for k in zero_comm}
    c = CostModel(t_block=0.0, t_chunk=some_comm, block_flops=0.0, t_launch=0.0)
    assert (simulate(sched, c, comm_overlap="overlap").total
            == simulate(sched, c, comm_overlap="serial").total)

    # both nonzero on at least one step -> overlap is STRICTLY cheaper
    c = CostModel(t_block=1.0, t_chunk=some_comm, block_flops=1.0, t_launch=0.0)
    assert any(s.comms and s.compute for s in sched.steps)
    assert (simulate(sched, c, comm_overlap="overlap").total
            < simulate(sched, c, comm_overlap="serial").total)


def test_launch_residual_is_never_hidden():
    """The per-step issue cost alpha stays on the critical path even when
    compute fully covers the payload."""
    sched = S.greedy_forward_schedule(2, 2)
    comm_steps = sum(1 for s in sched.steps if s.comms)
    t_chunk = {k: 0.5 for k in
               (S.RECV_Q, S.RECV_KV, S.SEND_O, S.RECV_ODOQ, S.SEND_DQ, S.SEND_DKV)}
    alpha = 0.25
    c = CostModel(t_block=100.0, t_chunk=t_chunk, block_flops=1.0, t_launch=alpha)
    r = simulate(sched, c, comm_overlap="overlap")
    # compute dominates every step; only the residual is exposed
    assert r.exposed_comm == pytest.approx(alpha * comm_steps)
    assert r.total == pytest.approx(r.compute + alpha * comm_steps)


def test_bidir_shrinks_profile_constants():
    """Per-direction bandwidth halves transfer time -> every scheduler
    Profile constant strictly shrinks (the greedy generator then co-schedules
    fewer blocks per transfer)."""
    comm = am.CommModel(seq=4096, hidden=512, n=8)
    hw = HardwareModel()
    p_over = make_cost_model(comm, hw, comm_overlap="overlap").profile()
    p_bi = make_cost_model(comm, hw, comm_overlap="bidir").profile()
    for f in dataclasses.fields(p_over):
        assert getattr(p_bi, f.name) < getattr(p_over, f.name)


def test_plan_cache_key_distinct_per_mode():
    """The three modes price steps differently, so tuned plans must never
    share a cache entry."""
    comm = am.CommModel(seq=4096, hidden=512, n=8)
    hw = HardwareModel()
    keys, descs = {}, {}
    for mode in S.COMM_OVERLAP_MODES:
        cfg = AttentionPlanConfig(backend="mesh", axis_name="sp", n=8, a=2,
                                  comm_overlap=mode)
        keys[mode], descs[mode] = _plan_key(cfg, comm, hw)
        assert descs[mode]["v"] == 5
        assert descs[mode]["comm_overlap"] == mode
    assert len(set(keys.values())) == 3


def test_invalid_mode_rejected_everywhere():
    comm = am.CommModel(seq=64, hidden=8, n=4)
    sched = S.greedy_forward_schedule(2, 2)
    cost = make_cost_model(comm)
    with pytest.raises(ValueError, match="comm_overlap"):
        S.validate_comm_overlap("sideways")
    with pytest.raises(ValueError, match="comm_overlap"):
        MeshAttentionConfig(axis_name="sp", n=4, a=2, comm_overlap="sideways")
    with pytest.raises(ValueError, match="comm_overlap"):
        AttentionPlanConfig(comm_overlap="sideways")
    with pytest.raises(ValueError, match="comm_overlap"):
        make_cost_model(comm, comm_overlap="sideways")
    with pytest.raises(ValueError, match="comm_overlap"):
        simulate(sched, cost, comm_overlap="sideways")


# --------------------------------------------------------------------------
# collective-permute accounting (satellite: pair = one logical step)
# --------------------------------------------------------------------------


def test_ppermute_pair_factor():
    assert am.ppermute_pair_factor("serial") == 1
    assert am.ppermute_pair_factor("overlap") == 1
    assert am.ppermute_pair_factor("bidir") == 2
    with pytest.raises(ValueError):
        am.ppermute_pair_factor("sideways")


def test_logical_ppermute_steps_collapses_pairs():
    assert am.logical_ppermute_steps(6, "overlap") == 6
    assert am.logical_ppermute_steps(6, "bidir") == 3
    with pytest.raises(ValueError, match="half-payload pairs"):
        am.logical_ppermute_steps(5, "bidir")


def test_collective_bytes_counts_and_pair_bytes_sum():
    """A bidir half-payload pair doubles the op count but its bytes sum to
    exactly one full hop; collapsing the count recovers the logical steps."""
    full = "  %p = f32[2,64,4,8]{3,2,1,0} collective-permute(%x), source_target_pairs={{0,1}}\n"
    half = ("  %pa = f32[2,64,4,4]{3,2,1,0} collective-permute(%x1), source_target_pairs={{0,1}}\n"
            "  %pb = f32[2,64,4,4]{3,2,1,0} collective-permute(%x2), source_target_pairs={{0,1}}\n")
    uni = collective_bytes("HloModule m\n" + full * 3)
    bi = collective_bytes("HloModule m\n" + half * 3)
    assert uni["collective-permute-count"] == 3
    assert bi["collective-permute-count"] == 6
    assert uni["collective-permute"] == bi["collective-permute"]  # bytes summed
    assert (am.logical_ppermute_steps(uni["collective-permute-count"], "overlap")
            == am.logical_ppermute_steps(bi["collective-permute-count"], "bidir")
            == 3)
