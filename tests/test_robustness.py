"""Fault-tolerant serving (ISSUE 10): oversubscribed admission with
preempt-and-recompute, request lifecycle states, the NaN logit guard, and
the deterministic chaos harness.

Allocator level: idempotent free/rollback, informative exhaustion errors,
oversubscription admission math, seize/restore, invariant sweeps.

Engine level: victim selection policy, preempt-and-recompute token identity
vs the conservative engine, shared-prefix donors surviving preemption,
the NaN guard retiring exactly one slot while other rows commit
bitwise-unchanged, cancel/deadline/reject terminal paths all freeing
pages, and a seeded churn property (random cancels + deadlines + pool
pressure across dense / paged / int8) asserting the pool AND scale tables
drain to zero with every ok stream equal to the fault-free oracle.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine, select_victim
from repro.serve.kv_pool import PageAllocator, PagedLayout, PoolExhausted
from repro.testing.chaos import ChaosConfig, ChaosInjector

CAP = 64
NEW = 8


def _alloc(num_pages=8, page_size=4, max_pages=8, n=1, **kw):
    return PageAllocator(PagedLayout(num_pages, page_size, max_pages, n), **kw)


# --------------------------------------------------------------------------
# allocator: idempotent free / rollback, informative errors, admission math
# --------------------------------------------------------------------------


def test_free_slot_idempotent():
    a = _alloc()
    a.alloc_slot(0, np.arange(6, dtype=np.int32), 4)
    assert a.pages_in_use == 2
    assert len(a.free_slot(0)) == 2  # both refs hit zero
    assert a.pages_in_use == 0
    # double free: no-op + counter, refcounts untouched
    assert a.free_slot(0) == []
    assert a.free_slot(0) == []
    assert a.double_free_noops == 2
    assert a.pages_in_use == 0 and (a.ref == 0).all()
    assert a.check_invariants() == []


def test_rollback_idempotent():
    a = _alloc()
    a.alloc_slot(0, np.arange(4, dtype=np.int32), 8)
    a.ensure_append(0, 4)
    assert a.slot_pages(0) == 2
    assert a.rollback(0, 4) == 1  # drop the speculative page
    noops = a.double_free_noops
    a.free_slot(0)
    assert a.rollback(0, 4) == 0  # rolled-back slot: idempotent no-op
    assert a.double_free_noops == noops + 1
    assert a.check_invariants() == []


def test_pool_exhausted_message_reports_occupancy():
    a = _alloc(num_pages=2, oversubscribe=2.0)
    a.alloc_slot(0, np.arange(8, dtype=np.int32), 0)  # 2 pages: pool full
    with pytest.raises(PoolExhausted) as ei:
        a.alloc_slot(1, np.arange(100, 104, dtype=np.int32), 0)
    msg = str(ei.value)
    for needle in ("2/2", "2 reserved", "virtual capacity of 4",
                   "oversubscribe=2.0", "free list empty"):
        assert needle in msg, (needle, msg)


def test_alloc_slot_unwinds_atomically_on_mid_prompt_exhaustion():
    a = _alloc(num_pages=3, oversubscribe=4.0)
    a.alloc_slot(0, np.arange(8, dtype=np.int32), 0)  # 2 of 3 pages
    with pytest.raises(PoolExhausted):
        a.alloc_slot(1, np.arange(200, 212, dtype=np.int32), 0)  # needs 3
    # the partial page grabbed before exhaustion was handed back
    assert a.slot_pages(1) == 0 and a.pages_in_use == 2
    assert (a.block_table[1] == PageAllocator.FREE).all()
    assert a.check_invariants() == []


def test_oversubscribe_admission_math():
    # conservative: lifetime pages must fit the physical pool
    a = _alloc(num_pages=4)
    assert a.can_admit(8, 8)  # 4 pages
    assert not a.can_admit(8, 12)  # 5 pages > 4
    # oversubscribed: lifetime books against virtual capacity, only prompt
    # pages + margin must fit physically
    b = _alloc(num_pages=4, oversubscribe=2.0)
    assert b.virtual_pages == 8
    assert b.can_admit(8, 12)  # 5 <= 8 virtual; 2 prompt + 1 margin <= 4
    assert not b.can_admit(8, 28)  # 9 lifetime > 8 virtual
    assert not b.can_admit(16, 0)  # 4 prompt + 1 margin > 4 physical
    b.alloc_slot(0, np.arange(8, dtype=np.int32), 12)
    assert b.pages_reserved == 5
    assert not b.can_admit(8, 12)  # 5 + 5 > 8 virtual
    # rejection: could never fit even an empty pool
    assert b.never_admittable(8, 60)  # 17 lifetime > 8 virtual
    assert b.never_admittable(20, 0)  # 5 prompt pages > 4 physical
    assert not b.never_admittable(8, 12)


def test_seize_restore_and_invariants():
    a = _alloc(num_pages=6)
    a.alloc_slot(0, np.arange(8, dtype=np.int32), 0)
    taken = a.seize_pages(3)
    assert len(taken) == 3 and a.stats()["seized_pages"] == 3
    assert a.check_invariants() == []  # conservation holds mid-squeeze
    with pytest.raises(PoolExhausted):
        a.alloc_slot(1, np.arange(300, 308, dtype=np.int32), 0)  # 1 free < 2
    a.restore_pages(taken)
    a.alloc_slot(1, np.arange(300, 308, dtype=np.int32), 0)
    a.free_slot(0), a.free_slot(1)
    assert a.pages_in_use == 0 and a.check_invariants() == []


def test_invariant_sweep_catches_corruption():
    a = _alloc()
    a.alloc_slot(0, np.arange(6, dtype=np.int32), 2)
    a.ref[int(a.block_table[0, 0])] += 1  # simulate a refcount leak
    assert any("ref" in p for p in a.check_invariants())


# --------------------------------------------------------------------------
# victim selection policy
# --------------------------------------------------------------------------


class _FakeReq:
    def __init__(self, rid, admit_tick):
        self.rid, self.admit_tick = rid, admit_tick


def test_select_victim_prefers_young_non_donors():
    a = _alloc(num_pages=16, max_pages=8)
    prefix = np.arange(8, dtype=np.int32)
    a.alloc_slot(0, prefix, 4)  # donor: slot 1 shares its pages
    a.alloc_slot(1, prefix, 4)
    a.alloc_slot(2, np.arange(100, 108, dtype=np.int32), 4)  # private
    slots = [_FakeReq(0, 0), _FakeReq(1, 5), _FakeReq(2, 3)]
    # youngest non-sharing slot loses first... but 0 and 1 SHARE pages, so
    # private slot 2 is preferred despite being older than slot 1
    assert select_victim(slots, a) == 2
    # among sharers only: youngest admit_tick first
    a.free_slot(2)
    slots[2] = None
    assert select_victim(slots, a) == 1
    # protection wins over policy
    assert select_victim(slots, a, protect={1}) == 0
    # nothing evictable
    assert select_victim(slots, a, protect={0, 1}) is None


def test_select_victim_skips_pageless_slots():
    a = _alloc()
    slots = [_FakeReq(0, 0), None]
    assert select_victim(slots, a) is None  # active but holds no pages yet


# --------------------------------------------------------------------------
# engine: preemption, NaN guard, lifecycle (shared module fixture)
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _mk(cfg, params, chaos=None, **kw):
    return ServeEngine(cfg, params, serve=ServeConfig(
        max_seq=CAP, num_slots=3, **kw), chaos=chaos)


def _run(eng, prompts, new_tokens=NEW, deadlines=None, cancels=None):
    rids = [
        eng.submit(p, new_tokens,
                   deadline_ticks=None if deadlines is None else deadlines[i])
        for i, p in enumerate(prompts)
    ]
    cancels = cancels or {}
    while eng.has_work:
        for idx in cancels.get(eng._tick, []):
            eng.cancel(rids[idx])
        eng.step()
    return [eng._finished[r] for r in rids]


_PRESSURE = dict(paged=True, page_size=4, num_pages=13, prefill_chunk=8,
                 oversubscribe=2.0)


def test_preempt_recompute_token_identity(granite):
    cfg, params = granite
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
               for _ in range(3)]
    ref = _run(_mk(cfg, params, paged=True, page_size=4, num_pages=24,
                   prefill_chunk=8), prompts, 12)
    eng = _mk(cfg, params, health_every=1, **_PRESSURE)
    got = _run(eng, prompts, 12)
    assert eng.preemptions > 0, "13-page pool drove no preemption"
    for r, g in zip(ref, got):
        assert g.status == "ok"
        assert g.generated == r.generated
        assert (g.preemptions > 0) == (g.recompute_tokens > 0)
    assert eng.allocator.pages_in_use == 0
    assert sum(g.preemptions for g in got) == eng.preemptions


def test_shared_prefix_donor_preemption_safe(granite):
    """Preempting a prefix DONOR must not strip the sharer's committed
    pages: refcounts keep them resident, and both streams stay identical
    to the pressure-free run."""
    cfg, params = granite
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, (12,), dtype=np.int32)
    prompts = [
        np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)]),
        np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)]),
        rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32),
    ]
    ref = _run(_mk(cfg, params, paged=True, page_size=4, num_pages=24,
                   prefill_chunk=8), prompts, 12)
    eng = _mk(cfg, params, health_every=1, **_PRESSURE)
    got = _run(eng, prompts, 12)
    assert eng.allocator.stats()["shared_hits"] >= 1
    for r, g in zip(ref, got):
        assert g.status == "ok" and g.generated == r.generated
    assert eng.allocator.pages_in_use == 0


def test_nan_guard_isolates_one_slot(granite):
    """Poisoning one decoding slot's cache retires only that request
    (status numeric_error); every other slot's stream is bitwise-unchanged
    (batch rows are independent)."""
    cfg, params = granite
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
               for _ in range(3)]
    clean = _run(_mk(cfg, params, paged=True, page_size=4, prefill_chunk=8),
                 prompts)
    eng = _mk(cfg, params, paged=True, page_size=4, prefill_chunk=8)
    rids = [eng.submit(p, NEW) for p in prompts]
    poisoned = False
    while eng.has_work:
        if not poisoned and eng.scheduler.slots[1] is not None \
                and eng.scheduler.slots[1].generated:
            eng.poison_slot_cache(1)
            poisoned = True
        eng.step()
    got = [eng._finished[r] for r in rids]
    statuses = [g.status for g in got]
    assert statuses.count("numeric_error") == 1, statuses
    assert eng.numeric_errors == 1
    for c, g in zip(clean, got):
        if g.status == "ok":
            assert g.generated == c.generated
    assert eng.allocator.pages_in_use == 0
    eng.health()


def test_nan_guard_dense(granite):
    cfg, params = granite
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
               for _ in range(2)]
    eng = _mk(cfg, params)
    rids = [eng.submit(p, NEW) for p in prompts]
    eng.step()  # prefill + first decode
    eng.poison_slot_cache(0)
    while eng.has_work:
        eng.step()
    got = [eng._finished[r] for r in rids]
    assert got[0].status == "numeric_error"
    assert got[1].status == "ok" and len(got[1].generated) == NEW


def test_cancel_deadline_reject_free_everything(granite):
    cfg, params = granite
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
               for _ in range(4)]
    prompts.append(rng.integers(0, cfg.vocab_size, (40,), dtype=np.int32))
    eng = _mk(cfg, params, paged=True, page_size=4, num_pages=8,
              prefill_chunk=8, oversubscribe=2.0)
    # rid 4's 40-token prompt (10 pages) can NEVER fit 8 physical pages
    got = _run(eng, prompts, 6,
               deadlines=[None, None, 2, None, None],
               cancels={1: [1]})
    statuses = [g.status for g in got]
    assert statuses[1] == "cancelled"
    assert statuses[4] == "rejected" and got[4].generated == []
    assert "deadline" in statuses
    assert eng.cancelled == 1 and eng.rejected_requests == 1
    assert eng.deadline_expired >= 1
    assert eng.allocator.pages_in_use == 0 and eng.allocator.pages_reserved == 0
    eng.health()


def test_cancel_unknown_rid_returns_none(granite):
    cfg, params = granite
    eng = _mk(cfg, params)
    assert eng.cancel(12345) is None


def test_chaos_trace_is_deterministic(granite):
    cfg, params = granite
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
               for _ in range(4)]
    cc = ChaosConfig(seed=6, ticks=16, squeezes=2, squeeze_frac=0.5,
                     squeeze_hold=3, nan_ticks=1, drop_ticks=1)
    outs = []
    for _ in range(2):
        inj = ChaosInjector(cc)
        eng = _mk(cfg, params, chaos=inj, health_every=2, **_PRESSURE)
        got = _run(eng, prompts, 10)
        assert eng.allocator.pages_in_use == 0
        outs.append((inj.events, [(g.status, g.generated) for g in got]))
    assert outs[0] == outs[1]
    assert outs[0][0], "seeded trace injected nothing"


# --------------------------------------------------------------------------
# churn property: random cancels/deadlines under pressure, all modes
# --------------------------------------------------------------------------

_MODES = {
    "dense": dict(prefill_chunk=8),
    "paged": dict(paged=True, page_size=4, num_pages=13, prefill_chunk=8,
                  oversubscribe=2.0),
    "int8": dict(paged=True, page_size=4, num_pages=13, prefill_chunk=8,
                 oversubscribe=2.0, kv_dtype="int8"),
}
_ENGINES = {}  # (mode) -> reused engine: jit traces warm across examples
_ORACLES = {}  # (mode, prompt bytes) -> fault-free stream


def _churn_engine(granite, mode):
    if mode not in _ENGINES:
        cfg, params = granite
        _ENGINES[mode] = _mk(cfg, params, health_every=4, **_MODES[mode])
    return _ENGINES[mode]


def _oracle_stream(granite, mode, prompt):
    key = (mode, prompt.tobytes())
    if key not in _ORACLES:
        cfg, params = granite
        okey = "oracle-" + mode
        if okey not in _ENGINES:
            kw = dict(_MODES[mode], oversubscribe=1.0)  # roomy, fault-free
            if kw.get("paged"):
                kw["num_pages"] = 32
            kw.pop("oversubscribe")
            _ENGINES[okey] = _mk(cfg, params, **kw)
        res = _run(_ENGINES[okey], [prompt], NEW)
        _ORACLES[key] = res[0].generated
    return _ORACLES[key]


@pytest.mark.parametrize("mode", sorted(_MODES))
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_churn_drains_and_ok_streams_match_oracle(granite, mode, seed):
    cfg, params = granite
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(2, 5))
    prompts = [
        rng.integers(0, cfg.vocab_size, (int(rng.integers(6, 20)),),
                     dtype=np.int32)
        for _ in range(n_req)
    ]
    deadlines = [
        int(rng.integers(4, 14)) if rng.random() < 0.3 else None
        for _ in range(n_req)
    ]
    cancels = {}
    for i in range(n_req):
        if rng.random() < 0.3:
            cancels.setdefault(int(rng.integers(1, 10)), []).append(i)
    eng = _churn_engine(granite, mode)
    base = eng._tick
    rids = [
        eng.submit(p, NEW, arrival_tick=base, deadline_ticks=deadlines[i])
        for i, p in enumerate(prompts)
    ]
    while eng.has_work:
        for idx in cancels.get(eng._tick - base, []):
            eng.cancel(rids[idx])
        eng.step()
    got = [eng._finished[r] for r in rids]
    # terminal states are the documented set; every path freed its pages
    assert {g.status for g in got} <= {
        "ok", "cancelled", "deadline", "numeric_error", "rejected"
    }
    if eng.allocator is not None:
        assert eng.allocator.pages_in_use == 0
        assert eng.allocator.pages_reserved == 0
        assert eng.allocator.scale_entries_in_use == 0
    eng.health()
    for g, p in zip(got, prompts):
        if g.status == "ok":
            assert g.generated == _oracle_stream(granite, mode, p), (mode, seed)
