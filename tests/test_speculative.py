"""Speculative multi-token decode: spec engine == vanilla greedy, exactly.

The whole design contract of ``ServeConfig.spec_k`` is that speculation is a
THROUGHPUT knob, never a sampling change: greedy accept/reject commits the
longest drafted prefix that matches the model's own argmax, so every token
stream must be byte-identical to the one-token-per-tick engine — dense and
paged, with page-level rollback reclaiming rejected pages and prefix sharers
never observing uncommitted speculative writes.  This file pins that
property (hypothesis over prompts/lengths), the proposer, the allocator's
``ensure_span``/``rollback`` surface, zero page leaks, the per-request
accounting (``spec_proposed``/``spec_accepted``/multi-token ``token_ticks``)
and the ``ServeConfig`` validation rows this PR adds.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.kv_pool import PageAllocator, PagedLayout
from repro.serve.speculative import propose_ngram

MAX_SEQ = 64
BUCKET = 32  # every prompt pads to one prefill shape: one compile per engine


@pytest.fixture(scope="module")
def granite():
    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _engine(cfg, params, **kw):
    serve = ServeConfig(
        max_seq=MAX_SEQ, num_slots=2, prefill_buckets=(BUCKET,), **kw
    )
    return ServeEngine(cfg, params, serve=serve)


@pytest.fixture(scope="module")
def engines(granite):
    """Long-lived engines reused across hypothesis examples: a fresh
    ServeEngine re-jits every launch (~seconds each), and all launches here
    are fixed-shape, so reuse is free and sound."""
    cfg, params = granite
    return {
        "vanilla": _engine(cfg, params),
        "spec": _engine(cfg, params, spec_k=4, spec_max_misses=None),
        "spec_paged": _engine(
            cfg, params, spec_k=4, spec_max_misses=None, paged=True, page_size=4
        ),
    }


def _run(eng, prompts, mnt):
    rids = [eng.submit(np.asarray(p, np.int32), max_new_tokens=mnt) for p in prompts]
    fin = eng.run()
    return [fin[r] for r in rids]


# ---------------------------------------------------------------------------
# proposer


def test_propose_ngram_predicts_loop():
    # history ends ... 1 2 3 1 2 3; suffix trigram (1,2,3) recurs -> the
    # continuation after the most recent match predicts the loop (clipped
    # at history end, never padded)
    assert propose_ngram([9, 1, 2, 3, 1, 2], [3], 4) == [1, 2, 3]


def test_propose_ngram_recency_wins():
    # (5,) occurs twice with different continuations; the most recent one
    # (-> 8) must win over the stale prompt match (-> 7)
    assert propose_ngram([5, 7, 5, 8], [5], 1) == [8]


def test_propose_ngram_no_repeat_is_empty():
    assert propose_ngram([1, 2, 3, 4, 5], [6], 4) == []


def test_propose_ngram_degenerate():
    assert propose_ngram([1, 2, 1], [], 0) == []
    assert propose_ngram([7], [], 4) == []  # size-1 history: nothing earlier


def test_propose_ngram_truncates_at_history_end():
    # match lands 2 tokens before the end: draft is clipped, not padded
    assert propose_ngram([1, 2, 9, 9, 1], [2], 8) == [9, 9, 1, 2]


# ---------------------------------------------------------------------------
# config validation


@pytest.mark.parametrize(
    "kw",
    [
        {"spec_k": 1},
        {"spec_k": -1},
        {"spec_k": 4, "spec_draft": "medusa"},
        {"spec_k": 4, "spec_max_misses": 0},
    ],
)
def test_serve_config_rejects_bad_spec_knobs(kw):
    with pytest.raises(ValueError):
        ServeConfig(max_seq=32, **kw)


def test_spec_requires_attention_only_arch(granite):
    cfg, params = granite
    import dataclasses

    ssm_cfg = dataclasses.replace(cfg, ssm=object())
    with pytest.raises(ValueError, match="attention-only"):
        ServeEngine(
            ssm_cfg, params, serve=ServeConfig(max_seq=32, spec_k=4)
        )


# ---------------------------------------------------------------------------
# allocator: ensure_span + rollback


def _layout():
    # chunk == page_size (n=1): 4 tokens per logical page, 12-page pool
    return PagedLayout(num_pages=12, page_size=4, max_pages=6, n=1)


def test_ensure_span_allocates_every_page_in_span():
    alloc = PageAllocator(_layout())
    alloc.alloc_slot(0, np.arange(5, dtype=np.int32), 16)
    base = alloc.slot_pages(0)
    alloc.ensure_span(0, 5, 8)  # positions 5..12 -> pages up through idx 3
    assert alloc.slot_pages(0) == max(base, alloc.layout.pages_for(13))
    alloc.free_slot(0)
    assert alloc.pages_in_use == 0


def test_rollback_frees_only_past_keep_len():
    alloc = PageAllocator(_layout())
    alloc.alloc_slot(0, np.arange(4, dtype=np.int32), 20)
    alloc.ensure_span(0, 4, 12)  # grow to cover positions through 15
    grown = alloc.slot_pages(0)
    assert grown == alloc.layout.pages_for(16)
    freed = alloc.rollback(0, 6)  # keep 6 tokens -> 2 pages
    assert freed == grown - alloc.layout.pages_for(6)
    assert alloc.slot_pages(0) == alloc.layout.pages_for(6)
    assert alloc.stats()["spec_rolled_back_pages"] == freed
    # rollback inside the kept page is a no-op
    assert alloc.rollback(0, 5) == 0
    alloc.free_slot(0)
    assert alloc.pages_in_use == 0


def test_rollback_never_touches_shared_prefix_pages():
    alloc = PageAllocator(_layout())
    prompt = np.arange(8, dtype=np.int32)
    alloc.alloc_slot(0, prompt, 8)
    shared = alloc.alloc_slot(1, prompt, 8).shared_len
    assert shared == 8 and alloc.shared_hits > 0
    donor_prompt_pages = list(alloc.block_table[0, : alloc.layout.pages_for(8)])
    alloc.ensure_span(0, 8, 8)  # donor speculates past its prompt
    alloc.rollback(0, 8)  # ...then rejects everything
    # the sharer still maps the same physical prompt pages, untouched
    assert list(alloc.block_table[1, : alloc.layout.pages_for(8)]) == donor_prompt_pages
    alloc.free_slot(0)
    alloc.free_slot(1)
    assert alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# engine: speculative == vanilla, token for token


def _make_trace(base, reps, p1, mnt):
    """Two prompts + a length; p0 skewed toward repetition so drafts
    actually get accepted, p1 random so rejection paths run too."""
    p0 = (base * (reps + 1))[: BUCKET - 1]
    return [p0, p1], mnt


_trace = st.builds(
    _make_trace,
    st.lists(st.integers(0, 5), min_size=2, max_size=6),
    st.integers(1, 4),
    st.lists(st.integers(0, 400), min_size=4, max_size=BUCKET - 1),
    st.integers(4, 20),
)


@given(_trace)
@settings(max_examples=10, deadline=None)
def test_spec_identical_to_vanilla_dense_and_paged(engines, trace):
    prompts, mnt = trace
    ref = [r.generated for r in _run(engines["vanilla"], prompts, mnt)]
    for name in ("spec", "spec_paged"):
        out = _run(engines[name], prompts, mnt)
        assert [r.generated for r in out] == ref, name
    # rollback never leaks: the pool drains fully between examples
    assert engines["spec_paged"].allocator.pages_in_use == 0


def test_spec_identical_under_miss_suspension(granite):
    """spec_max_misses is a COST policy: suspending/probing drafting must
    not change a single token, even at the most aggressive setting."""
    cfg, params = granite
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 400, (24,), dtype=np.int32) for _ in range(2)]
    ref = [r.generated for r in _run(_engine(cfg, params), prompts, 24)]
    eng = _engine(cfg, params, spec_k=4, spec_max_misses=1)
    assert [r.generated for r in _run(eng, prompts, 24)] == ref


def test_spec_eos_mid_commit(granite):
    """EOS can land in the middle of a multi-token commit: the stream must
    truncate exactly where vanilla decode truncates, and later drafted
    tokens must be discarded."""
    cfg, params = granite
    prompt = np.full((12,), 7, np.int32)
    probe = _run(_engine(cfg, params), [prompt], 12)[0].generated
    eos = probe[len(probe) // 2]  # a token vanilla actually emits mid-stream
    ref = _run(_engine(cfg, params, eos_id=eos), [prompt], 12)[0].generated
    eng = _engine(cfg, params, spec_k=4, spec_max_misses=None, eos_id=eos)
    assert _run(eng, [prompt], 12)[0].generated == ref


# ---------------------------------------------------------------------------
# accounting: counters, stats, multi-token ticks


def test_spec_counters_and_multi_token_ticks(granite):
    """A looping prompt must actually accept drafts: >1 token in some tick,
    with token_ticks stamped per token (len match, non-decreasing) and the
    per-request / engine-wide counters agreeing."""
    cfg, params = granite
    eng = _engine(cfg, params, spec_k=4, spec_max_misses=None, paged=True,
                  page_size=4)
    res = _run(eng, [np.full((16,), 7, np.int32)], 16)[0]
    assert len(res.token_ticks) == len(res.generated) == 16
    assert list(res.token_ticks) == sorted(res.token_ticks)
    ticks, counts = np.unique(res.token_ticks, return_counts=True)
    assert counts.max() > 1, "no tick committed multiple tokens"
    assert res.spec_proposed > 0
    assert 0 < res.spec_accepted <= res.spec_proposed
    assert eng.spec_proposed == res.spec_proposed
    assert eng.spec_accepted == res.spec_accepted
    assert eng.verify_trace_count == 1  # ONE fixed-shape verify compile
    stats = eng.kv_cache_stats()
    assert stats["spec_proposed"] == float(res.spec_proposed)
    assert stats["spec_accepted"] == float(res.spec_accepted)
    assert stats["spec_accept_rate"] == pytest.approx(
        res.spec_accepted / res.spec_proposed
    )
    assert stats["verify_launches"] >= 1.0
    assert "spec_rolled_back_pages" in stats
    assert eng.allocator.pages_in_use == 0


def test_vanilla_stats_report_zero_spec(granite):
    cfg, params = granite
    eng = _engine(cfg, params)
    _run(eng, [np.arange(8, dtype=np.int32)], 4)
    stats = eng.kv_cache_stats()
    assert stats["spec_proposed"] == 0.0
    assert stats["spec_accept_rate"] == 0.0
    assert stats["verify_launches"] == 0.0


def test_shared_prefix_sharer_never_sees_speculative_pages(granite):
    """A prefix sharer admitted WHILE its donor is speculating must decode
    from committed state only: same tokens as a solo run, and its shared
    pages must be exactly the donor's prompt pages (never a rolled-back
    speculative page)."""
    cfg, params = granite
    prompt = np.full((16,), 7, np.int32)  # loops -> donor speculates hard
    solo = _run(
        _engine(cfg, params, paged=True, page_size=4), [prompt], 12
    )[0].generated

    eng = _engine(cfg, params, spec_k=4, spec_max_misses=None, paged=True,
                  page_size=4)
    r0 = eng.submit(prompt, max_new_tokens=12, arrival_tick=0)
    r1 = eng.submit(prompt, max_new_tokens=12, arrival_tick=3)  # mid-spec
    fin = eng.run()
    assert fin[r0].generated == solo
    assert fin[r1].generated == solo
    assert eng.allocator.shared_hits > 0, "sharer did not share the prefix"
    assert eng.spec_accepted > 0, "donor never speculated"
    assert eng.allocator.pages_in_use == 0
