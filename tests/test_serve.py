"""Continuous-batching serve stack: scheduler logic, slot-pool engine, and
the vectorized-position decode path (single device; the multi-device trace
replay goes through dist_check in tests/test_distributed.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.parallel.context import ParallelCtx
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Scheduler, default_buckets

CTX = ParallelCtx()


# --------------------------------------------------------------------------
# scheduler (pure python)
# --------------------------------------------------------------------------


def test_default_buckets_ladder():
    assert default_buckets(128, 1) == (16, 32, 64, 128)
    assert default_buckets(128, 8) == (16, 32, 64, 128)
    # every bucket a multiple of n; cap always present
    bs = default_buckets(96, 8)
    assert all(b % 8 == 0 for b in bs) and bs[-1] == 96


def test_scheduler_admission_fifo_and_retire():
    s = Scheduler(2, (16, 32), 64)
    r0 = s.submit(np.arange(8), 4, arrival_tick=0)
    r1 = s.submit(np.arange(16), 4, arrival_tick=0)
    r2 = s.submit(np.arange(8), 4, arrival_tick=1)
    # tick 0: two free slots, FIFO among arrived requests; r2 not arrived yet
    assigned = s.admit(0)
    assert [(sl, r.rid) for sl, r in assigned] == [(0, r0.rid), (1, r1.rid)]
    assert s.admit(0) == [] and s.pending == 1
    # r2 arrives but no slot is free until one retires
    assert s.admit(1) == []
    done = s.retire(0, tick=3)
    assert done.rid == r0.rid and done.finish_tick == 3
    assigned = s.admit(4)
    assert [(sl, r.rid) for sl, r in assigned] == [(0, r2.rid)]
    assert s.active_slots() == [0, 1] and not s.pending
    s.retire(1, tick=5)
    with pytest.raises(ValueError):
        s.retire(1, tick=5)  # already free


def test_scheduler_bucketing_and_validation():
    s = Scheduler(1, (16, 32), 48)
    assert s.bucket_for(1) == 16
    assert s.bucket_for(16) == 16
    assert s.bucket_for(17) == 32
    with pytest.raises(ValueError):
        s.submit(np.arange(40), 16)  # 40 + 16 > 48
    with pytest.raises(ValueError):
        s.submit(np.arange(8), 0)
    with pytest.raises(ValueError):
        s.bucket_for(33)  # no bucket can hold it
    exact = Scheduler(1, (16,), 64, exact=True)
    assert exact.bucket_for(13) == 13  # SSM archs: no pad-correction
    # exact mode cannot pad its way to sp divisibility (hybrid archs still
    # shard attention prefill) -> reject at admission, not deep inside jit
    exact_sp = Scheduler(1, (16,), 64, exact=True, multiple=4)
    assert exact_sp.bucket_for(16) == 16
    with pytest.raises(ValueError):
        exact_sp.bucket_for(17)
    # the SSD chunked scan: per-device length must be <= or a multiple of chunk
    exact_chunk = Scheduler(1, (16,), 64, exact=True, chunk=8)
    assert exact_chunk.bucket_for(6) == 6
    assert exact_chunk.bucket_for(16) == 16
    with pytest.raises(ValueError):
        exact_chunk.bucket_for(12)


def test_pack_groups_binpack_toward_bucket_boundaries():
    """The bin-packing planner sorts by length and packs toward bucket
    boundaries: a 9+8+16 burst lands as a boundary-snug 16+9 row plus a
    padding-free 8 (48 padded tokens) where greedy crams one 64-bucket row."""
    from repro.serve.scheduler import Request

    def reqs(lengths):
        return [
            (slot, Request(slot, np.zeros(ln, np.int32), 4))
            for slot, ln in enumerate(lengths)
        ]

    s = Scheduler(8, (16, 32, 64), 128)

    def cost(groups):
        return sum(s.bucket_for(sum(len(r.prompt) for _, r in g)) for g in groups)

    burst = reqs((9, 8, 16))
    packed = s.pack_groups(burst, pack_max=4, plan="binpack")
    greedy = s.pack_groups(burst, pack_max=4, plan="greedy")
    assert cost(greedy) == 64  # 33 real tokens crammed into one 64 row
    assert cost(packed) == 48, [
        [len(r.prompt) for _, r in g] for g in packed
    ]
    assert sorted(sum(len(r.prompt) for _, r in g) for g in packed) == [8, 25]
    # every admitted slot appears exactly once in the plan
    assert sorted(sl for g in packed for sl, _ in g) == [0, 1, 2]

    # dense bursts that fit one bucket row beat any split: binpack keeps the
    # greedy plan as a candidate, so it is NEVER costlier than greedy
    for lengths in ((9, 16, 8, 30), (17, 16), (31, 2, 31, 2), (16, 16, 16)):
        p = s.pack_groups(reqs(lengths), pack_max=4, plan="binpack")
        g = s.pack_groups(reqs(lengths), pack_max=4, plan="greedy")
        assert cost(p) <= cost(g), (lengths, cost(p), cost(g))
        assert sorted(sl for grp in p for sl, _ in grp) == list(range(len(lengths)))
    with pytest.raises(ValueError):
        s.pack_groups(burst, pack_max=4, plan="nope")


# --------------------------------------------------------------------------
# engine: slot pool, cache ownership, retrace bounds
# --------------------------------------------------------------------------


def _engine(**kw):
    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    kw.setdefault("max_seq", 64)
    kw.setdefault("num_slots", 2)
    return cfg, params, ServeEngine(cfg, params, **kw)


def test_cache_allocated_once_across_generates(monkeypatch):
    """The slot-pool cache is allocated in __init__ and reused: a second
    generate() call must not allocate (or trace) anything new."""
    calls = {"n": 0}
    orig = tfm.init_cache

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(tfm, "init_cache", counting)
    cfg, params, eng = _engine()
    assert calls["n"] == 1  # the pool, eagerly, at construction
    prompts = (np.arange(16, dtype=np.int32).reshape(2, 8) * 5) % cfg.vocab_size
    out1 = eng.generate(prompts, max_new_tokens=4)
    after_first = calls["n"]  # +1 per bucket TRACE (inside jit), not per call
    out2 = eng.generate(prompts, max_new_tokens=4)
    assert calls["n"] == after_first, "second generate re-allocated the cache"
    np.testing.assert_array_equal(out1, out2)
    assert eng.decode_trace_count == 1


def test_retrace_bounded_by_buckets():
    """Retraces are a function of (bucket, pack-size) pairs, not the actual
    prompt-length mix: packed prefill keys are (bucket, k) and a fresh
    composition hitting the same keys must not trace anything new."""
    cfg, params, eng = _engine(num_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    lengths = [8, 16, 9, 30, 31, 12]
    for i, ln in enumerate(lengths):
        eng.submit(
            rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32),
            max_new_tokens=3,
            arrival_tick=i // 3,
        )
    eng.run()
    buckets = set(eng.scheduler.buckets)
    assert all(b in buckets and 1 <= k <= eng.pack_max
               for b, k in eng.prefill_trace_counts)
    assert all(v == 1 for v in eng.prefill_trace_counts.values())
    assert eng.decode_trace_count == 1
    keys_before = set(eng.prefill_trace_counts)
    # a fresh composition mapping to already-traced (bucket, k) keys: the
    # first batch's tick-0 pair packed into (32, 2), so 10+20 does too
    for ln in (10, 20):
        eng.submit(rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32), 3)
    eng.run()
    assert set(eng.prefill_trace_counts) == keys_before
    assert all(v == 1 for v in eng.prefill_trace_counts.values())
    assert eng.decode_trace_count == 1


def test_packed_prefill_matches_sequential():
    """Same-tick admissions pack into ONE prefill row under a document mask;
    every request's tokens must equal sequential single-request generation,
    and the un-packed engine must agree token-for-token too."""
    cfg, params, eng = _engine(num_slots=3, max_seq=128)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln in (16, 8, 8)]
    rids = [eng.submit(p, max_new_tokens=4, arrival_tick=0) for p in prompts]
    finished = eng.run()
    # the three same-tick prompts shared one packed (bucket=32, k=3) prefill
    assert eng.prefill_trace_counts == {(32, 3): 1}, eng.prefill_trace_counts
    seq_eng = ServeEngine(cfg, params, max_seq=128, num_slots=1)
    nopack = ServeEngine(cfg, params, max_seq=128, num_slots=3, pack_prefill=False)
    rids_np = [nopack.submit(p, max_new_tokens=4, arrival_tick=0) for p in prompts]
    fin_np = nopack.run()
    assert all(isinstance(key, int) for key in nopack.prefill_trace_counts)
    for rid, rid_np, p in zip(rids, rids_np, prompts):
        ref = seq_eng.generate(p[None, :], max_new_tokens=4)[0].tolist()
        assert finished[rid].generated == ref, (finished[rid].generated, ref)
        assert fin_np[rid_np].generated == ref


def test_continuous_matches_sequential():
    """A mixed-length arrival trace (slots at different depths per tick,
    padded prefill buckets) must reproduce sequential single-request
    generation token-for-token."""
    cfg, params, eng = _engine(num_slots=2, max_seq=64)
    rng = np.random.default_rng(7)
    trace = [(8, 0), (16, 0), (12, 2), (8, 3)]
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln, _ in trace]
    rids = [
        eng.submit(p, max_new_tokens=5, arrival_tick=t)
        for p, (_, t) in zip(prompts, trace)
    ]
    finished = eng.run()
    seq_eng = ServeEngine(cfg, params, max_seq=64, num_slots=1)
    for rid, p in zip(rids, prompts):
        ref = seq_eng.generate(p[None, :], max_new_tokens=5)
        assert finished[rid].generated == ref[0].tolist(), rid


def test_continuous_matches_sequential_hybrid():
    """SSM/hybrid archs serve through the exact-prefill path (no padding:
    the recurrent state has no pad-correction) and must still reproduce
    sequential generation."""
    cfg = get_config("hymba-1.5b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(4))
    eng = ServeEngine(cfg, params, max_seq=64, num_slots=2)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln in (8, 16)]
    rids = [eng.submit(p, max_new_tokens=4, arrival_tick=t) for p, t in zip(prompts, (0, 1))]
    with pytest.raises(ValueError):  # 12 > chunk=8 and not a multiple of it
        eng.submit(rng.integers(0, cfg.vocab_size, (12,), dtype=np.int32), 4)
    finished = eng.run()
    seq_eng = ServeEngine(cfg, params, max_seq=64, num_slots=1)
    for rid, p in zip(rids, prompts):
        ref = seq_eng.generate(p[None, :], max_new_tokens=4)
        assert finished[rid].generated == ref[0].tolist(), rid


def test_eos_retirement_frees_slot():
    """A slot retiring on EOS is recycled for the queue; the finished
    request keeps the tokens up to (and including) the EOS."""
    cfg, params, _ = _engine()
    base = ServeEngine(cfg, params, max_seq=64, num_slots=1)
    prompt = (np.arange(8, dtype=np.int32) * 3) % cfg.vocab_size
    ref = base.generate(prompt[None, :], max_new_tokens=6)[0].tolist()
    eos = ref[2]  # force retirement at (no later than) the third token
    stop = ref.index(eos) + 1
    eng = ServeEngine(cfg, params, max_seq=64, num_slots=1, eos_id=eos)
    r0 = eng.submit(prompt, max_new_tokens=6)
    r1 = eng.submit(prompt[:4], max_new_tokens=2)
    finished = eng.run()
    assert finished[r0].generated == ref[:stop]  # stopped at EOS, inclusive
    assert 1 <= len(finished[r1].generated) <= 2  # queued request got the slot
    assert finished[r1].admit_tick >= finished[r0].finish_tick


# --------------------------------------------------------------------------
# vectorized-position decode: mixed depths == each request alone, bitwise
# --------------------------------------------------------------------------


def _prefill_one(cfg, params, prompt):
    S = len(prompt)
    cache = tfm.init_cache(cfg, 1, 32, dtype=jnp.float32)
    batch = {
        "tokens": jnp.asarray(prompt)[None, :],
        "positions": jnp.arange(S, dtype=jnp.int32),
    }
    logits, cache = tfm.prefill(params, cfg, CTX, batch, cache)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache


def test_mixed_depth_decode_bitwise():
    """decode_step over slots at different depths (pos: [B]) must produce
    BITWISE-identical logits to decoding each request in its own cache."""
    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(1)
    pa = rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
    pb = rng.integers(0, cfg.vocab_size, (12,), dtype=np.int32)
    ta, cache_a = _prefill_one(cfg, params, pa)
    tb, cache_b = _prefill_one(cfg, params, pb)

    def merge(a, b):
        return jnp.concatenate([a, b], axis=1 if a.ndim > 1 else 0)

    cache = jax.tree.map(merge, cache_a, cache_b)
    np.testing.assert_array_equal(np.asarray(cache["pos"]), [8, 12])
    toks = jnp.concatenate([ta, tb], axis=0)
    step = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg, CTX))
    for _ in range(3):
        toks, cache, logits = step(params, cache, toks)
        ta, cache_a, la = step(params, cache_a, ta)
        tb, cache_b, lb = step(params, cache_b, tb)
        np.testing.assert_array_equal(np.asarray(logits[0]), np.asarray(la[0]))
        np.testing.assert_array_equal(np.asarray(logits[1]), np.asarray(lb[0]))
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(jnp.concatenate([ta, tb], 0)))


def test_padded_prefill_matches_exact():
    """Bucketed (right-padded) prefill: logits at the true last token and the
    subsequent decode are unaffected by pad tokens behind it."""
    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, (11,), dtype=np.int32)
    t_exact, cache_exact = _prefill_one(cfg, params, prompt)

    bucket = 16
    toks = np.zeros((1, bucket), np.int32)
    toks[0, : len(prompt)] = prompt
    cache = tfm.init_cache(cfg, 1, 32, dtype=jnp.float32)
    batch = {
        "tokens": jnp.asarray(toks),
        "positions": jnp.arange(bucket, dtype=jnp.int32),
        "length": jnp.asarray([len(prompt)], jnp.int32),
    }
    logits, cache = tfm.prefill(params, cfg, CTX, batch, cache)
    t_pad = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(t_pad), np.asarray(t_exact))
    assert int(cache["pos"][0]) == len(prompt)
    step = jax.jit(lambda p, c, t: tfm.decode_step(p, c, t, cfg, CTX))
    for _ in range(4):  # decode overwrites each pad entry before reading it
        t_pad, cache, lp = step(params, cache, t_pad)
        t_exact, cache_exact, le = step(params, cache_exact, t_exact)
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(le))


# --------------------------------------------------------------------------
# paged KV cache: block-table engine == dense engine, prefix sharing
# --------------------------------------------------------------------------


def test_paged_engine_matches_dense():
    """The paged engine (page pool + block tables, serve/kv_pool.py) must
    reproduce the dense engine token-for-token on a mixed trace, with the
    same retrace bounds, and drain its pool on retirement."""
    cfg, params, dense = _engine(num_slots=2, max_seq=64)
    rng = np.random.default_rng(13)
    trace = [(8, 0), (16, 0), (12, 2), (8, 3)]
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln, _ in trace]
    rids_d = [dense.submit(p, 5, arrival_tick=t) for p, (_, t) in zip(prompts, trace)]
    fin_d = dense.run()

    paged = ServeEngine(cfg, params, max_seq=64, num_slots=2, paged=True, page_size=4)
    rids_p = [paged.submit(p, 5, arrival_tick=t) for p, (_, t) in zip(prompts, trace)]
    fin_p = paged.run()
    for rd, rp in zip(rids_d, rids_p):
        assert fin_d[rd].generated == fin_p[rp].generated, (rd, rp)
    assert paged.decode_trace_count == 1
    assert set(paged.prefill_trace_counts) == set(dense.prefill_trace_counts)
    assert paged.allocator.pages_in_use == 0  # every retirement freed
    stats = paged.kv_cache_stats()
    assert stats["paged"] == 1 and stats["peak_page_bytes"] <= stats["cache_bytes"]


def test_paged_prefix_sharing_fewer_pages_same_tokens():
    """Two requests sharing a prompt prefix must allocate strictly fewer
    pages than two unrelated requests, produce identical tokens to unshared
    generation, and the owner retiring must not disturb the sharer."""
    cfg, params, _ = _engine()
    rng = np.random.default_rng(17)
    prefix = rng.integers(0, cfg.vocab_size, (8,), dtype=np.int32)
    pair = [
        np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32)])
        for ln in (4, 6)
    ]

    def run_paged(prompts, budgets):
        eng = ServeEngine(cfg, params, max_seq=64, num_slots=2, paged=True, page_size=4)
        rids = [eng.submit(p, mt, arrival_tick=0) for p, mt in zip(prompts, budgets)]
        fin = eng.run()
        return [fin[r].generated for r in rids], eng.allocator.stats()

    # asymmetric budgets: the owner (slot 0) retires while the sharer is
    # still decoding through the shared pages
    toks, st = run_paged(pair, (2, 6))
    oracle = ServeEngine(cfg, params, max_seq=64, num_slots=1)
    refs = [oracle.generate(p[None], max_new_tokens=mt)[0].tolist()
            for p, mt in zip(pair, (2, 6))]
    assert toks == refs, (toks, refs)
    assert st["shared_hits"] == 2  # 8-token prefix = 2 chunks of 4
    unrelated = [rng.integers(0, cfg.vocab_size, (len(p),), dtype=np.int32) for p in pair]
    _, st_un = run_paged(unrelated, (2, 6))
    assert st["fresh_allocs"] < st_un["fresh_allocs"], (st, st_un)


def test_paged_admission_defers_on_small_pool():
    """A pool smaller than the slot count's worst case defers admission (the
    scheduler accounts pages, not rows) but still completes every request."""
    cfg, params, _ = _engine()
    rng = np.random.default_rng(19)
    # pool of 4 chunks x 8 tokens = 32 tokens; each request reserves
    # ceil((16+8)/8) = 3 pages, so two can never be resident together
    eng = ServeEngine(cfg, params, max_seq=64, num_slots=2, paged=True,
                      page_size=8, num_pages=4)
    prompts = [rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32) for _ in range(2)]
    rids = [eng.submit(p, 8, arrival_tick=0) for p in prompts]
    fin = eng.run()
    a, b = fin[rids[0]], fin[rids[1]]
    assert len(a.generated) == len(b.generated) == 8
    assert b.admit_tick > a.admit_tick  # deferred until the pool freed
    oracle = ServeEngine(cfg, params, max_seq=64, num_slots=1)
    for rid, p in zip(rids, prompts):
        assert fin[rid].generated == oracle.generate(p[None], 8)[0].tolist()
