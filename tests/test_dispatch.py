"""Unit tests for the unified dispatch layer (registry, planning, cache).

Single-device: everything here exercises registry resolution and the
simulator-backed plan cache without a mesh; the multi-device routing is
covered by ``repro.testing.dist_check`` (tests/test_distributed.py).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import dispatch as D
from repro.core import schedule as S
from repro.core.am import CommModel
from repro.core.dispatch import AttentionPlanConfig
from repro.kernels import ref
from repro.parallel.context import ParallelCtx


# --------------------------------------------------------------------------
# registry resolution
# --------------------------------------------------------------------------


def test_registry_contains_all_paper_backends():
    assert {"mesh", "ring", "ulysses", "decode", "local-flash"} <= set(
        D.available_backends()
    )


def test_auto_resolution():
    assert AttentionPlanConfig(backend="auto", n=1).resolved_backend() == "local-flash"
    assert AttentionPlanConfig(backend="auto", n=8).resolved_backend() == "mesh"
    assert AttentionPlanConfig(backend="ring", n=8).resolved_backend() == "ring"


def test_unknown_backend_raises_with_known_list():
    with pytest.raises(ValueError, match="unknown attention backend"):
        D.get_backend("does-not-exist")
    with pytest.raises(ValueError, match="mesh"):
        AttentionPlanConfig(backend="nope", n=4).resolved_backend()


def test_decode_backend_rejects_batched_mode():
    q = jnp.zeros((1, 8, 2, 4))
    with pytest.raises(ValueError, match="step-wise"):
        D.attention_in_shard_map(q, q, q, AttentionPlanConfig(backend="decode", n=1))


def test_distributed_backend_without_mesh_raises():
    q = jnp.zeros((1, 8, 2, 4))
    with pytest.raises(ValueError, match="ParallelCtx"):
        D.distributed_attention(
            q, q, q, cfg=AttentionPlanConfig(backend="mesh", axis_name="sp", n=4)
        )


def test_local_fallback_matches_reference():
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (2, 32, 4, 16))
    k = jax.random.normal(kk, (2, 32, 2, 16))
    v = jax.random.normal(kv, (2, 32, 2, 16))
    o = D.distributed_attention(q, k, v, cfg=AttentionPlanConfig(causal=True))
    o_ref, _ = ref.attention_ref(q, k, v, band=ref.causal_band())
    assert float(jnp.max(jnp.abs(o - o_ref))) < 2e-5


# --------------------------------------------------------------------------
# plan_from_ctx
# --------------------------------------------------------------------------


def test_plan_from_ctx_single_device_defaults():
    cfg = D.plan_from_ctx(ParallelCtx(), causal=True)
    assert cfg.n == 1 and cfg.backend == "mesh"
    assert cfg.resolved_backend() == "mesh"  # n==1 short-circuits at call time


def test_plan_from_ctx_ring_forces_a1():
    ctx = ParallelCtx(attn_impl="ring", mesh_a=4)
    cfg = D.plan_from_ctx(ctx, causal=False)
    assert cfg.a == 1 and cfg.backend == "ring"


# --------------------------------------------------------------------------
# simulator planning + cache
# --------------------------------------------------------------------------


def _comm(n=8, seq=1024):
    return CommModel(seq=seq, hidden=512, n=n, kv_hidden=256, bytes_per_elem=2)


def test_a1_mesh_plan_degenerates_to_ring_schedule(tmp_path):
    """The paper's 'covers Ring-Attention as a special case': planning the
    mesh backend at a=1 yields schedules with the ring backend's structure —
    same comm-op multiset and the one-KV-recv-per-step cadence."""
    n = 8
    cfg = AttentionPlanConfig(
        backend="mesh", axis_name="sp", n=n, a=1, causal=False,
        autotune=True, plan_cache_dir=str(tmp_path),
    )
    D._MEM_CACHE.clear()
    a, fwd, bwd = D.plan_schedules(cfg, _comm(n))
    assert a == 1 and (fwd.a, fwd.b) == (1, n)
    ring = S.ring_forward_schedule(n)
    assert fwd.comm_ops() == ring.comm_ops() == [S.RECV_KV] * (n - 1)
    assert sorted(fwd.blocks()) == sorted(ring.blocks())
    S.validate_schedule(fwd, strict_paper=True)
    assert bwd is not None and (bwd.a, bwd.b) == (1, n)


def test_plan_cache_roundtrip(tmp_path):
    cfg = AttentionPlanConfig(
        backend="mesh", axis_name="sp", n=8, a=None, causal=True,
        autotune=True, plan_cache_dir=str(tmp_path),
    )
    D._MEM_CACHE.clear()
    a1, fwd1, bwd1 = D.plan_schedules(cfg, _comm())
    files = list(tmp_path.glob("*.json"))
    assert len(files) == 1, "one plan file per (shape, dtype, n, hw) key"
    # cold in-memory state must reload the identical plan from disk
    D._MEM_CACHE.clear()
    a2, fwd2, bwd2 = D.plan_schedules(cfg, _comm())
    assert (a1, fwd1, bwd1) == (a2, fwd2, bwd2)
    assert len(list(tmp_path.glob("*.json"))) == 1  # no re-tune, no new file


def test_plan_cache_distinguishes_geometry(tmp_path):
    cfg = AttentionPlanConfig(
        backend="mesh", axis_name="sp", n=8, causal=True,
        autotune=True, plan_cache_dir=str(tmp_path),
    )
    D._MEM_CACHE.clear()
    D.plan_schedules(cfg, _comm(seq=1024))
    D.plan_schedules(cfg, _comm(seq=4096))
    assert len(list(tmp_path.glob("*.json"))) == 2


def test_plan_cache_corrupt_entry_replans(tmp_path):
    cfg = AttentionPlanConfig(
        backend="mesh", axis_name="sp", n=4, causal=False,
        autotune=True, plan_cache_dir=str(tmp_path),
    )
    D._MEM_CACHE.clear()
    a1, fwd1, _ = D.plan_schedules(cfg, _comm(n=4))
    (path,) = tmp_path.glob("*.json")
    path.write_text("{not json")
    D._MEM_CACHE.clear()
    a2, fwd2, _ = D.plan_schedules(cfg, _comm(n=4))
    assert (a1, fwd1) == (a2, fwd2)


def test_unknown_hw_profile_raises(tmp_path):
    cfg = AttentionPlanConfig(
        backend="mesh", axis_name="sp", n=4, autotune=True,
        hw_profile="quantum", plan_cache_dir=str(tmp_path),
    )
    with pytest.raises(ValueError, match="hw_profile"):
        D.plan_schedules(cfg, _comm(n=4))


def test_schedule_json_roundtrip():
    sched = S.greedy_forward_schedule(2, 4)
    assert S.schedule_from_json(S.schedule_to_json(sched)) == sched
    bwd = S.greedy_backward_schedule(2, 4)
    assert S.schedule_from_json(S.schedule_to_json(bwd)) == bwd


def test_autotune_picks_near_sqrt_tile(tmp_path):
    """With symmetric Q/KV widths the tuner lands near a = sqrt(n)."""
    cfg = AttentionPlanConfig(
        backend="mesh", axis_name="sp", n=16, causal=False,
        autotune=True, plan_cache_dir=str(tmp_path),
    )
    D._MEM_CACHE.clear()
    comm = CommModel(seq=1 << 16, hidden=4096, n=16, bytes_per_elem=2)
    a, fwd, bwd = D.plan_schedules(cfg, comm)
    assert a in (2, 4, 8)
    S.validate_schedule(fwd)


# --------------------------------------------------------------------------
# call-site hygiene: nothing outside core/ (and tests) imports backends
# --------------------------------------------------------------------------


def test_no_direct_backend_imports_outside_core():
    import os
    import re

    root = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src", "repro")
    banned = re.compile(
        r"from repro\.core\.(mesh_attention|ring_attention|ulysses|decode_attention"
        r"|mesh_attention_collective) import|import repro\.core\.(mesh_attention"
        r"|ring_attention|ulysses|decode_attention|mesh_attention_collective)\b"
    )
    offenders = []
    for dirpath, _, files in os.walk(root):
        rel = os.path.relpath(dirpath, root)
        if rel.split(os.sep)[0] in ("core", "testing"):
            continue  # core owns the backends; testing compares against them
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as f:
                if banned.search(f.read()):
                    offenders.append(os.path.relpath(path, root))
    assert not offenders, f"direct backend imports outside core/: {offenders}"
