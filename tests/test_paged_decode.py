"""Paged-native split-K flash-decode kernel vs the gather-then-dense oracle.

The native kernel (kernels/paged_decode.py) must agree with the gather path
(page-gather + band kernel) to combine-order fp tolerance for arbitrary
depths, page tables, pool sizes, shard geometries, and windows — and must be
EXACT about what it reads: tail positions of a partial last page and
unallocated pages are poisoned with huge values that would blow up any leak.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.core import decode_attention as da
from repro.core import dispatch
from repro.core import kv_quant
from repro.core.am import CommModel
from repro.kernels import ops
from repro.kernels import paged_decode as pk
from repro.kernels.ref import NEG_INF
from repro.parallel.context import ParallelCtx
from repro.serve.kv_pool import PageAllocator, PagedLayout

H, HKV, D = 4, 2, 8
POISON = 1e4  # any leak of a masked/unallocated position is unmissable

# native-vs-oracle tolerance per storage mode: both paths dequantize the SAME
# stored values, so quantization noise cancels and only combine-order fp error
# remains; quantized modes get a little headroom for the extra scale multiply
_TOLS = {"fp": (2e-5, 1e-5), "int8": (5e-5, 2e-5), "fp8": (5e-5, 2e-5)}


def _build_pool(rng, depths, page_size, max_pages, extra_pages=0, kv_dtype="fp"):
    """Allocator-backed local pool: slot rows at the given LOCAL depths, all
    unwritten positions (page tails past depth, free pages) poisoned.

    ``kv_dtype != "fp"`` stores the pool quantized (scale side tables
    returned last); the dense oracle copy then holds the DEQUANTIZED values,
    so oracle comparisons check the read path, not quantization noise.
    Quantized poison: saturated codes under a huge scale."""
    lay = PagedLayout(
        num_pages=len(depths) * max_pages + extra_pages,
        page_size=page_size, max_pages=max_pages, n=1,
    )
    alloc = PageAllocator(lay, quantized=kv_dtype != "fp")
    if kv_dtype == "fp":
        k_pool = np.full((lay.num_pages, page_size, HKV, D), POISON, np.float32)
        v_pool = np.full_like(k_pool, POISON)
        k_scale = v_scale = None
    else:
        store = np.dtype(kv_quant.storage_dtype(kv_dtype))
        k_pool = np.full((lay.num_pages, page_size, HKV, D), 127, np.int8).astype(store)
        v_pool = k_pool.copy()
        k_scale = np.full((lay.num_pages, page_size, HKV), POISON, np.float32)
        v_scale = k_scale.copy()
    dense_k = np.zeros((len(depths), max_pages * page_size, HKV, D), np.float32)
    dense_v = np.zeros_like(dense_k)
    for slot, d in enumerate(depths):
        prompt = rng.integers(0, 2**30, (d,), dtype=np.int32)
        alloc.alloc_slot(slot, prompt, 0)
        for p in range(d):
            kv = rng.normal(size=(2, HKV, D)).astype(np.float32)
            lp, off = p // page_size, p % page_size
            pid = alloc.block_table[slot, lp]
            if kv_dtype == "fp":
                k_pool[pid, off], v_pool[pid, off] = kv[0], kv[1]
                dense_k[slot, p], dense_v[slot, p] = kv[0], kv[1]
            else:
                qk, sk = kv_quant.quantize(jnp.asarray(kv[0]), kv_dtype)
                qv, sv = kv_quant.quantize(jnp.asarray(kv[1]), kv_dtype)
                k_pool[pid, off], k_scale[pid, off] = np.asarray(qk), np.asarray(sk)
                v_pool[pid, off], v_scale[pid, off] = np.asarray(qv), np.asarray(sv)
                dense_k[slot, p] = np.asarray(kv_quant.dequantize(qk, sk))
                dense_v[slot, p] = np.asarray(kv_quant.dequantize(qv, sv))
    bt = jnp.asarray(alloc.device_table(len(depths)))
    out = (alloc, jnp.asarray(k_pool), jnp.asarray(v_pool), bt, dense_k, dense_v)
    if kv_dtype != "fp":
        out += (jnp.asarray(k_scale), jnp.asarray(v_scale))
    return out


def _oracle_partial(q, dense_k, dense_v, pos, kv_off, stride, window):
    """Gather-then-dense band partial — the exact reference path."""
    hi = (window - 1) if window else da.BAND_INF
    return da._banded_partial(
        q, jnp.asarray(dense_k), jnp.asarray(dense_v),
        jnp.asarray(pos, jnp.int32), kv_off, stride, hi, D**-0.5,
    )


# --------------------------------------------------------------------------
# hypothesis: native == gather over random depths / tables / pools / geometry
# --------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    depths=st.lists(st.integers(min_value=1, max_value=12), min_size=1, max_size=3),
    page_size=st.sampled_from([1, 2, 4]),
    stride=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([None, 3, 8]),
    vector_pos=st.booleans(),
    kv_dtype=st.sampled_from(["fp", "int8"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_native_matches_gather_oracle(
    depths, page_size, stride, window, vector_pos, kv_dtype, seed
):
    rng = np.random.default_rng(seed)
    max_pages = -(-max(depths) // page_size) + 1  # at least one never-written page
    shard = rng.integers(0, stride)  # striped shard geometry: kv_off = i
    built = _build_pool(rng, depths, page_size, max_pages, kv_dtype=kv_dtype)
    _, k_pool, v_pool, bt, dense_k, dense_v = built[:6]
    k_scale, v_scale = built[6:] if kv_dtype != "fp" else (None, None)
    q = jnp.asarray(rng.normal(size=(len(depths), 1, H, D)), jnp.float32)
    # global position whose last visible LOCAL slot is depth-1 on this shard
    pos = np.asarray([shard + stride * (d - 1) for d in depths], np.int32)
    if not vector_pos:
        pos = pos.min()  # scalar pos: every row at the same (lowest) depth
    o_n, lse_n = pk.paged_flash_decode(
        q, k_pool, v_pool, bt, jnp.asarray(pos), shard,
        stride_kv=stride, window=window, k_scale=k_scale, v_scale=v_scale,
    )
    o_g, lse_g = _oracle_partial(q, dense_k, dense_v, pos, shard, stride, window)
    atol, rtol = _TOLS[kv_dtype]
    np.testing.assert_allclose(np.asarray(o_n), np.asarray(o_g), atol=atol, rtol=rtol)
    np.testing.assert_allclose(np.asarray(lse_n), np.asarray(lse_g), atol=atol, rtol=rtol)


# --------------------------------------------------------------------------
# partial last page: the in-page tail mask is where split-K silently breaks
# --------------------------------------------------------------------------


def test_partial_last_page_exact_against_truncated_oracle():
    """Depths not divisible by page_size: the kernel must weigh the partial
    page by its LIVE tail only.  The oracle here sees just the first d
    positions (no masked garbage at all), so any tail leak — wrong lse
    weight, poison read — breaks the comparison loudly."""
    rng = np.random.default_rng(0)
    page_size, max_pages = 4, 4
    depths = [1, 5, 11]  # 1 = lone token in a page; 5, 11 = ragged tails
    _, k_pool, v_pool, bt, dense_k, dense_v = _build_pool(
        rng, depths, page_size, max_pages
    )
    q = jnp.asarray(rng.normal(size=(len(depths), 1, H, D)), jnp.float32)
    pos = jnp.asarray([d - 1 for d in depths], jnp.int32)
    o_n, lse_n = pk.paged_flash_decode(
        q, k_pool, v_pool, bt, pos, 0, stride_kv=1
    )
    for slot, d in enumerate(depths):
        o_ref, lse_ref = ops.block_attention(
            q[slot : slot + 1],
            jnp.asarray(dense_k[slot : slot + 1, :d]),
            jnp.asarray(dense_v[slot : slot + 1, :d]),
            (d - 1, 0, 0, da.BAND_INF),
        )
        np.testing.assert_allclose(
            np.asarray(o_n[slot]), np.asarray(o_ref[0]), atol=2e-5, rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(lse_n[slot]), np.asarray(lse_ref[0]), atol=2e-5, rtol=1e-5
        )


def test_empty_shard_returns_exact_empty_band():
    """A shard holding nothing visible must return o = 0, lse = NEG_INF
    exactly (the psum combine depends on it); all-empty splits must not
    resurrect with weight exp(NEG_INF - NEG_INF) = 1."""
    rng = np.random.default_rng(1)
    _, k_pool, v_pool, bt, _, _ = _build_pool(rng, [8], 4, 3)
    q = jnp.asarray(rng.normal(size=(1, 1, H, D)), jnp.float32)
    # striped shard i=3 of n=4 sees positions 3, 7, ...; pos=2 hides them all
    o, lse = pk.paged_flash_decode(
        q, k_pool, v_pool, bt, jnp.int32(2), 3, stride_kv=4
    )
    np.testing.assert_array_equal(np.asarray(o), 0.0)
    np.testing.assert_array_equal(np.asarray(lse), np.float32(NEG_INF))


def test_combine_split_partials_empty_guard():
    o = jnp.zeros((1, 3, H, D), jnp.float32)
    lse = jnp.full((1, 3, H), NEG_INF, jnp.float32)
    oc, lc = pk.combine_split_partials(o, lse)
    np.testing.assert_array_equal(np.asarray(oc), 0.0)
    np.testing.assert_array_equal(np.asarray(lc), np.float32(NEG_INF))


# --------------------------------------------------------------------------
# copy-on-write: decode through shared then privately-copied pages
# --------------------------------------------------------------------------


def test_cow_shared_page_decode():
    """Two slots share their prompt's page; slot 1 then appends through a CoW
    copy.  The native kernel must read each slot's CURRENT table — the shared
    page for slot 0, the private copy for slot 1."""
    rng = np.random.default_rng(2)
    page_size = 4
    lay = PagedLayout(num_pages=8, page_size=page_size, max_pages=2, n=1)
    alloc = PageAllocator(lay)
    prompt = np.arange(4, dtype=np.int32)  # exactly one chunk -> registered
    alloc.alloc_slot(0, prompt, 4)
    got = alloc.alloc_slot(1, prompt, 4)
    assert got.shared_pages == 1
    k_pool = np.full((lay.num_pages, page_size, HKV, D), POISON, np.float32)
    v_pool = np.full_like(k_pool, POISON)
    shared_kv = rng.normal(size=(2, page_size, HKV, D)).astype(np.float32)
    pid = int(alloc.block_table[0, 0])
    k_pool[pid], v_pool[pid] = shared_kv[0], shared_kv[1]
    # slot 1 appends at pos 2 (inside the shared page) -> private copy
    cp = alloc.ensure_append(1, 2)
    assert cp is not None
    src, dst = cp
    k_pool[dst], v_pool[dst] = k_pool[src].copy(), v_pool[src].copy()
    new_kv = rng.normal(size=(2, HKV, D)).astype(np.float32)
    k_pool[dst, 2], v_pool[dst, 2] = new_kv[0], new_kv[1]

    bt = jnp.asarray(alloc.device_table(2))
    q = jnp.asarray(rng.normal(size=(2, 1, H, D)), jnp.float32)
    pos = jnp.asarray([3, 2], jnp.int32)
    o_n, lse_n = pk.paged_flash_decode(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool), bt, pos, 0,
        stride_kv=1,
    )
    dense_k = np.zeros((2, lay.max_pages * page_size, HKV, D), np.float32)
    dense_v = np.zeros_like(dense_k)
    dense_k[0, :4], dense_v[0, :4] = shared_kv[0], shared_kv[1]
    dense_k[1, :4], dense_v[1, :4] = k_pool[dst], v_pool[dst]
    o_g, lse_g = _oracle_partial(q, dense_k, dense_v, pos, 0, 1, None)
    np.testing.assert_allclose(np.asarray(o_n), np.asarray(o_g), atol=2e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_n), np.asarray(lse_g), atol=2e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# dense cache as one implicit page run (split-K for the dense engine too)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("m,window", [(16, None), (32, 5), (24, None)])
def test_dense_split_k_matches_band(m, window):
    rng = np.random.default_rng(3)
    B = 3
    k_cache = jnp.asarray(rng.normal(size=(B, m, HKV, D)), jnp.float32)
    v_cache = jnp.asarray(rng.normal(size=(B, m, HKV, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, m, (B,)), jnp.int32)
    o_band = da.sharded_cache_decode(
        q, k_cache, v_cache, pos, None, 1, window=window, kernel="band"
    )
    o_native = da.sharded_cache_decode(
        q, k_cache, v_cache, pos, None, 1, window=window, kernel="native"
    )
    np.testing.assert_allclose(
        np.asarray(o_native), np.asarray(o_band), atol=2e-5, rtol=1e-5
    )


# --------------------------------------------------------------------------
# dispatch seam: the kernel-variant flag routes and keys correctly
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
def test_decode_step_kernel_flag_paged_n1(kv_dtype):
    # depths chosen so the append position sits inside an ALLOCATED page —
    # the engine guarantees this via ensure_append before every tick (an
    # unallocated append target is out of contract: the scatter drops the
    # write, the native kernel skips the page, and the gather path would
    # read clamped page 0 through the band)
    rng = np.random.default_rng(4)
    depths = [5, 3]
    page_size, max_pages = 2, 4
    built = _build_pool(rng, depths, page_size, max_pages, kv_dtype=kv_dtype)
    _, k_pool, v_pool, bt = built[:4]
    scales = built[6:] if kv_dtype != "fp" else (None, None)
    ctx = ParallelCtx()
    q = jnp.asarray(rng.normal(size=(2, 1, H, D)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(2, 1, HKV, D)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(2, 1, HKV, D)), jnp.float32)
    pos = jnp.asarray(depths, jnp.int32)  # append AT depth, attend <= pos
    outs, pools = {}, {}
    for kernel in ("gather", "native"):
        out = dispatch.decode_attention_step(
            q, kn, vn, k_pool, v_pool, pos, ctx,
            block_table=bt, decode_kernel=kernel,
            k_scale=scales[0], v_scale=scales[1],
        )
        outs[kernel] = np.asarray(out[0])
        # quantized: the updated scale tables ride along and must match too
        pools[kernel] = tuple(np.asarray(a) for a in out[1:])
    atol, rtol = _TOLS[kv_dtype]
    np.testing.assert_allclose(outs["native"], outs["gather"], atol=atol, rtol=rtol)
    # the UPDATE is kernel-independent: bitwise-identical pool/scale writes
    # (the fp path keeps its exact bitwise guarantee; quantize-on-write is
    # deterministic, so the quantized path holds it too)
    for a, b in zip(pools["gather"], pools["native"]):
        np.testing.assert_array_equal(a, b)


def test_native_falls_back_to_gather_under_ref_backend():
    """REPRO_KERNELS=ref must serve 'native' with the gather oracle (bitwise
    equal outputs), so pure-jnp environments keep one code path."""
    rng = np.random.default_rng(5)
    _, k_pool, v_pool, bt, _, _ = _build_pool(rng, [5], 2, 4)
    q = jnp.asarray(rng.normal(size=(1, 1, H, D)), jnp.float32)
    pos = jnp.asarray([4], jnp.int32)
    ops.set_backend("ref")
    try:
        o_n = da.paged_cache_decode(q, k_pool, v_pool, bt, pos, None, 1, kernel="native")
        o_g = da.paged_cache_decode(q, k_pool, v_pool, bt, pos, None, 1, kernel="gather")
    finally:
        ops.set_backend("auto")
    np.testing.assert_array_equal(np.asarray(o_n), np.asarray(o_g))


def test_plan_key_distinguishes_decode_kernel():
    comm = CommModel(seq=256, hidden=128, n=4)
    hw = dispatch.HW_PROFILES["default"]
    keys = {
        dispatch._plan_key(
            dispatch.AttentionPlanConfig(n=4, paged=True, decode_kernel=dk), comm, hw
        )[0]
        for dk in ("native", "gather")
    }
    assert len(keys) == 2
    with pytest.raises(ValueError):
        dispatch.AttentionPlanConfig(decode_kernel="warp")
    # the n==1 dense path never builds a plan config: the resolver itself
    # must reject typos instead of silently serving the default kernel
    with pytest.raises(ValueError):
        dispatch.decode_attention_step(
            jnp.zeros((1, 1, H, D)), jnp.zeros((1, 1, HKV, D)),
            jnp.zeros((1, 1, HKV, D)), jnp.zeros((1, 8, HKV, D)),
            jnp.zeros((1, 8, HKV, D)), jnp.int32(0), ParallelCtx(),
            decode_kernel="nativ",
        )


# --------------------------------------------------------------------------
# engine: version-gated block-table upload
# --------------------------------------------------------------------------


def test_block_table_upload_is_version_gated():
    """Decode ticks whose appends stay inside the current page must NOT
    re-upload the device block table; only allocator mutations (prefill,
    chunk-boundary appends, CoW, retirement) do."""
    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    # page_size 16 = one chunk holds prompt + all new tokens: after the
    # prefill upload, every decode tick stays inside the page
    eng = ServeEngine(cfg, params, max_seq=64, num_slots=2, paged=True, page_size=16)
    eng.submit(rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32), 5)
    eng.step()  # prefill + first decode tick
    uploads_after_prefill = eng.bt_uploads
    assert uploads_after_prefill >= 1
    while eng.has_work:
        eng.step()
    # retirement frees pages (a table mutation) -> at most one more upload
    # would show on a NEXT sync; the decode ticks themselves added none
    assert eng.bt_uploads == uploads_after_prefill
    ticks = eng._tick
    assert eng.bt_uploads < ticks
    assert eng.kv_cache_stats()["bt_uploads"] == float(eng.bt_uploads)
