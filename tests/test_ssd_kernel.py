"""SSD Pallas kernel (interpret=True) vs the sequential-recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ssd_ref
from repro.kernels.ssd_scan import ssd_scan_fwd


def _inputs(key, B, S, H, P, G, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize(
    "B,S,H,P,G,N,chunk",
    [
        (1, 32, 2, 8, 1, 16, 8),
        (2, 64, 4, 16, 2, 8, 16),
        (1, 48, 3, 8, 1, 8, 8),  # group=1, 3 heads, chunk not pow2 count
        (1, 16, 2, 32, 2, 32, 16),
    ],
)
def test_ssd_kernel_vs_sequential_ref(B, S, H, P, G, N, chunk):
    x, dt, A, Bm, Cm = _inputs(jax.random.PRNGKey(B * S + H), B, S, H, P, G, N)
    y, state = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, state_ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(state, state_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_dtypes(dtype):
    x, dt, A, Bm, Cm = _inputs(jax.random.PRNGKey(0), 1, 32, 2, 8, 1, 8)
    y, _ = ssd_scan_fwd(
        x.astype(dtype), dt, A, Bm, Cm, chunk=8, interpret=True
    )
    assert y.dtype == dtype
    y_ref, _ = ssd_ref(x, dt, A, Bm, Cm)
    tol = 3e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        y.astype(np.float32), y_ref.astype(np.float32), rtol=tol, atol=tol
    )


def test_ssd_kernel_single_chunk_and_full():
    """chunk == S degenerates to one quadratic block; chunk == 1 is the pure
    recurrence — both must agree with the oracle."""
    x, dt, A, Bm, Cm = _inputs(jax.random.PRNGKey(3), 1, 16, 2, 8, 1, 8)
    y_ref, st_ref = ssd_ref(x, dt, A, Bm, Cm)
    for chunk in (1, 16):
        y, st = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
        np.testing.assert_allclose(y, y_ref, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(st, st_ref, rtol=3e-4, atol=3e-4)
