"""Autotuner (Fig. 6), simulator invariants, HLO collective parsing, and
dry-run cell bookkeeping."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.am import CommModel
from repro.core.autotune import plan_for, tune
from repro.core.simulator import HardwareModel, make_cost_model, simulate
from repro.core.tiling import factorizations

COMM_HW = HardwareModel(peak_flops=989e12, link_bw=2e9, attn_efficiency=0.3)
FAST_HW = HardwareModel(peak_flops=50e12, link_bw=400e9, attn_efficiency=0.9)


def test_autotune_picks_square_for_mha_comm_bound():
    """Communication-bound + MHA: the tuned tile approaches sqrt(n) (paper
    §3.8 AM-GM optimum)."""
    plan = tune(CommModel(seq=1 << 20, hidden=4096, n=64), COMM_HW, causal=True)
    assert plan.a in (4, 8, 16)  # near sqrt(64), never the ring extreme
    assert plan.a != 1


def test_autotune_compute_bound_indifferent_but_valid():
    """Compute-bound: any tile hides comm; the tuner must return a valid plan
    whose simulated time ~= pure compute."""
    plan = tune(CommModel(seq=1 << 18, hidden=4096, n=16), FAST_HW, causal=False)
    assert plan.fwd_sim.exposed_comm < 0.05 * plan.fwd_sim.total


def test_autotune_beats_or_ties_every_fixed_tile():
    comm = CommModel(seq=1 << 19, hidden=4096, n=32)
    best = tune(comm, COMM_HW, causal=True)
    for a, _ in factorizations(32):
        assert best.total <= plan_for(comm, a, COMM_HW, causal=True).total * 1.0001


def test_gqa_moves_tuned_tile_flatter():
    """EXPERIMENTS.md §Perf B2: with GQA the byte-optimal tile has smaller a
    (measured on compiled HLO; here the analytic/tuner view)."""
    mha = CommModel(seq=1 << 20, hidden=4096, n=16)
    gqa = CommModel(seq=1 << 20, hidden=4096, n=16, kv_hidden=4096 // 8)
    assert gqa.best_a() <= mha.best_a()
    assert gqa.best_a() <= 2


@given(st.integers(2, 32).flatmap(lambda n: st.tuples(st.just(n), st.sampled_from([a for a, _ in factorizations(n)]))))
@settings(max_examples=50, deadline=None)
def test_simulator_invariants(na):
    """total >= compute, total >= serialized-comm/rings, exposed <= comm."""
    n, a = na
    comm = CommModel(seq=1 << 16, hidden=1024, n=n)
    plan = plan_for(comm, a, COMM_HW, causal=False, with_backward=False)
    sim = plan.fwd_sim
    assert sim.total >= sim.compute - 1e-12
    assert sim.exposed_comm <= sim.comm + 1e-12
    assert sim.total >= sim.compute + sim.exposed_comm - 1e-9
    # wire bytes match the analytic model exactly
    assert sim.comm_bytes == comm.fwd_bytes(a)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %ag = bf16[16,1024,128]{2,1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = f32[256,256]{1,0} all-reduce(%y), replica_groups=[8,2]<=[16], to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[2,512]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %ags = (bf16[8,8]{1,0}, bf16[32,8]{1,0}) all-gather-start(%v), replica_groups={{0,1,2,3}}
  %agd = bf16[32,8]{1,0} all-gather-done(%ags)
"""


def test_collective_bytes_parsing():
    from repro.launch.hlo_analysis import collective_bytes

    out = collective_bytes(HLO_SAMPLE)
    # all-gather: 16*1024*128*2 bytes * 3/4  +  start form: 32*8*2 * 3/4
    assert out["all-gather"] == pytest.approx(16 * 1024 * 128 * 2 * 0.75 + 32 * 8 * 2 * 0.75)
    # all-reduce: 2 * payload * (g-1)/g with iota groups [8,2] -> g=2
    assert out["all-reduce"] == pytest.approx(2 * 256 * 256 * 4 * 0.5)
    # reduce-scatter: result * (g-1)
    assert out["reduce-scatter"] == pytest.approx(64 * 128 * 4 * 1)
    # collective-permute: full payload
    assert out["collective-permute"] == pytest.approx(2 * 512 * 2)
    assert out["total"] == pytest.approx(sum(out[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")))


def test_roofline_terms_math():
    from repro.launch.hlo_analysis import HW, roofline_terms

    r = roofline_terms(1e12, 1e11, 1e9, chips=256, model_flops=200e12)
    assert r["compute_s"] == pytest.approx(1e12 / HW["peak_flops"])
    assert r["memory_s"] == pytest.approx(1e11 / HW["hbm_bw"])
    assert r["collective_s"] == pytest.approx(1e9 / HW["link_bw"])
    assert r["dominant"] == "memory"  # 122ms > 5.1ms > 0.02ms
    assert r["useful_flops_ratio"] == pytest.approx(200e12 / (1e12 * 256))
    r2 = roofline_terms(1e14, 1e10, 1e9, chips=8)
    assert r2["dominant"] == "compute"


# ---------------------------------------------------------------------------
# dry-run cell bookkeeping
# ---------------------------------------------------------------------------


def test_cell_applicability_rules():
    from repro.configs import ALL_ARCHS, SHAPES, get_config
    from repro.launch.cells import cell_applicable

    runs_500k = {
        a for a in ALL_ARCHS
        if cell_applicable(get_config(a), SHAPES["long_500k"])[0]
    }
    assert runs_500k == {"mamba2-370m", "hymba-1.5b", "mixtral-8x7b"}
    for a in ALL_ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_applicable(get_config(a), SHAPES[s])[0]


def test_model_flops_sane():
    from repro.configs import SHAPES, get_config
    from repro.launch.cells import active_params, model_flops

    dense = active_params(get_config("granite-8b"))
    assert 7.5e9 < dense < 9.5e9
    moe_total_vs_active = active_params(get_config("mixtral-8x7b"))
    assert 11e9 < moe_total_vs_active < 16e9  # 2-of-8 experts active + shared
    f = model_flops(get_config("granite-8b"), SHAPES["train_4k"])
    assert f == pytest.approx(6 * dense * 4096 * 256, rel=1e-6)


def test_dryrun_results_complete_and_clean():
    """The shipped dry-run artifacts: 40 cells x 2 meshes, no errors."""
    import json
    import os

    d = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run results not generated")
    base = [f for f in os.listdir(d) if f.endswith("single.json") or f.endswith("multi.json")]
    assert len(base) == 80
    statuses = {}
    for fn in base:
        with open(os.path.join(d, fn)) as f:
            statuses[fn] = json.load(f)["status"]
    assert all(s in ("ok", "skip") for s in statuses.values()), statuses
    assert sum(1 for s in statuses.values() if s == "ok") == 66
