"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = simulated or
measured microseconds of the benchmarked operation; derived = the headline
quantity the paper reports for that table).  Detailed tables are written to
benchmarks/results/*.json for EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    )

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def _save(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


# ---- Table 2: theoretical communication volume ------------------------------


def bench_table2_comm_volume():
    from repro.core.am import table2

    rows = {}
    for n in (32, 64, 128, 256, 1024):
        rows[n] = table2(n)
    _save("table2_comm_volume", rows)
    red = 1 - rows[256]["mesh"] / rows[256]["ring"]
    _emit("table2_comm_volume", 0.0, f"mesh_vs_ring_reduction_256gpu={red:.1%}")
    return rows


# ---- Table 3: fwd+bwd throughput (simulated, paper-calibrated cluster) -------


def bench_table3_throughput():
    from benchmarks.common import PAPER_HW, attention_time

    rows = []
    t0 = time.perf_counter()
    for causal in (True, False):
        for seq in (256 * 1024, 512 * 1024, 1024 * 1024):
            for n in (32, 64, 128, 256):
                ring = attention_time(n, seq, a=1, causal=causal)
                mesh = attention_time(n, seq, a=None, causal=causal)
                rows.append(
                    {
                        "causal": causal, "seq": seq, "n": n,
                        "ring_iters_per_s": ring["iters_per_s"],
                        "mesh_iters_per_s": mesh["iters_per_s"],
                        "mesh_a": mesh["a"],
                        "speedup": mesh["iters_per_s"] / ring["iters_per_s"],
                    }
                )
    wall = (time.perf_counter() - t0) * 1e6 / len(rows)
    _save("table3_throughput", rows)
    sp = [r["speedup"] for r in rows]
    avg, mx = sum(sp) / len(sp), max(sp)
    _emit("table3_throughput", wall, f"speedup_avg={avg:.2f}x_max={mx:.2f}x (paper: 2.9x/3.4x)")
    return rows


# ---- Table 4: MFU -------------------------------------------------------------


def bench_table4_mfu():
    from benchmarks.common import attention_time, mfu

    rows = []
    for causal in (True, False):
        for seq in (256 * 1024, 512 * 1024, 1024 * 1024):
            for n in (32, 64, 128, 256):
                ring = attention_time(n, seq, a=1, causal=causal)
                mesh = attention_time(n, seq, a=None, causal=causal)
                rows.append(
                    {
                        "causal": causal, "seq": seq, "n": n,
                        "ring_mfu": mfu(n, seq, ring["total_s"], causal),
                        "mesh_mfu": mfu(n, seq, mesh["total_s"], causal),
                    }
                )
    _save("table4_mfu", rows)
    ratio = sum(r["mesh_mfu"] / max(r["ring_mfu"], 1e-9) for r in rows) / len(rows)
    _emit("table4_mfu", 0.0, f"mfu_ratio_avg={ratio:.2f}x (paper: 2.5x avg)")
    return rows


# ---- Figure 8: strong / weak scaling -----------------------------------------


def bench_fig8_scaling():
    from benchmarks.common import attention_time

    strong = []
    for n in (32, 64, 128, 256):
        ring = attention_time(n, 1 << 20, a=1, causal=True)
        mesh = attention_time(n, 1 << 20, a=None, causal=True)
        strong.append({"n": n, "ring_s": ring["total_s"], "mesh_s": mesh["total_s"]})
    weak = []
    seq = 512 * 1024
    for n in (32, 64, 128, 256):
        ring = attention_time(n, seq, a=1, causal=True)
        mesh = attention_time(n, seq, a=None, causal=True)
        weak.append({"n": n, "seq": seq, "ring_s": ring["total_s"], "mesh_s": mesh["total_s"]})
        seq = int(seq * 1.41421356)
    _save("fig8_scaling", {"strong": strong, "weak": weak})
    ring_slow = weak[-1]["ring_s"] / weak[0]["ring_s"]
    mesh_slow = weak[-1]["mesh_s"] / weak[0]["mesh_s"]
    _emit(
        "fig8_scaling", 0.0,
        f"weak_scaling_slowdown ring={ring_slow:.2f}x mesh={mesh_slow:.2f}x (paper: 3.74x/2.83x)",
    )
    return strong, weak


# ---- Figure 9: runtime + communication breakdown ------------------------------


def bench_fig9_breakdown():
    from benchmarks.common import attention_time

    rows = []
    for n in (32, 64, 128, 256):
        ring = attention_time(n, 1 << 20, a=1, causal=True)
        mesh = attention_time(n, 1 << 20, a=None, causal=True)
        rows.append(
            {
                "n": n,
                "ring_compute_s": ring["compute_s"],
                "ring_wait_s": ring["exposed_comm_s"],
                "mesh_compute_s": mesh["compute_s"],
                "mesh_wait_s": mesh["exposed_comm_s"],
                "ring_comm_gb": ring["comm_bytes"] / 1e9,
                "mesh_comm_gb": mesh["comm_bytes"] / 1e9,
            }
        )
    _save("fig9_breakdown", rows)
    r = rows[-1]
    wait_red = 1 - r["mesh_wait_s"] / max(r["ring_wait_s"], 1e-12)
    vol_red = 1 - r["mesh_comm_gb"] / r["ring_comm_gb"]
    _emit(
        "fig9_breakdown", 0.0,
        f"wait_reduction_256={wait_red:.1%} comm_volume_reduction_256={vol_red:.1%} "
        f"(paper: ~74.9%/85.5%)",
    )
    return rows


# ---- Table 5: peak memory ------------------------------------------------------


def bench_table5_peak_memory():
    """Analytic attention-working-set model, same units as the paper:
    Ring holds <=2 KV chunks + 1 Q chunk; Mesh holds a Q chunks + b KV chunks
    + partial-O accumulators; backward adds the OdOQ/dQ/dKV buffers."""
    from repro.core.tiling import best_square_a

    bytes_per = 2  # bf16
    rows = []
    for causal in (True, False):
        for seq in (256 * 1024, 512 * 1024, 1024 * 1024):
            for n in (32, 64, 128, 256):
                chunk = seq * 4096 // n * bytes_per
                a = best_square_a(n)
                b = n // a
                ring_fwd = (1 + 2 * 2) * chunk
                ring_bwd = (1 + 2 * 2 + 3) * chunk
                mesh_fwd = (a + 2 * b + 2 * a) * chunk  # Q + KV + fp32 O acc
                mesh_bwd = (3 * a + 2 * b + 2 * a + 2 * b) * chunk
                rows.append(
                    {
                        "causal": causal, "seq": seq, "n": n,
                        "ring_fwd_gb": ring_fwd / 2**30,
                        "ring_bwd_gb": ring_bwd / 2**30,
                        "mesh_fwd_gb": mesh_fwd / 2**30,
                        "mesh_bwd_gb": mesh_bwd / 2**30,
                    }
                )
    _save("table5_peak_memory", rows)
    r = next(x for x in rows if x["causal"] and x["seq"] == 1 << 20 and x["n"] == 256)
    _emit(
        "table5_peak_memory", 0.0,
        f"1M_256gpu mesh_fwd={r['mesh_fwd_gb']:.1f}GB ring_fwd={r['ring_fwd_gb']:.2f}GB "
        f"(paper: 3.2/0.5)",
    )
    return rows


# ---- Figure 10: GQA sweep -------------------------------------------------------


def bench_fig10_gqa():
    from benchmarks.common import PAPER_HIDDEN, attention_time

    rows = []
    for g in (1, 2, 4, 8):
        kvh = PAPER_HIDDEN // g
        ring = attention_time(128, 1 << 20, a=1, causal=True, kv_hidden=kvh)
        mesh = attention_time(128, 1 << 20, a=None, causal=True, kv_hidden=kvh)
        rows.append(
            {
                "g": g,
                "ring_s": ring["total_s"], "mesh_s": mesh["total_s"],
                "mesh_a": mesh["a"],
                "speedup": ring["total_s"] / mesh["total_s"],
            }
        )
    _save("fig10_gqa", rows)
    _emit(
        "fig10_gqa", 0.0,
        "speedups_g1248=" + "/".join(f"{r['speedup']:.2f}x" for r in rows)
        + " (paper: gains shrink with g)",
    )
    return rows


# ---- Figure 5 / Algorithm 2: schedule quality -----------------------------------


def bench_schedule_quality():
    from benchmarks.common import PAPER_HW
    from repro.core import schedule as S
    from repro.core.am import CommModel
    from repro.core.simulator import make_cost_model, simulate

    comm = CommModel(seq=1 << 20, hidden=4096, n=64)
    cost = make_cost_model(comm, PAPER_HW, causal=True)
    rows = {}
    for name, sched in [
        ("greedy", S.greedy_forward_schedule(8, 8, cost.profile())),
        ("naive_rowfirst", S.naive_forward_schedule(8, 8)),
        ("ring", S.ring_forward_schedule(64)),
        (
            "greedy_concurrent",
            S.greedy_forward_schedule(8, 8, cost.profile(), allow_concurrent_rings=True),
        ),
    ]:
        sim = simulate(sched, cost, comm)
        rows[name] = {
            "total_s": sim.total,
            "exposed_comm_s": sim.exposed_comm,
            "overlap_efficiency": sim.overlap_efficiency,
            "steps": sim.steps,
        }
    _save("fig5_schedule_quality", rows)
    gain = rows["naive_rowfirst"]["total_s"] / rows["greedy"]["total_s"]
    _emit("fig5_schedule_quality", rows["greedy"]["total_s"] * 1e6, f"greedy_vs_naive={gain:.2f}x")
    return rows


# ---- Figure 6: autotuner choices -------------------------------------------------


def bench_fig6_autotune():
    from benchmarks.common import PAPER_HW, TPU_HW
    from repro.core.am import CommModel
    from repro.core.autotune import tune

    rows = []
    t0 = time.perf_counter()
    for hw_name, hw in (("paper", PAPER_HW), ("tpu_v5e", TPU_HW)):
        for n in (16, 64, 256):
            for seq in (1 << 18, 1 << 20):
                plan = tune(CommModel(seq=seq, hidden=4096, n=n), hw, causal=True)
                rows.append({"hw": hw_name, "n": n, "seq": seq, "a": plan.a, "b": plan.b,
                             "total_s": plan.total})
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    _save("fig6_autotune", rows)
    _emit("fig6_autotune", us, "chosen_a=" + "/".join(str(r["a"]) for r in rows))
    return rows


# ---- assigned architectures: tuned tile per arch -----------------------------------


def bench_arch_tiles():
    """The Fig-6 flow applied to every assigned arch's attention geometry on
    the production model axis (n=16): chosen tile + comm vs Ring-Attention."""
    from repro.configs import ALL_ARCHS, get_config
    from repro.core.am import CommModel

    rows = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if cfg.attention_free:
            rows.append({"arch": arch, "a": None, "note": "attention-free (SSD)"})
            continue
        comm = CommModel(
            seq=32768, hidden=cfg.num_heads * cfg.hd, n=16,
            kv_hidden=cfg.num_kv_heads * cfg.hd,
        )
        a = comm.best_a()
        rows.append(
            {
                "arch": arch, "a": a, "b": 16 // a,
                "fwd_bytes_gb": comm.fwd_bytes(a) / 1e9,
                "ring_bytes_gb": comm.ring_fwd_bytes() / 1e9,
                "vs_ring": comm.fwd_bytes(a) / comm.ring_fwd_bytes(),
            }
        )
    _save("arch_tiles", rows)
    picks = "/".join(f"{r['arch'].split('-')[0]}:a{r['a']}" for r in rows if r["a"])
    _emit("arch_tiles", 0.0, picks)
    return rows


# ---- measured: mesh-attention wall time on fake devices ---------------------------


def bench_measured_mesh_attention():
    """Real (CPU, 1-core, 8 fake devices) wall time of the distributed op —
    a smoke-level sanity check that the machinery runs, not a perf claim."""
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    code = r"""
import time, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.dispatch import AttentionPlanConfig, attention_in_shard_map
n=8
mesh = jax.make_mesh((n,), ("sp",))
B,S,H,D = 1, 8*256, 4, 32
q,k,v = (jax.random.normal(kk,(B,S,H,D)) for kk in jax.random.split(jax.random.PRNGKey(0),3))
for a in (1, 2, 4):
    cfg = AttentionPlanConfig(backend="ring" if a == 1 else "mesh", axis_name="sp",
        n=n, a=a, causal=False, block_q=64, block_kv=64)
    f = jax.jit(shard_map(lambda q,k,v: attention_in_shard_map(q,k,v,cfg), mesh=mesh,
        in_specs=(P(None,"sp"),)*3, out_specs=P(None,"sp"), check_vma=False))
    f(q,k,v).block_until_ready()
    t0=time.perf_counter()
    for _ in range(3): o = f(q,k,v)
    o.block_until_ready()
    print(f"a={a}", (time.perf_counter()-t0)/3*1e6)
"""
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600
    )
    if proc.returncode != 0:
        _emit("measured_mesh_attention", 0.0, f"FAILED:{proc.stderr[-200:]}")
        return None
    lines = [l for l in proc.stdout.splitlines() if l.startswith("a=")]
    rows = {l.split()[0]: float(l.split()[1]) for l in lines}
    _save("measured_mesh_attention", rows)
    _emit(
        "measured_mesh_attention", min(rows.values()),
        " ".join(f"{k}:{v:.0f}us" for k, v in rows.items()),
    )
    return rows


# ---- mask pruning: comm volume with/without a document mask ------------------------


def bench_mesh_attention():
    """Segment-masked vs unmasked comm volume on a (2,4) fake-device mesh:
    simulated (event simulator over pruned schedules) AND measured (ppermute
    bytes in the compiled HLO), per commit."""
    from benchmarks.mesh_attention_bench import run_bench

    payload = run_bench()
    _save("mesh_attention_bench", payload)
    sim_red = payload.get("sim_comm_reduction", 0.0)
    meas_red = payload.get("measured_comm_reduction")
    meas = f"{meas_red:.1%}" if meas_red is not None else "n/a"
    _emit(
        "mesh_attention_bench",
        payload.get("measured", {}).get("pruned_wall_us", 0.0),
        f"mask_comm_reduction sim={sim_red:.1%} measured={meas}",
    )
    return payload


# ---- continuous-batching serve throughput/latency ---------------------------------


def bench_serve():
    """Mixed-length arrival trace through the slot-pool engine (reduced
    config): tokens/s + latency percentiles, accumulated per commit."""
    from benchmarks.serve_bench import run_bench

    payload = run_bench("granite-8b", slots=4, requests=8, new_tokens=6)
    _save("serve_bench", payload)
    lat = payload["latency_s"]
    ratio = payload["paged_prefix"]["bytes_per_request_ratio"]
    _emit(
        "serve_bench", payload["wall_s"] / max(payload["ticks"], 1) * 1e6,
        f"tok_per_s={payload['tokens_per_s']:.1f} "
        f"p50={lat['p50']:.3f}s p95={lat['p95']:.3f}s "
        f"paged_bytes_per_req={ratio:.2f}x_dense",
    )
    return payload


# ---- decode kernel: gather vs paged-native split-K --------------------------------


def bench_decode():
    """One decode tick over a paged KV pool, gather vs the native split-K
    kernel, at several depth mixes and pool occupancies: measured tokens/s
    plus modeled HBM bytes/token (depth- vs capacity-proportional)."""
    from benchmarks.decode_bench import run_bench

    payload = run_bench()
    _save("decode_bench", payload)
    half = payload["hbm_bytes_ratio_at_half_occupancy"]
    mesh = payload.get("mesh_engine") or {}
    eq = mesh.get("native_equals_gather_equals_dense")
    rows = payload["op_level"]
    mixed = next(r for r in rows if r["scenario"] == "mixed_depth")
    _emit(
        "decode_bench", mixed["native"]["us_per_tick"],
        f"native_hbm_bytes={half:.2f}x_gather mesh_tokens_eq={eq} "
        f"native_backend={payload['native_backend']}",
    )
    return payload


# ---- roofline table from the dry-run ----------------------------------------------


def bench_roofline_table():
    ddir = os.path.join(RESULTS_DIR, "dryrun")
    if not os.path.isdir(ddir):
        _emit("roofline_table", 0.0, "no-dryrun-results-yet")
        return None
    rows = []
    for fn in sorted(os.listdir(ddir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(ddir, fn)) as f:
            rows.append(json.load(f))
    ok = sum(1 for r in rows if r.get("status") == "ok")
    skip = sum(1 for r in rows if r.get("status") == "skip")
    err = sum(1 for r in rows if r.get("status") == "error")
    _save("roofline_table", rows)
    _emit("roofline_table", 0.0, f"cells ok={ok} skip={skip} error={err}")
    return rows


BENCHES = {
    "table2_comm_volume": bench_table2_comm_volume,
    "table3_throughput": bench_table3_throughput,
    "table4_mfu": bench_table4_mfu,
    "fig8_scaling": bench_fig8_scaling,
    "fig9_breakdown": bench_fig9_breakdown,
    "table5_peak_memory": bench_table5_peak_memory,
    "fig10_gqa": bench_fig10_gqa,
    "fig5_schedule_quality": bench_schedule_quality,
    "fig6_autotune": bench_fig6_autotune,
    "arch_tiles": bench_arch_tiles,
    "measured_mesh_attention": bench_measured_mesh_attention,
    "mesh_attention_bench": bench_mesh_attention,
    "serve_bench": bench_serve,
    "decode_bench": bench_decode,
    "roofline_table": bench_roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    names = args.only or list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name]()


if __name__ == "__main__":
    main()
