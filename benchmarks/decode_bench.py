"""Decode-kernel benchmark: gather-then-dense vs paged-native split-K.

    PYTHONPATH=src python -m benchmarks.decode_bench [--json-out PATH]

Benches ONE decode tick (cache append + flash-decode through
``dispatch.decode_attention_step``) over a paged KV pool at several depth
mixes and pool occupancies, for both kernel variants:

  * ``gather`` — ``paged_cache_gather`` materializes every slot's full
    virtual-capacity view, then the dense band kernel runs over it; HBM
    traffic scales with *capacity*.
  * ``native`` — the split-K Pallas kernel (kernels/paged_decode.py) reads
    the block table in-kernel and touches only allocated, band-visible
    pages; HBM traffic scales with *depth*.

Two quantities per scenario:

  * **modeled HBM bytes/token** — the analytic K/V read volume each variant
    must move per generated token (the paper's data-locality axis; exact by
    construction, hardware-independent).
  * **measured tokens/s** — wall time of the jitted step on the current
    backend.  On CPU CI the native kernel runs in Pallas *interpret* mode, so
    its measured number reflects interpreter overhead, not TPU behavior —
    the JSON carries ``native_backend`` so trajectory readers can tell; the
    modeled bytes are the portable signal.

Every cell also carries an ``int8`` twin: the same pool stored quantized
(1-byte K/V elements + f32 per-(token, kv-head) scales, dequantized in-path)
with its own modeled bytes/token, measured tokens/s, and max |Δoutput| vs
the fp run — ``int8_native_bytes_ratio`` is the storage-traffic headline.

With >= 8 devices a (2, 4)-mesh engine section rides along: the mixed
16/32/64 serve trace, dense vs paged-gather vs paged-native (fp and int8)
tokens/s plus the int8 engine's max per-token |Δlogit| vs the fp engine.
Results accumulate per commit as ``BENCH_decode_bench_<sha>.json`` (CI).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    )

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

# op-level geometry (granite-8b reduced attention head layout)
H, HKV, HD = 4, 2, 32
PAGE_SIZE = 16
MAX_SEQ = 256  # virtual capacity per slot
DTYPE_BYTES = 4  # fp32 pools
SCALE_BYTES = 4  # f32 per-(token, kv-head) scale entries (quantized pools)
# storage bytes per K-or-V element by pool storage mode
KV_DTYPE_BYTES = {"fp": DTYPE_BYTES, "int8": 1, "fp8": 1}

SCENARIOS = [
    # (name, per-slot depths)
    ("shallow_uniform", [32, 32, 32, 32]),
    ("mixed_depth", [16, 32, 64, 128]),
    ("deep_uniform", [224, 224, 224, 224]),
]
OCCUPANCIES = (0.25, 0.5, 1.0)


def pages_for(depth: int, page_size: int = PAGE_SIZE) -> int:
    return -(-depth // page_size)


def modeled_hbm_bytes_per_token(
    kernel: str, depths, max_pages: int, kv_dtype: str = "fp"
) -> float:
    """K/V bytes one decode tick must read per generated token.

    gather: every slot's FULL virtual capacity is materialized from the pool
    (unallocated entries clamp to page 0 but are still moved), then the band
    kernel reads the gathered copy again — capacity-proportional either way;
    the model counts the pool-read side only (the dominant, irreducible term).

    native: only allocated pages whose positions the band admits are DMA'd
    (pl.when-skipped pages keep a constant block index, so their fetches are
    elided) — depth-proportional.

    ``kv_dtype`` sets the storage width: a quantized pool moves 1-byte K/V
    elements plus one f32 scale per (token, kv-head) for each of K and V —
    for HD=32 that is (2*32*1 + 2*4) / (2*32*4) = 72/256 ≈ 0.28x per page.
    """
    elem = KV_DTYPE_BYTES[kv_dtype]
    per_page = PAGE_SIZE * HKV * (HD + HD) * elem  # K + V
    if kv_dtype != "fp":
        per_page += PAGE_SIZE * HKV * 2 * SCALE_BYTES  # K + V scale entries
    if kernel == "gather":
        pages_read = len(depths) * max_pages
    else:
        pages_read = sum(pages_for(d) for d in depths)
    return pages_read * per_page / len(depths)  # one token per slot per tick


def _build_case(rng, depths, occupancy):
    """Allocator-backed pool at the requested occupancy (pages_in_use /
    num_pages), plus the step operands."""
    import jax.numpy as jnp
    import numpy as np

    from repro.serve.kv_pool import PageAllocator, PagedLayout

    max_pages = MAX_SEQ // PAGE_SIZE
    used = sum(pages_for(d) for d in depths)
    num_pages = max(used, int(round(used / occupancy)))
    lay = PagedLayout(num_pages=num_pages, page_size=PAGE_SIZE,
                      max_pages=max_pages, n=1)
    alloc = PageAllocator(lay)
    for slot, d in enumerate(depths):
        alloc.alloc_slot(slot, rng.integers(0, 2**30, (d,), dtype=np.int32), 0)
    B = len(depths)
    k_pool = jnp.asarray(rng.normal(size=(num_pages, PAGE_SIZE, HKV, HD)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(num_pages, PAGE_SIZE, HKV, HD)), jnp.float32)
    bt = jnp.asarray(alloc.device_table(B))
    q = jnp.asarray(rng.normal(size=(B, 1, H, HD)), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(B, 1, HKV, HD)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, 1, HKV, HD)), jnp.float32)
    # overwrite each slot's last token: the target page is always allocated
    # (that is the engine's ensure_append contract)
    pos = jnp.asarray([d - 1 for d in depths], jnp.int32)
    occ = used / num_pages
    return (q, k_new, v_new, k_pool, v_pool, pos, bt), occ, max_pages


def bench_op_level(reps: int = 30, seed: int = 0):
    import jax
    import numpy as np

    from repro.core import dispatch, kv_quant
    from repro.parallel.context import ParallelCtx

    ctx = ParallelCtx()
    rng = np.random.default_rng(seed)
    rows = []
    for name, depths in SCENARIOS:
        for occupancy in OCCUPANCIES:
            operands, occ, max_pages = _build_case(rng, depths, occupancy)
            q, k_new, v_new, k_pool, v_pool, pos, bt = operands
            # int8 twin of the same pool: quantized storage + scale tables
            qk_pool, k_scale = kv_quant.quantize(k_pool, "int8")
            qv_pool, v_scale = kv_quant.quantize(v_pool, "int8")
            row = {
                "scenario": name,
                "depths": depths,
                "occupancy": round(occ, 3),
                "virtual_cap": MAX_SEQ,
            }
            fp_out = {}
            for kernel in ("gather", "native"):
                fn = jax.jit(
                    lambda q, kn, vn, kp, vp, pos, bt, _k=kernel:
                    dispatch.decode_attention_step(
                        q, kn, vn, kp, vp, pos, ctx,
                        block_table=bt, decode_kernel=_k,
                    )
                )
                o, kp2, vp2 = fn(*operands)
                o.block_until_ready()
                t0 = time.perf_counter()
                for _ in range(reps):
                    o, kp2, vp2 = fn(*operands)
                o.block_until_ready()
                wall = (time.perf_counter() - t0) / reps
                fp_out[kernel] = np.asarray(o)
                row[kernel] = {
                    "us_per_tick": wall * 1e6,
                    "tokens_per_s": len(depths) / wall,
                    "hbm_bytes_per_token": modeled_hbm_bytes_per_token(
                        kernel, depths, max_pages
                    ),
                }
                # int8 cell for the same kernel: quantized pool + in-path
                # dequant (in-kernel for native, gather-side for the ref)
                fn_q = jax.jit(
                    lambda q, kn, vn, kp, vp, pos, bt, ks, vs, _k=kernel:
                    dispatch.decode_attention_step(
                        q, kn, vn, kp, vp, pos, ctx,
                        block_table=bt, decode_kernel=_k,
                        k_scale=ks, v_scale=vs,
                    )
                )
                ops_q = (q, k_new, v_new, qk_pool, qv_pool, pos, bt,
                         k_scale, v_scale)
                o_q = fn_q(*ops_q)[0]
                o_q.block_until_ready()
                t0 = time.perf_counter()
                for _ in range(reps):
                    o_q = fn_q(*ops_q)[0]
                o_q.block_until_ready()
                wall_q = (time.perf_counter() - t0) / reps
                row[kernel + "_int8"] = {
                    "us_per_tick": wall_q * 1e6,
                    "tokens_per_s": len(depths) / wall_q,
                    "hbm_bytes_per_token": modeled_hbm_bytes_per_token(
                        kernel, depths, max_pages, kv_dtype="int8"
                    ),
                    "max_abs_err_vs_fp": float(
                        np.max(np.abs(np.asarray(o_q) - fp_out[kernel]))
                    ),
                }
            row["hbm_bytes_ratio"] = (
                row["native"]["hbm_bytes_per_token"]
                / row["gather"]["hbm_bytes_per_token"]
            )
            row["tokens_per_s_ratio"] = (
                row["native"]["tokens_per_s"] / row["gather"]["tokens_per_s"]
            )
            # the quantization headline: int8 native traffic vs fp native
            row["int8_native_bytes_ratio"] = (
                row["native_int8"]["hbm_bytes_per_token"]
                / row["native"]["hbm_bytes_per_token"]
            )
            rows.append(row)
    return rows


def bench_engine_mesh(seed: int = 0, new_tokens: int = 6):
    """(2, 4)-mesh serve-trace tokens/s: dense vs paged-gather vs paged-native
    (requires >= 8 devices; returns None otherwise)."""
    import jax

    if jax.device_count() < 8:
        return None
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    trace = [(16, 0), (32, 1), (64, 2), (16, 4)]
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln, _ in trace]
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)
    out = {}
    tokens = {}
    logits = {}
    for mode, kw in (
        ("dense", {}),
        ("paged_gather", dict(paged=True, page_size=4, decode_kernel="gather")),
        ("paged_native", dict(paged=True, page_size=4, decode_kernel="native")),
        ("paged_native_int8", dict(paged=True, page_size=4,
                                   decode_kernel="native", kv_dtype="int8")),
    ):
        eng = ServeEngine(cfg, params, ctx=ctx, max_seq=128, num_slots=3, **kw)
        # capture per-token logits on the fp reference and the int8 engine so
        # the quantization error lands in the per-commit JSON
        eng.capture_logits = mode in ("dense", "paged_native_int8")

        def submit():
            base = eng._tick
            return [
                eng.submit(p, max_new_tokens=new_tokens, arrival_tick=base + t)
                for p, (_, t) in zip(prompts, trace)
            ]

        rids = submit()
        eng.run()  # warm every (bucket, k) prefill + the decode trace
        tokens[mode] = [eng._finished[r].generated for r in rids]
        if eng.capture_logits:
            logits[mode] = [eng.debug_logits[r] for r in rids]
        base_tick = eng._tick
        submit()
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        total = len(prompts) * new_tokens
        out[mode] = {
            "tokens_per_s": total / wall,
            "ticks": eng._tick - base_tick,
            "wall_s": wall,
        }
    out["native_equals_gather_equals_dense"] = (
        tokens["paged_native"] == tokens["paged_gather"] == tokens["dense"]
    )
    out["int8_tokens_equal_fp"] = tokens["paged_native_int8"] == tokens["dense"]
    out["int8_max_logit_err_vs_fp"] = max(
        float(np.max(np.abs(a - b)))
        for fp_rows, q_rows in zip(logits["dense"], logits["paged_native_int8"])
        for a, b in zip(fp_rows, q_rows)
    )
    return out


def run_bench(seed: int = 0, reps: int = 30):
    import jax

    rows = bench_op_level(reps=reps, seed=seed)
    half = [r for r in rows if r["occupancy"] <= 0.55 and r["occupancy"] >= 0.3]
    payload = {
        "geometry": {
            "heads": H, "kv_heads": HKV, "head_dim": HD,
            "page_size": PAGE_SIZE, "virtual_cap": MAX_SEQ,
            "dtype_bytes": DTYPE_BYTES,
            "kv_dtype_bytes": KV_DTYPE_BYTES, "scale_bytes": SCALE_BYTES,
        },
        "op_level": rows,
        "native_backend": (
            "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"
        ),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        # headline: at <= 50% occupancy the native kernel's modeled traffic
        # follows depth while gather pays full virtual capacity per row
        "hbm_bytes_ratio_at_half_occupancy": (
            sum(r["hbm_bytes_ratio"] for r in half) / len(half) if half else None
        ),
        # quantization headline: int8 native storage traffic vs fp native —
        # identical at every cell by construction (both scale with depth),
        # reported per row too so CI can gate each occupancy cell
        "int8_native_bytes_ratio": max(r["int8_native_bytes_ratio"] for r in rows),
        "int8_max_abs_err": max(
            r[k + "_int8"]["max_abs_err_vs_fp"]
            for r in rows for k in ("gather", "native")
        ),
    }
    mesh_section = bench_engine_mesh(seed=seed)
    if mesh_section is not None:
        payload["mesh_engine"] = mesh_section
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=30)
    ap.add_argument("--json-out", default=os.path.join(RESULTS_DIR, "decode_bench.json"))
    args = ap.parse_args(argv)
    payload = run_bench(reps=args.reps)
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=1)
    print(json.dumps({
        "hbm_bytes_ratio_at_half_occupancy": payload["hbm_bytes_ratio_at_half_occupancy"],
        "int8_native_bytes_ratio": payload["int8_native_bytes_ratio"],
        "int8_max_abs_err": payload["int8_max_abs_err"],
        "native_backend": payload["native_backend"],
        "mesh_engine": payload.get("mesh_engine"),
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
