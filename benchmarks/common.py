"""Shared benchmark machinery.

The paper evaluates on a 256-GPU cluster (32 heads x head_dim 128 = hidden
4096).  This container has no TPU/GPU fabric, so the paper-table benchmarks
drive the SAME schedules the distributed op executes through the calibrated
lock-step simulator (core/simulator.py).  ``PAPER_HW`` is an H800-class
communication-bound profile chosen to match the paper's §2.2 observation
(Ring-Attention waits on comm ~91.5% of the time at 128 GPUs / 1M tokens);
``TPU_HW`` is the v5e roofline model used everywhere else in the repo.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import schedule as S
from repro.core.am import CommModel
from repro.core.autotune import plan_for, tune
from repro.core.simulator import HardwareModel, make_cost_model, simulate

PAPER_HIDDEN = 4096  # 32 heads x 128 (paper §4.1)
# H800-class chips on a commodity fabric with NCCL launch latency.  NOTE
# (EXPERIMENTS.md §Paper-validation): the paper's own anchors — ring waiting
# 91.5% (§2.2), mesh comm share 86.6% (§4.4), 85.4% volume reduction (§4.5),
# max speedup 3.4x (Table 3) — are mutually inconsistent under ANY uniform-
# bandwidth lock-step model (the first three imply ~7-8x).  We calibrate
# moderately and validate TRENDS; the deepest comm-bound cells realize more
# of the theoretical sqrt(n) gain here than on the paper's congested fabric.
PAPER_HW = HardwareModel(peak_flops=989e12, link_bw=25e9, attn_efficiency=0.35,
                         latency=100e-6)
TPU_HW = HardwareModel()  # v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s/link


def attention_time(
    n: int,
    seq: int,
    *,
    a: Optional[int] = None,  # None -> autotuned; 1 -> Ring-Attention
    causal: bool = True,
    hw: HardwareModel = PAPER_HW,
    kv_hidden: Optional[int] = None,
    with_backward: bool = True,
    allow_concurrent_rings: bool = False,
) -> Dict:
    comm = CommModel(seq=seq, hidden=PAPER_HIDDEN, n=n, kv_hidden=kv_hidden)
    if a is None:
        plan = tune(comm, hw, causal=causal, with_backward=with_backward,
                    allow_concurrent_rings=allow_concurrent_rings)
    else:
        plan = plan_for(comm, a, hw, causal=causal, with_backward=with_backward,
                        allow_concurrent_rings=allow_concurrent_rings)
    fwd, bwd = plan.fwd_sim, plan.bwd_sim
    total = plan.total
    comm_bytes = plan.comm_bytes
    compute = fwd.compute + (bwd.compute if bwd else 0.0)
    exposed = fwd.exposed_comm + (bwd.exposed_comm if bwd else 0.0)
    return {
        "a": plan.a,
        "b": plan.b,
        "total_s": total,
        "fwd_s": fwd.total,
        "bwd_s": bwd.total if bwd else 0.0,
        "compute_s": compute,
        "exposed_comm_s": exposed,
        "comm_bytes": comm_bytes,
        "iters_per_s": 1.0 / total,
    }


def attention_flops(seq: int, causal: bool) -> float:
    """Model FLOPs of one fwd+bwd attention call (batch 1)."""
    f = 4.0 * seq * seq * PAPER_HIDDEN * (1 + 2.5)
    return f * (0.5 if causal else 1.0)


def mfu(n: int, seq: int, total_s: float, causal: bool, hw: HardwareModel = PAPER_HW) -> float:
    return attention_flops(seq, causal) / (total_s * n * hw.peak_flops)
