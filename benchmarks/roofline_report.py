"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_report [--tag SUFFIX]

Prints markdown; EXPERIMENTS.md embeds the output.
"""

from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

_NOTES = {
    ("train", "compute"): "near compute roofline; push flash-block utilization / reduce remat recompute",
    ("train", "memory"): "cut op-level traffic: fused flash blocks (TPU kernel), remat policy saving matmul outputs, bf16 end-to-end",
    ("train", "collective"): "restructure gradient/MoE reductions (reduce-scatter instead of all-reduce; combine before reducing)",
    ("prefill", "memory"): "prefill has no backward: drop remat (halves param gathers) and keep scores fused in the flash kernel",
    ("prefill", "collective"): "shrink ring payloads (GQA-aware tile, latent-wire KV for MLA) / overlap with compute",
    ("prefill", "compute"): "raise MXU utilization of the block kernel",
    ("decode", "memory"): "decode is weight/cache-bandwidth bound by nature: shrink bytes (quantized cache, fused decode kernel)",
    ("decode", "collective"): "batch the per-token psums across layers",
    ("decode", "compute"): "unexpected for decode; inspect HLO",
}


def load(tag: str = ""):
    rows = []
    for fn in sorted(os.listdir(RESULTS)):
        if not fn.endswith(f"{tag}.json"):
            continue
        base = fn[: -len(".json")]
        parts = base.split("__")
        if len(parts) != 3 or (tag and not parts[2].endswith(tag)):
            continue
        if not tag and (parts[2] not in ("single", "multi")):
            continue
        with open(os.path.join(RESULTS, fn)) as f:
            rows.append(json.load(f))
    return rows


def fmt(rows, mesh="single"):
    from repro.configs import SHAPES

    print(f"\n### Roofline table — {mesh}-pod mesh "
          f"({'2x16x16 = 512' if mesh == 'multi' else '16x16 = 256'} chips)\n")
    print("| arch | shape | status | compute (s) | memory (s) | collective (s) | dominant | "
          "MODEL_FLOPS | useful/HLO | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — | — | "
                  f"{r['reason'].split(';')[0]} |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — | — | — | {r.get('error','')[:60]} |")
            continue
        rl = r["roofline"]
        kind = SHAPES[r["shape"]].kind
        note = _NOTES.get((kind, rl["dominant"]), "")
        print(
            f"| {r['arch']} | {r['shape']} | ok | {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | **{rl['dominant']}** | {rl.get('model_flops',0):.2e} "
            f"| {rl.get('useful_flops_ratio',0):.3f} | {note} |"
        )


def fmt_dryrun(rows):
    print("\n### Dry-run compile results (per cell)\n")
    print("| arch | shape | mesh | status | lower (s) | compile (s) | "
          "flops/device | bytes/device | collective B/device (total) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — | — | — | — |")
            continue
        cb = r.get("collective_bytes_per_device", {}).get("total", 0)
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | {r.get('lower_s','—')} "
            f"| {r.get('compile_s','—')} | {r.get('flops_per_device',0):.3e} "
            f"| {r.get('bytes_per_device',0):.3e} | {cb:.3e} |"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--section", default="all", choices=["all", "roofline", "dryrun"])
    args = ap.parse_args()
    rows = load(args.tag)
    if args.section in ("all", "dryrun"):
        fmt_dryrun(rows)
    if args.section in ("all", "roofline"):
        fmt(rows, "single")
        fmt(rows, "multi")


if __name__ == "__main__":
    main()
