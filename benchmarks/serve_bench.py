"""Continuous-batching serve benchmark: tokens/s + latency percentiles.

    PYTHONPATH=src python -m benchmarks.serve_bench [--arch granite-8b] \
        [--slots 4] [--requests 12] [--new-tokens 8] [--json-out PATH]

Replays a mixed-length arrival trace through the slot-pool engine (reduced
config, current backend — a smoke-level trajectory number on CPU CI, a real
measurement on accelerators) and writes JSON next to the table-2 results in
``benchmarks/results/serve_bench.json`` so the perf trajectory accumulates
per commit (same convention as ``table2_comm_volume.json``).

Four comparison sections ride along in the payload:

  * ``pack_planner`` — the same bursty trace under the greedy vs the
    bin-packing ``Scheduler.pack_groups`` planner: padded prefill tokens and
    TTFT percentiles, plus the deltas.
  * ``paged_prefix`` — a shared-prefix trace (every request opens with the
    same system prompt) on the dense vs the PAGED engine: attention-cache
    bytes per request (dense: the fixed slot pool; paged: peak resident
    pages) and TTFT, with the allocator's sharing counters.
  * ``continuous_prefill`` — a bursty long-prompt trace (one long prompt
    arriving while short requests decode) under one-shot vs chunked
    (``ServeConfig.prefill_chunk`` + ``tick_token_budget``) prefill:
    per-tick wall times give real inter-token latency percentiles for the
    short requests, reported as multiples of a quiet (no-burst) trace.
    ``--check-bursty-p95 MULT`` exits nonzero if the chunked bursty p95
    exceeds MULT x the quiet p95 — the CI latency-bound gate.
  * ``speculative`` — spec_k ∈ {0, 2, 4} on a repetitive trace (greedy
    decode loops, prompt-lookup drafts accepted: tokens/s multiplies) and a
    random trace (drafts rejected, per-slot drafting suspends via
    ``spec_max_misses``: tokens/s stays ~baseline), with inter-token
    percentiles and acceptance/rollback counters per cell.
  * ``robustness`` — the bursty trace on a deliberately tight page pool at
    ``oversubscribe`` ∈ {1.0, 1.5, 2.0}: tokens/s, completed-request
    throughput, and preemption/recompute counts per cell.  Conservative
    admission (1.0) serializes on worst-case reservations; oversubscribed
    admission trades preempt-and-recompute work for occupancy.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    )

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")


def _pct(sorted_vals, p):
    if not sorted_vals:
        return None
    i = min(len(sorted_vals) - 1, int(round(p / 100 * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def _replay(eng, prompts, arrivals, new_tokens, before_timed=None):
    """Submit a trace twice (warmup compiles outside the timed region), time
    the second pass, and return (requests, ticks, wall_s).  ``before_timed``
    runs between the passes — snapshot engine/allocator counters there so
    reported stats cover the timed trace only, not the warmup too."""
    import time

    def submit():
        base = eng._tick
        return [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=base + t)
            for p, t in zip(prompts, arrivals)
        ]

    submit()
    eng.run()
    if before_timed is not None:
        before_timed()
    base_tick = eng._tick
    rids = submit()
    t0 = time.perf_counter()
    while eng.has_work:
        eng.step()
    wall = time.perf_counter() - t0
    return [eng._finished[r] for r in rids], eng._tick - base_tick, wall


def _ttft(reqs, tick_s):
    vals = sorted((r.first_token_tick - r.arrival_tick + 1) * tick_s for r in reqs)
    return {"p50": _pct(vals, 50), "p95": _pct(vals, 95)}


def _replay_ticks(eng, prompts, arrivals, new_tokens, waves=1):
    """Like ``_replay`` but records per-tick wall times so inter-token
    latency can be measured rather than averaged.  Returns
    (requests, walls, base_tick): ``walls[i]`` is the wall time of absolute
    tick ``base_tick + i``.

    ``waves > 1`` replays the identical trace that many times after warmup
    and keeps the fastest replay (smallest total wall): each wave is the
    same deterministic workload, so min-wall filters scheduler stalls and
    CPU-frequency dips that would otherwise make single-wave cells noisy."""
    import time

    def submit():
        base = eng._tick
        return [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=base + t)
            for p, t in zip(prompts, arrivals)
        ]

    submit()
    eng.run()  # warmup: compiles every launch shape the timed pass hits
    best = None
    for _ in range(max(1, waves)):
        base = eng._tick
        rids = submit()
        walls = []
        while eng.has_work:
            t0 = time.perf_counter()
            eng.step()
            walls.append(time.perf_counter() - t0)
        run = ([eng._finished[r] for r in rids], walls, base)
        if best is None or sum(walls) < sum(best[1]):
            best = run
    return best


def _inter_token_gaps(reqs, walls, base):
    """Wall-clock gap between consecutive tokens of each request: the sum of
    tick walls from just after the earlier token's tick through the later
    token's tick."""
    gaps = []
    for r in reqs:
        ticks = [t - base for t in r.token_ticks]
        for a, b in zip(ticks, ticks[1:]):
            gaps.append(sum(walls[a + 1:b + 1]))
    return sorted(gaps)


def bench_continuous_prefill(
    cfg, params, *, seed=0, new_tokens=16, long_len=512, chunk=64, budget=96
):
    """Bursty long-prompt trace: short requests decode steadily while one
    ``long_len``-token prompt arrives mid-stream.  Three engines:

      * ``quiet``    — short requests only: the inter-token latency baseline.
      * ``one_shot`` — the burst prefilled in a single launch: every short
        request sees a latency spike proportional to the prompt length.
      * ``chunked``  — continuous prefill: the burst ingests ``chunk`` tokens
        per tick under ``budget``, so no tick's launch scales with the
        prompt and the spike is bounded.

    The headline numbers are the bursty p95 inter-token latencies as
    multiples of the quiet p95, plus the long request's TTFT in ticks and
    decode throughput under each engine."""
    import numpy as np

    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(seed)
    max_seq = long_len + new_tokens + 16
    shorts = [rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
              for _ in range(6)]
    long_prompt = rng.integers(0, cfg.vocab_size, (long_len,), dtype=np.int32)
    short_arrivals = [0, 0, 2, 4, 6, 8]
    burst_prompts = shorts + [long_prompt]
    burst_arrivals = short_arrivals + [4]

    configs = {
        "quiet": (ServeConfig(max_seq=max_seq, num_slots=3),
                  shorts, short_arrivals),
        "one_shot": (ServeConfig(max_seq=max_seq, num_slots=3),
                     burst_prompts, burst_arrivals),
        "chunked": (ServeConfig(max_seq=max_seq, num_slots=3,
                                prefill_chunk=chunk, tick_token_budget=budget),
                    burst_prompts, burst_arrivals),
    }
    out = {"long_len": long_len, "chunk": chunk, "tick_token_budget": budget}
    for name, (serve, prompts, arrivals) in configs.items():
        eng = ServeEngine(cfg, params, serve=serve)
        reqs, walls, base = _replay_ticks(eng, prompts, arrivals, new_tokens)
        short_reqs = [r for r in reqs if len(r.prompt) < long_len]
        gaps = _inter_token_gaps(short_reqs, walls, base)
        decode_tokens = sum(len(r.generated) for r in reqs)
        wall = sum(walls)
        section = {
            "ticks": len(walls),
            "wall_s": wall,
            "inter_token_s": {"p50": _pct(gaps, 50), "p95": _pct(gaps, 95)},
            "tick_wall_max_s": max(walls) if walls else None,
            "decode_tokens_per_s": decode_tokens / max(wall, 1e-9),
        }
        long_reqs = [r for r in reqs if len(r.prompt) >= long_len]
        if long_reqs:
            section["long_ttft_ticks"] = long_reqs[0].ttft_ticks
            section["long_chunks"] = long_reqs[0].chunks
        if name == "chunked":
            stats = eng.tick_stats()
            n = len(walls)
            section["tick_prefill_tokens"] = stats["prefill_tokens"][-n:]
            section["tick_decode_tokens"] = stats["decode_tokens"][-n:]
        out[name] = section
    quiet_p95 = out["quiet"]["inter_token_s"]["p95"] or 1e-9
    for name in ("one_shot", "chunked"):
        out[name]["inter_token_p95_vs_quiet"] = (
            (out[name]["inter_token_s"]["p95"] or 0.0) / quiet_p95
        )
    return out


def bench_speculative(
    cfg, *, weight_seed=5, seed=0, slots=4, new_tokens=256, max_seq=320,
    spec_ks=(0, 2, 4),
):
    """Speculative decode grid: spec_k x {repetitive, random} traces on the
    PAGED engine (so page-level rollback is exercised and counted).

      * ``repetitive`` — every prompt is a constant token run, and the
        reduced model's greedy decode settles into short verbatim loops that
        prompt-lookup drafting predicts: the high-acceptance regime.  Runs
        with ``spec_max_misses=None`` (the trace never goes permanently
        cold, so suspension would only cut the win).
      * ``random``     — i.i.d. random prompts: the low-acceptance regime.
        Runs with the default miss cap so per-slot drafting suspends after
        a few dry verify ticks and throughput degrades to ~baseline
        instead of paying a verify launch every tick.

    Weights come from a section-local seed: acceptance on a RANDOM-INIT
    reduced model depends on which weight draw's greedy decode happens to
    loop, and this section measures the engine's commit win at a given
    acceptance rate, not model quality — so it pins a draw whose decode is
    sustainably repetitive (~0.7 acceptance at spec_k=4).

    Per cell: decode tokens/s, inter-token p50/p95 (multi-token commits land
    same-tick, so accepted tokens show a 0-gap), acceptance + rollback
    counters; per trace: tokens/s as a multiple of that trace's spec_k=0
    baseline.

    Timing protocol: each trace's cells run in ROUNDS — one timed replay
    per spec_k, round-robin, repeated ``rounds`` times on long-lived
    engines — and the headline ratio is the MEDIAN of the per-round
    ``tokens/s(k) / tokens/s(k0)``.  Host-load drift on a shared CPU moves
    whole rounds, not single cells, so ratios taken within a round are
    stable where a once-per-cell measurement can swing tens of percent."""
    import jax
    import numpy as np

    from repro.models import transformer as tfm
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    rounds = 5
    params = tfm.init_params(cfg, jax.random.PRNGKey(weight_seed))
    rng = np.random.default_rng(seed)
    traces = {
        "repetitive": ([np.full(32, 7, np.int32) for _ in range(slots)], None),
        "random": ([rng.integers(1, cfg.vocab_size, (32,), dtype=np.int32)
                    for _ in range(slots)], 4),
    }
    out = {"spec_ks": list(spec_ks), "new_tokens": new_tokens, "rounds": rounds}
    for trace, (prompts, max_misses) in traces.items():
        section = {"spec_max_misses": max_misses}
        engines = {
            k: ServeEngine(cfg, params, serve=ServeConfig(
                max_seq=max_seq, num_slots=slots, paged=True,
                spec_k=k, spec_max_misses=max_misses,
            ))
            for k in spec_ks
        }
        runs = {k: [] for k in spec_ks}  # per round: (tps, reqs, walls, base)
        for _ in range(rounds):
            for k in spec_ks:
                reqs, walls, base = _replay_ticks(
                    engines[k], prompts, [0] * len(prompts), new_tokens
                )
                tokens = sum(len(r.generated) for r in reqs)
                runs[k].append((tokens / max(sum(walls), 1e-9), reqs, walls, base))
        for k in spec_ks:
            tps, reqs, walls, base = max(runs[k], key=lambda r: r[0])
            gaps = _inter_token_gaps(reqs, walls, base)
            stats = engines[k].kv_cache_stats()
            section[f"k{k}"] = {
                "ticks": len(walls),
                "wall_s": sum(walls),
                "tokens_per_s": tps,
                "inter_token_s": {"p50": _pct(gaps, 50), "p95": _pct(gaps, 95)},
                "spec_accept_rate": stats["spec_accept_rate"],
                "spec_proposed": stats["spec_proposed"],
                "spec_accepted": stats["spec_accepted"],
                "spec_rolled_back_pages": stats["spec_rolled_back_pages"],
                "verify_launches": stats["verify_launches"],
            }
        k0 = spec_ks[0]
        for k in spec_ks[1:]:
            ratios = sorted(
                sk[0] / max(s0[0], 1e-9)
                for sk, s0 in zip(runs[k], runs[k0])
            )
            section[f"k{k}"]["tokens_per_s_vs_k0"] = ratios[len(ratios) // 2]
        out[trace] = section
    return out


def bench_pack_planner(cfg, params, *, seed=0, new_tokens=4, max_seq=128):
    """Bursty trace (same-tick admission waves of mixed short lengths) under
    the greedy vs the bin-packing pack planner: TTFT + padded prefill cost."""
    import numpy as np

    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(seed)
    # bursts crafted around bucket boundaries: greedy admission-order packing
    # crams across them, binpack snaps groups to boundaries
    lengths = [9, 8, 16, 30, 17, 15, 9, 8]
    arrivals = [0, 0, 0, 3, 3, 3, 6, 6]
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln in lengths]
    out = {}
    real_tokens = sum(lengths)
    for plan in ("greedy", "binpack"):
        eng = ServeEngine(
            cfg, params,
            serve=ServeConfig(max_seq=max_seq, num_slots=4, pack_plan=plan),
        )
        snap = {}

        def before_timed():
            snap.update(launches=eng.prefill_launches,
                        tokens=eng.prefill_launch_tokens)

        reqs, ticks, wall = _replay(
            eng, prompts, arrivals, new_tokens, before_timed=before_timed
        )
        tick_s = wall / max(ticks, 1)
        padded = eng.prefill_launch_tokens - snap["tokens"]
        out[plan] = {
            "ttft_s": _ttft(reqs, tick_s),
            "ticks": ticks,
            "prefill_launches": eng.prefill_launches - snap["launches"],
            "padded_prefill_tokens": padded,
            "prefill_utilization": real_tokens / max(padded, 1),
        }
    g, b = out["greedy"]["ttft_s"]["p50"], out["binpack"]["ttft_s"]["p50"]
    out["ttft_p50_delta_s"] = (g or 0) - (b or 0)  # >0: binpack faster
    out["padded_tokens_saved"] = (
        out["greedy"]["padded_prefill_tokens"] - out["binpack"]["padded_prefill_tokens"]
    )
    return out


def bench_paged_prefix(cfg, params, *, seed=0, requests=6, new_tokens=4, max_seq=128):
    """Shared-prefix trace: every request opens with the same 32-token system
    prompt.  Dense vs paged engine: cache bytes per request + TTFT."""
    import numpy as np

    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    prompts = [
        np.concatenate(
            [prefix, rng.integers(0, cfg.vocab_size, (int(rng.choice([8, 16])),),
                                  dtype=np.int32)]
        )
        for _ in range(requests)
    ]
    arrivals = [i // 2 for i in range(requests)]
    out = {}
    for mode in ("dense", "paged"):
        kw = dict(paged=True, page_size=8) if mode == "paged" else {}
        eng = ServeEngine(
            cfg, params,
            serve=ServeConfig(max_seq=max_seq, num_slots=4, **kw),
        )
        snap = {}

        def before_timed():
            if eng.allocator is not None:
                snap.update(eng.allocator.stats())

        reqs, ticks, wall = _replay(
            eng, prompts, arrivals, new_tokens, before_timed=before_timed
        )
        tick_s = wall / max(ticks, 1)
        stats = eng.kv_cache_stats()
        resident = stats.get("peak_page_bytes", stats["cache_bytes"])
        out[mode] = {
            "ttft_s": _ttft(reqs, tick_s),
            "ticks": ticks,
            "cache_bytes": stats["cache_bytes"],
            "resident_cache_bytes": resident,
            "cache_bytes_per_request": resident / requests,
            **(
                # counters accumulate over warmup + timed: report the timed
                # trace's deltas only
                {k: stats[k] - snap[k] for k in
                 ("shared_hits", "fresh_allocs", "cow_copies")}
                if mode == "paged" else {}
            ),
        }
    d, p = out["dense"], out["paged"]
    out["bytes_per_request_ratio"] = (
        p["cache_bytes_per_request"] / max(d["cache_bytes_per_request"], 1.0)
    )
    return out


def bench_robustness(
    cfg, params, *, seed=0, requests=8, new_tokens=8, max_seq=128,
):
    """Bursty trace on a deliberately TIGHT page pool at oversubscribe ∈
    {1.0, 1.5, 2.0}.  At 1.0 admission books worst-case lifetime pages, so
    the tight pool serializes the burst; above 1.0 admission books prompt
    pages + margin and resolves mid-decode exhaustion by preempting and
    recomputing — per cell: tokens/s, completed-requests/s, and the
    preemption/recompute counters that price the trade."""
    import numpy as np

    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(seed)
    lengths = [int(rng.choice([32, 48, 64])) for _ in range(requests)]
    prompts = [
        rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln in lengths
    ]
    # bursty: everything lands within the first two ticks
    arrivals = [i % 2 for i in range(requests)]
    out = {}
    for factor in (1.0, 1.5, 2.0):
        eng = ServeEngine(
            cfg, params,
            serve=ServeConfig(
                max_seq=max_seq, num_slots=4, paged=True, page_size=8,
                num_pages=24, prefill_chunk=32, oversubscribe=factor,
            ),
        )
        snap = {}

        def before_timed():
            snap["preemptions"] = eng.preemptions
            snap["recompute_tokens"] = eng.recompute_tokens

        reqs, ticks, wall = _replay(
            eng, prompts, arrivals, new_tokens, before_timed=before_timed
        )
        done = [r for r in reqs if r.status == "ok"]
        tokens = sum(len(r.generated) for r in done)
        out[f"oversubscribe_{factor}"] = {
            "tokens_per_s": tokens / max(wall, 1e-9),
            "completed_requests": len(done),
            "completed_per_s": len(done) / max(wall, 1e-9),
            "ticks": ticks,
            "preemptions": eng.preemptions - snap["preemptions"],
            "recompute_tokens": eng.recompute_tokens - snap["recompute_tokens"],
            "statuses": sorted(r.status for r in reqs),
        }
    return out


def run_bench(
    arch: str = "granite-8b",
    *,
    slots: int = 4,
    requests: int = 12,
    new_tokens: int = 8,
    max_seq: int = 128,
    seed: int = 0,
    long_len: int = 512,
    prefill_chunk: int = 64,
    tick_token_budget: int = 96,
):
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config(arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    eng = ServeEngine(cfg, params,
                      serve=ServeConfig(max_seq=max_seq, num_slots=slots))

    rng = np.random.default_rng(seed)
    lengths = [int(rng.choice([16, 32, 64])) for _ in range(requests)]
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln in lengths]

    def submit_trace():
        """Paired arrivals keep admission interleaved with decode (mixed-depth
        slots) and exercise the packed (bucket, k) prefill paths."""
        base = eng._tick
        return [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=base + i // 2)
            for i, p in enumerate(prompts)
        ]

    # warm the jit caches OUTSIDE the timed region by replaying the exact
    # trace once: compiles every (bucket, pack-size) prefill the timed run
    # will hit, plus the shared decode step
    submit_trace()
    eng.run()

    base_tick = eng._tick
    rids = submit_trace()

    t0 = time.perf_counter()
    while eng.has_work:
        eng.step()
    total_wall = time.perf_counter() - t0

    reqs = [eng._finished[rid] for rid in rids]
    total_tokens = sum(len(r.generated) for r in reqs)
    # tick-driven replay: per-request latency = tick span x measured mean
    # tick time (arrival-to-finish for end-to-end, arrival-to-first-token
    # for TTFT); on a real clock-driven server these become wall timestamps
    ticks = eng._tick - base_tick  # warmup ticks are outside the timed region
    tick_s = total_wall / max(ticks, 1)
    lat = sorted((r.finish_tick - r.arrival_tick + 1) * tick_s for r in reqs)
    ttft = sorted((r.first_token_tick - r.arrival_tick + 1) * tick_s for r in reqs)
    payload = {
        "arch": arch,
        "slots": slots,
        "requests": requests,
        "new_tokens": new_tokens,
        "prompt_lengths": lengths,
        "ticks": ticks,
        "wall_s": total_wall,
        "tokens_total": total_tokens,
        "tokens_per_s": total_tokens / max(total_wall, 1e-9),
        "latency_s": {"p50": _pct(lat, 50), "p95": _pct(lat, 95)},
        "first_token_s": {"p50": _pct(ttft, 50), "p95": _pct(ttft, 95)},
        "prefill_traces": {str(k): v for k, v in eng.prefill_trace_counts.items()},
        "decode_traces": eng.decode_trace_count,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }
    # packing and the paged cache serve attention-only decoder archs: the
    # comparison sections skip SSM/encoder/frontend configs instead of
    # crashing the whole benchmark
    if cfg.ssm is None and not cfg.encoder_layers and cfg.frontend is None:
        payload["pack_planner"] = bench_pack_planner(
            cfg, params, seed=seed, max_seq=max_seq
        )
        payload["paged_prefix"] = bench_paged_prefix(
            cfg, params, seed=seed, max_seq=max_seq
        )
        payload["continuous_prefill"] = bench_continuous_prefill(
            cfg, params, seed=seed, long_len=long_len,
            chunk=prefill_chunk, budget=tick_token_budget,
        )
        payload["speculative"] = bench_speculative(cfg, seed=seed)
        payload["robustness"] = bench_robustness(
            cfg, params, seed=seed, max_seq=max_seq
        )
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--long-len", type=int, default=512,
                    help="burst prompt length for the continuous_prefill section")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="chunk size for the continuous_prefill section")
    ap.add_argument("--tick-token-budget", type=int, default=96,
                    help="per-tick token budget for the continuous_prefill section")
    ap.add_argument("--check-bursty-p95", type=float, default=None, metavar="MULT",
                    help="exit nonzero if the chunked bursty p95 inter-token "
                         "latency exceeds MULT x the quiet-trace p95")
    ap.add_argument("--json-out", default=os.path.join(RESULTS_DIR, "serve_bench.json"))
    args = ap.parse_args(argv)
    payload = run_bench(
        args.arch, slots=args.slots, requests=args.requests,
        new_tokens=args.new_tokens, max_seq=args.max_seq,
        long_len=args.long_len, prefill_chunk=args.prefill_chunk,
        tick_token_budget=args.tick_token_budget,
    )
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=1)
    summary = {k: payload[k] for k in
               ("tokens_per_s", "latency_s", "first_token_s", "ticks")}
    if "pack_planner" in payload:
        summary["pack_ttft_p50_delta_s"] = payload["pack_planner"]["ttft_p50_delta_s"]
        summary["paged_bytes_per_request_ratio"] = (
            payload["paged_prefix"]["bytes_per_request_ratio"]
        )
    if "continuous_prefill" in payload:
        cp = payload["continuous_prefill"]
        summary["bursty_p95_vs_quiet"] = {
            "one_shot": cp["one_shot"]["inter_token_p95_vs_quiet"],
            "chunked": cp["chunked"]["inter_token_p95_vs_quiet"],
        }
    if "speculative" in payload:
        sp = payload["speculative"]
        summary["spec_tokens_per_s_vs_k0"] = {
            trace: {f"k{k}": round(sp[trace][f"k{k}"]["tokens_per_s_vs_k0"], 3)
                    for k in sp["spec_ks"][1:]}
            for trace in ("repetitive", "random")
        }
        summary["spec_accept_rate_k4"] = {
            trace: sp[trace]["k4"]["spec_accept_rate"]
            for trace in ("repetitive", "random")
        }
    print(json.dumps(summary))
    if args.check_bursty_p95 is not None:
        if "continuous_prefill" not in payload:
            print(f"check-bursty-p95: arch {args.arch!r} skips the "
                  "continuous_prefill section", file=sys.stderr)
            return 1
        ratio = payload["continuous_prefill"]["chunked"]["inter_token_p95_vs_quiet"]
        if ratio > args.check_bursty_p95:
            print(f"check-bursty-p95: chunked bursty p95 is {ratio:.2f}x the "
                  f"quiet p95 (bound: {args.check_bursty_p95:.2f}x)",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
