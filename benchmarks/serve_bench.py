"""Continuous-batching serve benchmark: tokens/s + latency percentiles.

    PYTHONPATH=src python -m benchmarks.serve_bench [--arch granite-8b] \
        [--slots 4] [--requests 12] [--new-tokens 8] [--json-out PATH]

Replays a mixed-length arrival trace through the slot-pool engine (reduced
config, current backend — a smoke-level trajectory number on CPU CI, a real
measurement on accelerators) and writes JSON next to the table-2 results in
``benchmarks/results/serve_bench.json`` so the perf trajectory accumulates
per commit (same convention as ``table2_comm_volume.json``).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    )

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")


def _pct(sorted_vals, p):
    if not sorted_vals:
        return None
    i = min(len(sorted_vals) - 1, int(round(p / 100 * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def run_bench(
    arch: str = "granite-8b",
    *,
    slots: int = 4,
    requests: int = 12,
    new_tokens: int = 8,
    max_seq: int = 128,
    seed: int = 0,
):
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.serve.engine import ServeEngine

    cfg = get_config(arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    eng = ServeEngine(cfg, params, max_seq=max_seq, num_slots=slots)

    rng = np.random.default_rng(seed)
    lengths = [int(rng.choice([16, 32, 64])) for _ in range(requests)]
    prompts = [rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln in lengths]

    def submit_trace():
        """Paired arrivals keep admission interleaved with decode (mixed-depth
        slots) and exercise the packed (bucket, k) prefill paths."""
        base = eng._tick
        return [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=base + i // 2)
            for i, p in enumerate(prompts)
        ]

    # warm the jit caches OUTSIDE the timed region by replaying the exact
    # trace once: compiles every (bucket, pack-size) prefill the timed run
    # will hit, plus the shared decode step
    submit_trace()
    eng.run()

    base_tick = eng._tick
    rids = submit_trace()

    t0 = time.perf_counter()
    while eng.has_work:
        eng.step()
    total_wall = time.perf_counter() - t0

    reqs = [eng._finished[rid] for rid in rids]
    total_tokens = sum(len(r.generated) for r in reqs)
    # tick-driven replay: per-request latency = tick span x measured mean
    # tick time (arrival-to-finish for end-to-end, arrival-to-first-token
    # for TTFT); on a real clock-driven server these become wall timestamps
    ticks = eng._tick - base_tick  # warmup ticks are outside the timed region
    tick_s = total_wall / max(ticks, 1)
    lat = sorted((r.finish_tick - r.arrival_tick + 1) * tick_s for r in reqs)
    ttft = sorted((r.first_token_tick - r.arrival_tick + 1) * tick_s for r in reqs)
    payload = {
        "arch": arch,
        "slots": slots,
        "requests": requests,
        "new_tokens": new_tokens,
        "prompt_lengths": lengths,
        "ticks": ticks,
        "wall_s": total_wall,
        "tokens_total": total_tokens,
        "tokens_per_s": total_tokens / max(total_wall, 1e-9),
        "latency_s": {"p50": _pct(lat, 50), "p95": _pct(lat, 95)},
        "first_token_s": {"p50": _pct(ttft, 50), "p95": _pct(ttft, 95)},
        "prefill_traces": {str(k): v for k, v in eng.prefill_trace_counts.items()},
        "decode_traces": eng.decode_trace_count,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--json-out", default=os.path.join(RESULTS_DIR, "serve_bench.json"))
    args = ap.parse_args(argv)
    payload = run_bench(
        args.arch, slots=args.slots, requests=args.requests,
        new_tokens=args.new_tokens, max_seq=args.max_seq,
    )
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=1)
    print(json.dumps({k: payload[k] for k in
                      ("tokens_per_s", "latency_s", "first_token_s", "ticks")}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
