"""Mesh-Attention comm-volume benchmark: mask pruning, simulated + measured.

    PYTHONPATH=src python -m benchmarks.mesh_attention_bench [--json-out PATH]

Runs a segment-masked (packed two-document) workload against the unmasked
causal baseline on a (2, 4) fake-device mesh and reports, per commit:

  * simulated per-device comm bytes (event simulator over the pruned vs
    unpruned greedy schedules),
  * MEASURED per-device collective-permute bytes parsed from the compiled
    HLO (``launch/hlo_analysis.collective_bytes``) — the wire truth,
  * measured wall time per call on the fake-device CPU mesh (smoke-level),
  * packed-output-vs-dense-oracle max abs error,
  * an ``overlap`` section comparing the serial | overlap | bidir transports:
    best-of-5 wall time, measured ppermute bytes (asserted IDENTICAL across
    modes — overlapping must never change wire volume), raw vs logical
    ppermute step counts (a bidir half-payload pair is one logical hop), and
    the simulator's per-mode total/exposed-comm estimates.

JSON lands in ``benchmarks/results/mesh_attention_bench.json`` and CI uploads
it as ``BENCH_mesh_attention_<sha>.json`` (same convention as serve_bench),
so the comm-volume trajectory accumulates per commit.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    )

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

_MEASURE_CODE = r"""
import json, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.masking import MaskSpec
from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
from repro.core import schedule as Sch
from repro.kernels import ref
from repro.launch.hlo_analysis import collective_bytes
import dataclasses

n = 4
mesh = jax.make_mesh((2, 4), ("data", "sp"))
B, S, H, Hkv, D = 2, 512, 4, 2, 32
kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(kq, (B, S, H, D))
k = jax.random.normal(kk, (B, S, Hkv, D))
v = jax.random.normal(kv, (B, S, Hkv, D))
doc_lens = (S // 2, S // 2)
spec = MaskSpec.document(doc_lens)
seg = jnp.asarray(spec.segment_array(S))

cfg = MeshAttentionConfig(axis_name="sp", n=n, a=2, mask=spec,
                          layout="contiguous", block_q=64, block_kv=64)
cfg_un = dataclasses.replace(
    cfg,
    fwd_schedule=Sch.greedy_forward_schedule(cfg.a, cfg.b),
    bwd_schedule=Sch.greedy_backward_schedule(cfg.a, cfg.b),
)

def build(c):
    return jax.jit(shard_map(
        lambda q, k, v, s: mesh_attention(q, k, v, c, seg=s),
        mesh=mesh, in_specs=(P("data", "sp"),) * 3 + (P("sp"),),
        out_specs=P("data", "sp"), check_vma=False,
    ))

out = {}
for name, c in (("pruned", cfg), ("unpruned", cfg_un)):
    f = build(c)
    hlo = f.lower(q, k, v, seg).compile().as_text()
    out[name + "_ppermute_bytes"] = collective_bytes(hlo)["collective-permute"]
    o = f(q, k, v, seg)
    o.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        o = f(q, k, v, seg)
    o.block_until_ready()
    out[name + "_wall_us"] = (time.perf_counter() - t0) / 3 * 1e6
    out[name + "_out"] = np.asarray(o)

o_ref, _ = ref.attention_ref(q, k, v, band=ref.causal_band(), seg_q=seg, seg_kv=seg)
out["packed_vs_oracle_err"] = float(jnp.max(jnp.abs(out["pruned_out"] - o_ref)))
out["pruned_bitwise_eq_unpruned"] = bool(
    (out["pruned_out"] == out["unpruned_out"]).all()
)
del out["pruned_out"], out["unpruned_out"]

# comm-overlap transport comparison on the same pruned workload: the three
# modes must move IDENTICAL ppermute byte volume (bidir just splits each hop
# into a half-payload pair) and produce bitwise-identical outputs; wall time
# is best-of-5 to keep the fake-device CPU measurement stable.
ov = {}
serial_out = None
for mode in Sch.COMM_OVERLAP_MODES:
    f = build(dataclasses.replace(cfg, comm_overlap=mode))
    hlo = f.lower(q, k, v, seg).compile().as_text()
    cb = collective_bytes(hlo)
    o = f(q, k, v, seg)
    o.block_until_ready()
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        o = f(q, k, v, seg)
        o.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    o = np.asarray(o)
    if mode == "serial":
        serial_out = o
    else:
        assert (o == serial_out).all(), mode + " output != serial bitwise"
    ov[mode] = {
        "ppermute_bytes": cb["collective-permute"],
        "ppermute_ops": int(cb["collective-permute-count"]),
        "wall_us": best * 1e6,
    }
for mode in ("overlap", "bidir"):
    assert ov[mode]["ppermute_bytes"] == ov["serial"]["ppermute_bytes"], (
        mode, ov[mode]["ppermute_bytes"], ov["serial"]["ppermute_bytes"])
out["overlap"] = ov
print("RESULT " + json.dumps(out))
"""


def run_bench():
    from repro.core import schedule as Sch
    from repro.core.am import CommModel, ppermute_pair_factor
    from repro.core.autotune import plan_for
    from repro.core.masking import MaskSpec

    n, a, S = 4, 2, 512
    comm = CommModel(seq=S, hidden=4 * 32, n=n, kv_hidden=2 * 32,
                     bytes_per_elem=4, batch=2)
    mask = MaskSpec.document((S // 2, S // 2))
    sim_masked = plan_for(comm, a, mask=mask, layout="contiguous")
    sim_unmasked = plan_for(comm, a, causal=True, layout="contiguous")

    payload = {
        "mesh": [2, 4],
        "n": n,
        "a": a,
        "seq": S,
        "doc_lens": [S // 2, S // 2],
        "sim_comm_bytes_masked": sim_masked.comm_bytes,
        "sim_comm_bytes_unmasked": sim_unmasked.comm_bytes,
        "sim_comm_reduction": 1.0 - sim_masked.comm_bytes / max(sim_unmasked.comm_bytes, 1),
        "fwd_comms_masked": sim_masked.fwd.comm_ops(),
        "fwd_comms_unmasked": sim_unmasked.fwd.comm_ops(),
    }

    # simulated step cost per comm_overlap transport (same pruned workload):
    # serial fully exposes every transfer; overlap hides what compute covers;
    # bidir additionally moves each hop at per-direction bandwidth
    payload["sim_overlap"] = {
        mode: {
            "total_s": p.total,
            "exposed_comm_s": (p.fwd_sim.exposed_comm
                               + (p.bwd_sim.exposed_comm if p.bwd_sim else 0.0)),
            "comm_bytes": p.comm_bytes,
            "ppermute_pair_factor": ppermute_pair_factor(mode),
        }
        for mode, p in (
            (m, plan_for(comm, a, mask=mask, layout="contiguous", comm_overlap=m))
            for m in Sch.COMM_OVERLAP_MODES
        )
    }

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    proc = subprocess.run(
        [sys.executable, "-c", _MEASURE_CODE],
        capture_output=True, text=True, env=env, timeout=900,
    )
    lines = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT ")]
    if proc.returncode != 0 or not lines:
        payload["measured_error"] = proc.stderr[-500:]
        return payload
    measured = json.loads(lines[-1][len("RESULT "):])
    payload["measured"] = measured
    m, u = measured["pruned_ppermute_bytes"], measured["unpruned_ppermute_bytes"]
    payload["measured_comm_reduction"] = 1.0 - m / max(u, 1)
    ov = measured.get("overlap")
    if ov:
        from repro.core.am import logical_ppermute_steps

        # hard gate (bench smoke): overlapping may NOT change wire volume
        for mode in ("overlap", "bidir"):
            assert ov[mode]["ppermute_bytes"] == ov["serial"]["ppermute_bytes"], (
                f"{mode} moved different ppermute bytes than serial: {ov}"
            )
        for mode, rec in ov.items():
            rec["logical_steps"] = logical_ppermute_steps(rec["ppermute_ops"], mode)
        assert ov["bidir"]["logical_steps"] == ov["serial"]["logical_steps"], ov
        payload["measured_overlap_speedup"] = (
            ov["serial"]["wall_us"] / max(ov["overlap"]["wall_us"], 1e-9)
        )
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--json-out", default=os.path.join(RESULTS_DIR, "mesh_attention_bench.json")
    )
    args = ap.parse_args(argv)
    payload = run_bench()
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=1)
    print(json.dumps({k: payload[k] for k in payload if not isinstance(payload[k], dict)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
