"""Deterministic synthetic data pipeline.

Produces (tokens, labels, positions [+ frames/patches]) batches for any
architecture.  For striped-layout archs running sequence-parallel, the
pipeline applies the paper's §3.7 stripe permutation to tokens AND labels and
emits the true token positions so RoPE and the causal band see real
positions.  Losses are permutation-invariant, so training metrics are
layout-independent (tested).

Determinism: batch i of a run is a pure function of (seed, step) — restart
from a checkpoint replays the identical stream, which the fault-tolerance
tests rely on.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.masking import positions_from_doc_lens, segment_ids_from_doc_lens
from repro.core.tiling import stripe_permutation
from repro.parallel.context import ParallelCtx

__all__ = ["make_batch", "batch_spec_shapes", "doc_lengths"]


def batch_spec_shapes(
    cfg: ModelConfig, seq: int, batch: int, docs: Optional[int] = None
) -> Dict[str, tuple]:
    """Shapes/dtypes of one training batch (used by input_specs in dryrun)."""
    shapes = {
        "tokens": ((batch, seq), np.int32),
        "labels": ((batch, seq), np.int32),
        "positions": ((seq,), np.int32),
    }
    if docs and docs > 1:
        shapes["segments"] = ((seq,), np.int32)
        shapes["mask"] = ((batch, seq), np.float32)
    if cfg.frontend == "audio_stub":
        shapes["frames"] = ((batch, cfg.encoder_seq, cfg.frontend_dim), np.float32)
    if cfg.frontend == "vision_stub":
        shapes["patches"] = ((batch, cfg.num_patches, cfg.frontend_dim), np.float32)
    return shapes


def doc_lengths(seq: int, docs: int, *, seed: int = 0, step: int = 0) -> np.ndarray:
    """Deterministic pseudo-random partition of ``seq`` into ``docs`` document
    lengths (each >= 2) — a pure function of (seed, step) like the batch."""
    if docs < 1 or docs * 2 > seq:
        raise ValueError(f"cannot pack {docs} documents (>=2 tokens each) into seq={seq}")
    rng = np.random.default_rng([seed, step, 0xD0C5])
    cuts = np.sort(rng.choice(np.arange(1, seq // 2), size=docs - 1, replace=False)) * 2
    bounds = np.concatenate([[0], cuts, [seq]])
    return np.diff(bounds).astype(np.int64)


def make_batch(
    cfg: ModelConfig,
    seq: int,
    batch: int,
    *,
    seed: int = 0,
    step: int = 0,
    ctx: Optional[ParallelCtx] = None,
    dtype=jnp.float32,
    docs: Optional[int] = None,
) -> Dict[str, jnp.ndarray]:
    """``docs=N`` packs N synthetic documents into every row: ``segments``
    carries per-token document ids (the attention mask becomes causal-within-
    document), ``positions`` restart at each document start (per-document
    RoPE), and the loss ``mask`` zeroes the label that would cross a document
    boundary.  Boundaries are shared across rows (the schedule is per-call)."""
    ctx = ctx or ParallelCtx()
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kt, kf, kp = jax.random.split(key, 3)
    toks = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab_size, jnp.int32)
    tokens, labels = toks[:, :-1], toks[:, 1:]

    segments = loss_mask = None
    if docs and docs > 1:
        lens = doc_lengths(seq, docs, seed=seed, step=step)
        segments = segment_ids_from_doc_lens(lens, seq)
        base_positions = positions_from_doc_lens(lens)
        # the label of a document's last token is the next document's first
        boundary = np.zeros(seq, np.float32)
        boundary[np.cumsum(lens)[:-1] - 1] = 1.0
        loss_mask = np.broadcast_to(1.0 - boundary, (batch, seq)).copy()
    else:
        base_positions = np.arange(seq, dtype=np.int32)

    n = ctx.sp_size
    if n > 1 and cfg.causal_layout == "striped":
        perm = np.asarray(stripe_permutation(seq, n))
        tokens = tokens[:, perm]
        labels = labels[:, perm]
        positions = jnp.asarray(base_positions[perm])
        if segments is not None:
            segments = segments[perm]
            loss_mask = loss_mask[:, perm]
    else:
        positions = jnp.asarray(base_positions)
    out = {"tokens": tokens, "labels": labels, "positions": positions}
    if segments is not None:
        out["segments"] = jnp.asarray(segments)
        out["mask"] = jnp.asarray(loss_mask)
    if cfg.frontend == "audio_stub":
        out["frames"] = jax.random.normal(kf, (batch, cfg.encoder_seq, cfg.frontend_dim), dtype)
    if cfg.frontend == "vision_stub":
        out["patches"] = jax.random.normal(kp, (batch, cfg.num_patches, cfg.frontend_dim), dtype)
    return out
