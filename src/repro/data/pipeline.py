"""Deterministic synthetic data pipeline.

Produces (tokens, labels, positions [+ frames/patches]) batches for any
architecture.  For striped-layout archs running sequence-parallel, the
pipeline applies the paper's §3.7 stripe permutation to tokens AND labels and
emits the true token positions so RoPE and the causal band see real
positions.  Losses are permutation-invariant, so training metrics are
layout-independent (tested).

Determinism: batch i of a run is a pure function of (seed, step) — restart
from a checkpoint replays the identical stream, which the fault-tolerance
tests rely on.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.tiling import stripe_permutation
from repro.parallel.context import ParallelCtx

__all__ = ["make_batch", "batch_spec_shapes"]


def batch_spec_shapes(cfg: ModelConfig, seq: int, batch: int) -> Dict[str, tuple]:
    """Shapes/dtypes of one training batch (used by input_specs in dryrun)."""
    shapes = {
        "tokens": ((batch, seq), np.int32),
        "labels": ((batch, seq), np.int32),
        "positions": ((seq,), np.int32),
    }
    if cfg.frontend == "audio_stub":
        shapes["frames"] = ((batch, cfg.encoder_seq, cfg.frontend_dim), np.float32)
    if cfg.frontend == "vision_stub":
        shapes["patches"] = ((batch, cfg.num_patches, cfg.frontend_dim), np.float32)
    return shapes


def make_batch(
    cfg: ModelConfig,
    seq: int,
    batch: int,
    *,
    seed: int = 0,
    step: int = 0,
    ctx: Optional[ParallelCtx] = None,
    dtype=jnp.float32,
) -> Dict[str, jnp.ndarray]:
    ctx = ctx or ParallelCtx()
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kt, kf, kp = jax.random.split(key, 3)
    toks = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab_size, jnp.int32)
    tokens, labels = toks[:, :-1], toks[:, 1:]
    n = ctx.sp_size
    if n > 1 and cfg.causal_layout == "striped":
        perm = jnp.asarray(stripe_permutation(seq, n))
        tokens = tokens[:, perm]
        labels = labels[:, perm]
        positions = perm.astype(jnp.int32)
    else:
        positions = jnp.arange(seq, dtype=jnp.int32)
    out = {"tokens": tokens, "labels": labels, "positions": positions}
    if cfg.frontend == "audio_stub":
        out["frames"] = jax.random.normal(kf, (batch, cfg.encoder_seq, cfg.frontend_dim), dtype)
    if cfg.frontend == "vision_stub":
        out["patches"] = jax.random.normal(kp, (batch, cfg.num_patches, cfg.frontend_dim), dtype)
    return out
