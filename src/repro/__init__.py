"""Mesh-Attention (Chen et al., CS.DC 2025) on JAX/TPU.

A production-grade multi-pod framework: the paper's 2-D assignment-matrix
tiling as a first-class distributed attention op (``repro.core``), Pallas TPU
kernels (``repro.kernels``), a 10-architecture model zoo (``repro.models`` /
``repro.configs``), and the training/serving substrate (``repro.parallel``,
``repro.optim``, ``repro.train``, ``repro.serve``, ``repro.launch``).
"""

__version__ = "1.0.0"
