"""Single import point normalizing JAX API drift.

Every module in this tree that needs ``shard_map`` (or the other helpers
below) imports it from here instead of from ``jax`` directly, so the repo
runs unmodified on both API generations:

  * jax >= 0.5/0.6: ``jax.shard_map`` is a top-level export with the
    ``check_vma=`` / ``axis_names=`` keywords;
  * jax <= 0.4.x (this container ships 0.4.37): only
    ``jax.experimental.shard_map.shard_map`` exists, with the older
    ``check_rep=`` / ``auto=`` spelling.

The wrapper accepts the NEW spelling everywhere and translates down when
needed, so call sites are written once against the modern API.
"""

from __future__ import annotations

from typing import Optional

import jax

__all__ = [
    "shard_map",
    "get_abstract_mesh",
    "typeof",
    "vma_struct",
    "abstract_mesh",
    "supports_nested_manual_grad",
    "JAX_HAS_TOPLEVEL_SHARD_MAP",
]

JAX_HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def _mesh_axis_names(mesh):
    names = getattr(mesh, "axis_names", None)
    if names is None:  # AbstractMesh exposes shape_tuple
        names = tuple(name for name, _ in mesh.shape_tuple)
    return tuple(names)


if JAX_HAS_TOPLEVEL_SHARD_MAP:

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names: Optional[set] = None):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw,
        )

else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  axis_names: Optional[set] = None):
        """0.4.x translation: ``check_vma`` -> ``check_rep``; the manual-axes
        set ``axis_names`` -> its complement ``auto`` (axes left to GSPMD)."""
        kw = {"check_rep": check_vma}
        if axis_names is not None:
            kw["auto"] = frozenset(_mesh_axis_names(mesh)) - frozenset(axis_names)
        return _exp_shard_map(f, mesh, in_specs, out_specs, **kw)


def supports_nested_manual_grad() -> bool:
    """Whether ``jax.grad`` may cross a shard_map nested inside a
    partial-manual shard_map region.

    0.4.x names the inner op's grad residuals over every mesh axis
    (``shard_map._all_mesh_names_except_spmd``), clashing with the outer
    region's manual axes, and the 0.4-era XLA SPMD partitioner fatals on the
    resulting manual-subgroup shardings.  New jax tracks this through the vma
    type system.  Callers (e.g. the compressed cross-pod gradient path) gate
    the nested-manual formulation on this and otherwise fall back to the
    un-nested equivalent.
    """
    return JAX_HAS_TOPLEVEL_SHARD_MAP


def typeof(x):
    """``jax.typeof`` (new) or the abstract value (0.4.x)."""
    get = getattr(jax, "typeof", None)
    if get is not None:
        return get(x)
    from jax import core

    return core.get_aval(x)


def vma_struct(shape, dtype, *like):
    """ShapeDtypeStruct whose varying-manual-axes set is the union of the
    inputs' — required for pallas_call outputs under shard_map(check_vma) on
    new jax.  0.4.x avals carry no vma and the kwarg does not exist, so the
    plain struct is returned there.
    """
    vma = frozenset().union(*(getattr(typeof(x), "vma", frozenset()) for x in like))
    if not vma:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, vma=vma)


def abstract_mesh(axis_sizes, axis_names):
    """Device-free mesh handle across both AbstractMesh constructor shapes:
    new jax takes ``(sizes, names)``, 0.4.x takes ``(((name, size), ...))``."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def get_abstract_mesh():
    """Ambient abstract mesh (None when unsupported or not under a mesh).

    Newer jax exposes ``jax.sharding.get_abstract_mesh`` and nested
    ``shard_map`` calls must reuse the ambient mesh (its axis_types carry
    which axes are already manual).  0.4.x has no such accessor; callers
    fall back to their concrete mesh handle, which is what nested
    ``shard_map`` expected on that generation.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    return get()
