"""Attention layer: GQA/MHA/MLA projections over the unified dispatch seam.

The projection math runs under pjit (GSPMD shards the weights); the attention
itself goes through ``repro.core.dispatch`` — the backend (mesh | ring |
ulysses | decode | local-flash) is a registry lookup driven by the
``ParallelCtx``, and the tile/schedule may come from the autotuner's plan
cache.  No backend module is imported here.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import dispatch
from repro.models.layers import dense_init, rms_norm, rope
from repro.parallel.context import ParallelCtx

__all__ = [
    "init_attention_params",
    "init_cross_attention_params",
    "attention_block",
    "cross_attention_block",
    "distributed_attention",
    "decode_attention_step",
    "chunk_attention_step",
]


# --------------------------------------------------------------------------
# distributed dispatch (thin adapters over repro.core.dispatch)
# --------------------------------------------------------------------------


def distributed_attention(
    q: jnp.ndarray,  # [B, S(/n), H, D] local-logical global view under pjit
    k: jnp.ndarray,
    v: jnp.ndarray,
    ctx: ParallelCtx,
    *,
    causal: bool,
    window: Optional[int] = None,
    layout: str = "striped",
    segments: Optional[jnp.ndarray] = None,  # [S] int32, same order as tokens
) -> jnp.ndarray:
    """``segments`` switches the mask to causal-within-document (packed
    multi-document rows); it must be permuted exactly like the tokens."""
    if segments is not None:
        from repro.core.masking import MaskSpec

        cfg = dispatch.plan_from_ctx(
            ctx, mask=MaskSpec.segment(window=window), layout=layout
        )
        return dispatch.distributed_attention(q, k, v, cfg=cfg, ctx=ctx, segments=segments)
    cfg = dispatch.plan_from_ctx(ctx, causal=causal, window=window, layout=layout)
    return dispatch.distributed_attention(q, k, v, cfg=cfg, ctx=ctx)


def decode_attention_step(
    q: jnp.ndarray,  # [B, 1, H, D]
    k_new: jnp.ndarray,  # [B, 1, Hkv, D]
    v_new: jnp.ndarray,
    k_cache: jnp.ndarray,  # [B, cap(/n), Hkv, D] (paged: the page pool)
    v_cache: jnp.ndarray,
    pos,  # int32 scalar or [B] per-slot position vector
    ctx: ParallelCtx,
    *,
    window: Optional[int] = None,
    layout: str = "striped",
    scale: Optional[float] = None,
    block_table: Optional[jnp.ndarray] = None,  # [B, max_pages]: paged cache
    decode_kernel: Optional[str] = None,  # None -> ctx.decode_kernel
    k_scale: Optional[jnp.ndarray] = None,  # f32 scale tables: quantized pool
    v_scale: Optional[jnp.ndarray] = None,
):
    """Returns (o, new_k_cache, new_v_cache).  ``block_table`` is handed to
    the decode backend verbatim; with the native kernel variant it is read
    in-kernel (scalar-prefetched), never gathered into a dense view.  With
    ``k_scale``/``v_scale`` (quantized paged pool) the return extends to
    ``(o, k_cache, v_cache, k_scale, v_scale)``."""
    return dispatch.decode_attention_step(
        q, k_new, v_new, k_cache, v_cache, pos, ctx,
        window=window, layout=layout, scale=scale, block_table=block_table,
        decode_kernel=decode_kernel, k_scale=k_scale, v_scale=v_scale,
    )


def chunk_attention_step(
    q: jnp.ndarray,  # [B, C, H, D] chunk queries
    k_new: jnp.ndarray,  # [B, C, Hkv, D]
    v_new: jnp.ndarray,
    k_cache: jnp.ndarray,  # [B, cap(/n), Hkv, D] (paged: the page pool)
    v_cache: jnp.ndarray,
    starts,  # int32 [B]: global position of each row's chunk base
    lens,  # int32 [B]: valid tokens per row (0 = inactive row)
    write_starts,  # int32 [B]: skip KV writes below this (shared prefix)
    ctx: ParallelCtx,
    *,
    window: Optional[int] = None,
    layout: str = "striped",
    scale: Optional[float] = None,
    block_table: Optional[jnp.ndarray] = None,  # [B, max_pages]: paged cache
    k_scale: Optional[jnp.ndarray] = None,  # f32 scale tables: quantized pool
    v_scale: Optional[jnp.ndarray] = None,
):
    """Continuous-prefill chunk append + prefix-causal attention; returns
    (o, new_k_cache, new_v_cache) like ``decode_attention_step`` (plus the
    updated scale tables when a quantized pool passes them)."""
    return dispatch.chunk_attention_step(
        q, k_new, v_new, k_cache, v_cache, starts, lens, write_starts, ctx,
        window=window, layout=layout, scale=scale, block_table=block_table,
        k_scale=k_scale, v_scale=v_scale,
    )


# --------------------------------------------------------------------------
# parameter initialization
# --------------------------------------------------------------------------


def _norm_params(cfg: ModelConfig, L: int, D: int, dtype) -> dict:
    if cfg.norm == "layernorm":
        return {"ln": jnp.ones((L, D), dtype), "ln_b": jnp.zeros((L, D), dtype)}
    return {"ln": jnp.zeros((L, D), dtype)}


def init_attention_params(key, cfg: ModelConfig, L: int, dtype) -> dict:
    D, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    p = dict(_norm_params(cfg, L, D, dtype))
    if cfg.mla is not None:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        p.update(
            wq_a=dense_init(ks[0], (L, D, m.q_lora_rank), dtype=dtype),
            q_ln=jnp.zeros((L, m.q_lora_rank), dtype),
            wq_b=dense_init(ks[1], (L, m.q_lora_rank, H * qk_dim), dtype=dtype),
            wkv_a=dense_init(ks[2], (L, D, m.kv_lora_rank + m.qk_rope_head_dim), dtype=dtype),
            kv_ln=jnp.zeros((L, m.kv_lora_rank), dtype),
            wkv_b=dense_init(
                ks[3], (L, m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)), dtype=dtype
            ),
            wo=dense_init(ks[4], (L, H * m.v_head_dim, D), dtype=dtype),
        )
        return p
    p.update(
        wq=dense_init(ks[0], (L, D, H * hd), dtype=dtype),
        wk=dense_init(ks[1], (L, D, Hkv * hd), dtype=dtype),
        wv=dense_init(ks[2], (L, D, Hkv * hd), dtype=dtype),
        wo=dense_init(ks[3], (L, H * hd, D), dtype=dtype),
    )
    if cfg.qkv_bias:
        p.update(
            bq=jnp.zeros((L, H * hd), dtype),
            bk=jnp.zeros((L, Hkv * hd), dtype),
            bv=jnp.zeros((L, Hkv * hd), dtype),
        )
    return p


def init_cross_attention_params(key, cfg: ModelConfig, L: int, dtype) -> dict:
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        **_norm_params(cfg, L, D, dtype),
        "wq": dense_init(ks[0], (L, D, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (L, D, H * hd), dtype=dtype),
        "wv": dense_init(ks[2], (L, D, H * hd), dtype=dtype),
        "wo": dense_init(ks[3], (L, H * hd, D), dtype=dtype),
    }


# --------------------------------------------------------------------------
# projections (one layer slice: params without the leading L dim)
# --------------------------------------------------------------------------


def _mla_q_latent(x, p, cfg: ModelConfig, positions):
    """-> (q [B,S,H,qk] roped, latent [B,S,1,kvr+rope] roped)."""
    B, S, D = x.shape
    m = cfg.mla
    H = cfg.num_heads
    cq = rms_norm(x @ p["wq_a"], p["q_ln"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    kv_a = x @ p["wkv_a"]  # [B,S,kvr + rope]
    c_kv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_ln"])
    k_rope = rope(kv_a[..., None, m.kv_lora_rank :], positions, cfg.rope_theta)
    lat = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)
    return q, lat


def _mla_expand(lat, wkv_b, cfg: ModelConfig):
    """latent chunk [B,m,1,kvr+rope] -> per-head (k [B,m,H,qk], v padded)."""
    m = cfg.mla
    H = cfg.num_heads
    B, S = lat.shape[0], lat.shape[1]
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    c = lat[:, :, 0, : m.kv_lora_rank]
    r = lat[..., m.kv_lora_rank :]  # [B,S,1,rope], rope already applied
    kv_b = (c @ wkv_b).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, vv = kv_b[..., : m.qk_nope_head_dim], kv_b[..., m.qk_nope_head_dim :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(r, (B, S, H, m.qk_rope_head_dim))], axis=-1
    )
    # pad V up to the qk head dim so one flash kernel serves q/k/v
    # (sliced back after attention; see DESIGN.md kernel notes)
    v = jnp.pad(vv, ((0, 0), (0, 0), (0, 0), (0, qk - m.v_head_dim)))
    return k, v


def _project_qkv(x, p, cfg: ModelConfig, positions):
    """-> q [B,S,H,hd_qk], k [B,S,Hkv,hd_qk], v [B,S,Hkv,hd_v_padded]"""
    B, S, D = x.shape
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    if cfg.mla is not None:
        q, lat = _mla_q_latent(x, p, cfg, positions)
        k, v = _mla_expand(lat, p["wkv_b"], cfg)
        return q, k, v
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _latent_wire_attention(
    q, lat, wkv_b, cfg: ModelConfig, ctx: ParallelCtx, *, causal, segments=None
):
    """MLA x Mesh-Attention with the compressed latent on the KV ring
    (beyond-paper; forward-only — see EXPERIMENTS.md §Perf): wire bytes per
    KV hop drop from 2·H·qk to kvr+rope (MiniCPM3: 15360 -> 288 per token)."""
    scale = (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim) ** -0.5
    if segments is not None:
        from repro.core.masking import MaskSpec

        plan = dispatch.plan_from_ctx(
            ctx, mask=MaskSpec.segment(window=cfg.window), layout=cfg.causal_layout,
            backend="mesh", scale=scale,
        )
    else:
        plan = dispatch.plan_from_ctx(
            ctx, causal=causal, layout=cfg.causal_layout, backend="mesh", scale=scale,
        )
    return dispatch.latent_wire_attention(
        q, lat, wkv_b, lambda chunk, wb: _mla_expand(chunk, wb, cfg), cfg=plan, ctx=ctx,
        segments=segments,
    )


def attention_block(
    x: jnp.ndarray,  # [B, S, D]
    p: dict,  # one layer's params
    cfg: ModelConfig,
    ctx: ParallelCtx,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    segments: Optional[jnp.ndarray] = None,  # [S] int32 packed-document ids
) -> jnp.ndarray:
    """Pre-norm self-attention with residual."""
    B, S, D = x.shape
    h = rms_norm(x, p["ln"]) if cfg.norm == "rmsnorm" else _ln(x, p)
    if cfg.mla is not None and ctx.mla_latent_wire and ctx.sp_size > 1:
        q, lat = _mla_q_latent(h, p, cfg, positions)
        o = _latent_wire_attention(
            q, lat, p["wkv_b"], cfg, ctx, causal=causal, segments=segments
        )
    else:
        q, k, v = _project_qkv(h, p, cfg, positions)
        o = distributed_attention(
            q, k, v, ctx, causal=causal, window=cfg.window, layout=cfg.causal_layout,
            segments=segments,
        )
    if cfg.mla is not None:
        o = o[..., : cfg.mla.v_head_dim]
    o = o.reshape(B, S, -1) @ p["wo"]
    return x + o


def _ln(x, p):
    from repro.models.layers import layer_norm

    return layer_norm(x, p["ln"], p.get("ln_b", jnp.zeros_like(p["ln"])))


def cross_attention_block(
    x: jnp.ndarray,  # [B, S_dec, D]
    enc: jnp.ndarray,  # [B, S_enc, D] (encoder output)
    p: dict,
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> jnp.ndarray:
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    h = rms_norm(x, p["ln"]) if cfg.norm == "rmsnorm" else _ln(x, p)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (enc @ p["wk"]).reshape(B, enc.shape[1], H, hd)
    v = (enc @ p["wv"]).reshape(B, enc.shape[1], H, hd)
    o = distributed_attention(q, k, v, ctx, causal=False)
    o = o.reshape(B, S, -1) @ p["wo"]
    return x + o
