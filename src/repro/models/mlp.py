"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, layer_norm, rms_norm
from repro.parallel.context import ParallelCtx

__all__ = ["init_mlp_params", "mlp_block"]

_ACT = {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}


def init_mlp_params(key, cfg: ModelConfig, L: int, dtype, d_ff=None) -> dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": dense_init(ks[0], (L, D, F), dtype=dtype),
        "w2": dense_init(ks[1], (L, F, D), dtype=dtype),
    }
    if cfg.mlp_gated:
        p["w3"] = dense_init(ks[2], (L, D, F), dtype=dtype)
    if cfg.norm == "layernorm":
        p["ln"] = jnp.ones((L, D), dtype)
        p["ln_b"] = jnp.zeros((L, D), dtype)
    else:
        p["ln"] = jnp.zeros((L, D), dtype)
    return p


def mlp_block(x: jnp.ndarray, p: dict, cfg: ModelConfig, ctx: ParallelCtx) -> jnp.ndarray:
    act = _ACT[cfg.mlp_act]
    if cfg.norm == "layernorm":
        h = layer_norm(x, p["ln"], p["ln_b"])
    else:
        h = rms_norm(x, p["ln"])
    up = h @ p["w1"]
    if cfg.mlp_gated:
        up = act(up) * (h @ p["w3"])
    else:
        up = act(up)
    return x + up @ p["w2"]
