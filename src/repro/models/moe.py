"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch is MegaBlocks/GShard-style but gather/scatter based (no [S,E,C]
one-hot blow-up): per sample, the S·K (token, expert) assignments are sorted
by expert id, ranked within expert, and tokens beyond the per-expert capacity
C = ceil(S·K·cf / E) are dropped.  Everything is static-shaped (jit/pjit
friendly).

Distribution modes (cfg.moe.mode):
  * "tp": expert d_ff sharded over the model axis (works for any expert
    count, e.g. Mixtral's 8 experts on a 16-wide axis).  The second expert
    matmul produces partials that GSPMD psums/reduce-scatters.
  * "ep": expert dim sharded over the model axis (experts padded up to a
    multiple of the axis; padding experts get -inf router logits).  GSPMD
    inserts the dispatch all-to-all when resharding xe from token- to
    expert-major.

Both modes first gather the sequence dimension over the model axis
(Megatron SP<->TP transition) because routing needs token-local decisions
while the sequence is context-parallel for attention.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.parallel.context import ParallelCtx

__all__ = ["init_moe_params", "moe_block", "padded_experts"]

_ACT = {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}


def padded_experts(cfg: ModelConfig, ctx: ParallelCtx) -> int:
    e = cfg.moe.num_experts
    if cfg.moe.mode == "ep" and ctx.sp_size > 1:
        return int(math.ceil(e / ctx.sp_size) * ctx.sp_size)
    return e


def init_moe_params(key, cfg: ModelConfig, L: int, dtype, ctx: ParallelCtx) -> dict:
    m = cfg.moe
    D, Fe = cfg.d_model, m.d_ff_expert
    E = padded_experts(cfg, ctx)
    ks = jax.random.split(key, 8)
    p = {
        "ln": jnp.zeros((L, D), dtype),
        "router": dense_init(ks[0], (L, D, E), dtype=jnp.float32),
        "we1": dense_init(ks[1], (L, E, D, Fe), in_axis=-2, dtype=dtype),
        "we3": dense_init(ks[2], (L, E, D, Fe), in_axis=-2, dtype=dtype),
        "we2": dense_init(ks[3], (L, E, Fe, D), in_axis=-2, dtype=dtype),
    }
    if m.num_shared:
        Fs = m.d_ff_shared
        p.update(
            ws1=dense_init(ks[4], (L, D, Fs), dtype=dtype),
            ws3=dense_init(ks[5], (L, D, Fs), dtype=dtype),
            ws2=dense_init(ks[6], (L, Fs, D), dtype=dtype),
            shared_gate=dense_init(ks[7], (L, D, 1), dtype=dtype),
        )
    return p


def _dispatch_indices(idx: jnp.ndarray, E: int, C: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """idx: [T, K] expert choice per (token, k) -> (slot [T,K], valid [T,K]).

    slot = expert*C + rank-within-expert (capacity-dropped entries invalid).
    """
    T, K = idx.shape
    flat = idx.reshape(-1)  # [T*K]
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    # rank of each sorted entry within its expert: position - first occurrence
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks = jnp.arange(T * K) - first
    valid_sorted = ranks < C
    slot_sorted = sorted_e * C + jnp.minimum(ranks, C - 1)
    # scatter back to (token, k) order
    slot = jnp.zeros((T * K,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    valid = jnp.zeros((T * K,), bool).at[order].set(valid_sorted)
    return slot.reshape(T, K), valid.reshape(T, K)


def _route(h, router_w, cfg: ModelConfig, E_pad: int):
    """h [B,S,D] -> (idx [B,S,K], weights [B,S,K], aux_loss scalar)."""
    m = cfg.moe
    logits = (h.astype(jnp.float32) @ router_w).astype(jnp.float32)  # [B,S,E_pad]
    if E_pad > m.num_experts:  # mask padding experts
        neg = jnp.full((E_pad - m.num_experts,), -1e30, jnp.float32)
        logits = logits.at[..., m.num_experts :].add(neg)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    if m.router_norm_topk:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e)
    T = h.shape[0] * h.shape[1]
    sel = jax.nn.one_hot(idx[..., 0], E_pad, dtype=jnp.float32)
    f = sel.reshape(T, E_pad).mean(0)
    pm = probs.reshape(T, E_pad).mean(0)
    aux = m.num_experts * jnp.sum(f * pm)
    return idx, w.astype(h.dtype), aux


def _moe_ep_segmented(x, p, cfg: ModelConfig, ctx: ParallelCtx):
    """Expert parallelism in pure GSPMD via an explicit segment dim.

    Beyond-paper §Perf: the naive global-view dispatch makes GSPMD gather the
    whole sequence (plus a top_k-duplicated [B,S·K,D] buffer).  Exposing the
    sequence shards as a leading segment dim [B, n, S/n, ...] (a free reshape
    of the sharded layout) keeps routing/dispatch LOCAL per shard; the only
    cross-device movement is resharding the capacity buffer
    [B, n, E, C_loc, D] from segment-major to expert-major and back — which
    GSPMD emits as all-to-alls.  Per-shard capacity C_loc =
    ceil(S_loc·K·cf/E) (the standard EP formulation).
    """
    m = cfg.moe
    act = _ACT[cfg.mlp_act]
    B, S, D = x.shape
    E = p["router"].shape[-1]
    n = ctx.sp_size
    S_loc = S // n
    C = int(math.ceil(S_loc * m.top_k * m.capacity_factor / E))
    bs = ctx.eff_batch_spec(B)
    P_ = jax.sharding.PartitionSpec

    def seg(spec_tail):
        return jax.sharding.NamedSharding(ctx.mesh, P_(bs, ctx.sp_axis, *spec_tail))

    def exp(spec_tail):
        return jax.sharding.NamedSharding(ctx.mesh, P_(bs, None, ctx.sp_axis, *spec_tail))

    h = rms_norm(x, p["ln"])
    idx, w, aux = _route(h, p["router"], cfg, E)
    hseg = jax.lax.with_sharding_constraint(h.reshape(B, n, S_loc, D), seg([None]))
    idxseg = idx.reshape(B, n, S_loc, m.top_k)
    wseg = w.reshape(B, n, S_loc, m.top_k)

    def one(h_s, idx_s, w_s):  # per (batch, segment)
        slot, valid = _dispatch_indices(idx_s, E, C)
        contrib = jnp.where(valid[..., None], w_s[..., None], 0.0)
        xe = jnp.zeros((E * C, D), h_s.dtype)
        src = jnp.repeat(h_s, m.top_k, axis=0)
        xe = xe.at[slot.reshape(-1)].add(jnp.where(valid.reshape(-1, 1), src, 0.0))
        return xe, slot, contrib

    xe, slot, contrib = jax.vmap(jax.vmap(one))(hseg, idxseg, wseg)
    xe = jax.lax.with_sharding_constraint(xe.reshape(B, n, E, C, D), seg([None, None, None]))
    # segment-major -> expert-major: the dispatch all-to-all
    xe = jax.lax.with_sharding_constraint(xe, exp([None, None]))
    up = jnp.einsum("bnecd,edf->bnecf", xe, p["we1"])
    gate = jnp.einsum("bnecd,edf->bnecf", xe, p["we3"])
    ye = jnp.einsum("bnecf,efd->bnecd", act(up) * gate, p["we2"])
    # expert-major -> segment-major: the return all-to-all
    ye = jax.lax.with_sharding_constraint(ye, seg([None, None, None]))

    def combine_one(ye_s, slot_s, contrib_s):
        got = ye_s.reshape(E * C, D)[slot_s.reshape(-1)].reshape(S_loc, m.top_k, D)
        return jnp.sum(got * contrib_s.astype(got.dtype), axis=1)

    out = jax.vmap(jax.vmap(combine_one))(ye, slot, contrib)  # [B, n, S_loc, D]
    out = out.reshape(B, S, D)
    if m.num_shared:
        g = jax.nn.sigmoid((h @ p["shared_gate"]).astype(jnp.float32)).astype(h.dtype)
        out = out + g * ((act(h @ p["ws1"]) * (h @ p["ws3"])) @ p["ws2"])
    out = ctx.constrain(out, "seq", None)
    return x + out.astype(x.dtype), aux


def _moe_ep_manual(x, p, cfg: ModelConfig, ctx: ParallelCtx):
    """Expert parallelism with explicit dispatch all-to-alls inside a
    partial-manual shard_map (GShard-style).  NOTE: functionally validated on
    fake-device meshes (tests), but the 256-device CPU dry-run compile hits
    an XLA host-backend bug ("Invalid binary instruction opcode copy"), so
    the production EP path is the segmented pure-GSPMD variant above.
    """
    import jax
    from jax import lax

    from repro.compat import shard_map

    m = cfg.moe
    act = _ACT[cfg.mlp_act]
    B, S, D = x.shape
    E = p["router"].shape[-1]
    n = ctx.sp_size
    E_loc = E // n
    S_loc = S // n
    C = int(math.ceil(S_loc * m.top_k * m.capacity_factor / E))

    def inner(h, ln, router, we1, we3, we2, *shared):
        hn = rms_norm(h, ln)
        idx, w, aux = _route(hn, router, cfg, E)

        def one_sample(h_s, idx_s, w_s):
            slot, valid = _dispatch_indices(idx_s, E, C)
            contrib = jnp.where(valid[..., None], w_s[..., None], 0.0)
            xe = jnp.zeros((E * C, D), h_s.dtype)
            src = jnp.repeat(h_s, m.top_k, axis=0)
            xe = xe.at[slot.reshape(-1)].add(jnp.where(valid.reshape(-1, 1), src, 0.0))
            return xe, slot, contrib

        xe, slot, contrib = jax.vmap(one_sample)(hn, idx, w)
        xe = xe.reshape(B, E, C, D)
        # dispatch: expert-major exchange (tokens travel to their experts)
        xe = lax.all_to_all(xe, ctx.sp_axis, split_axis=1, concat_axis=2, tiled=True)
        up = jnp.einsum("becd,edf->becf", xe, we1)
        gate = jnp.einsum("becd,edf->becf", xe, we3)
        ye = jnp.einsum("becf,efd->becd", act(up) * gate, we2)
        # return: tokens travel home
        ye = lax.all_to_all(ye, ctx.sp_axis, split_axis=2, concat_axis=1, tiled=True)
        ye = ye.reshape(B, E * C, D)

        def combine_one(ye_s, slot_s, contrib_s):
            got = ye_s[slot_s.reshape(-1)].reshape(S_loc, m.top_k, D)
            return jnp.sum(got * contrib_s.astype(got.dtype), axis=1)

        out = jax.vmap(combine_one)(ye, slot, contrib)
        if m.num_shared:
            ws1, ws3, ws2, sg = shared
            g = jax.nn.sigmoid((hn @ sg).astype(jnp.float32)).astype(hn.dtype)
            out = out + g * ((act(hn @ ws1) * (hn @ ws3)) @ ws2)
        return out, lax.pmean(aux, ctx.sp_axis)

    P_ = jax.sharding.PartitionSpec
    seq_spec = P_(None, "model", None)
    args = [p["ln"], p["router"], p["we1"], p["we3"], p["we2"]]
    in_specs = [seq_spec, P_(), P_(), P_("model"), P_("model"), P_("model")]
    if m.num_shared:
        args += [p["ws1"], p["ws3"], p["ws2"], p["shared_gate"]]
        in_specs += [P_(), P_(), P_(), P_()]
    f = shard_map(
        inner,
        mesh=ctx.shard_map_mesh(),
        in_specs=tuple(in_specs),
        out_specs=(seq_spec, P_()),
        axis_names={"model"},
        check_vma=False,
    )
    out, aux = f(x, *args)
    return x + out.astype(x.dtype), aux


def moe_block(
    x: jnp.ndarray,  # [B, S, D]
    p: dict,  # one layer's params
    cfg: ModelConfig,
    ctx: ParallelCtx,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x + moe(x), aux_loss)."""
    m = cfg.moe
    if (
        m.mode == "ep"
        and ctx.mesh is not None
        and ctx.sp_size > 1
        and padded_experts(cfg, ctx) % ctx.sp_size == 0
        and x.shape[1] % ctx.sp_size == 0
    ):
        return _moe_ep_segmented(x, p, cfg, ctx)
    act = _ACT[cfg.mlp_act]
    B, S, D = x.shape
    E = p["router"].shape[-1]
    C = int(math.ceil(S * m.top_k * m.capacity_factor / E))

    h = rms_norm(x, p["ln"])
    # SP -> token-local: gather the sequence over the model axis
    h = ctx.constrain(h, None, None)
    idx, w, aux = _route(h, p["router"], cfg, E)

    def one_sample(h_s, idx_s, w_s):
        slot, valid = _dispatch_indices(idx_s, E, C)  # [S,K]
        contrib = jnp.where(valid[..., None], w_s[..., None], 0.0)
        # xe[e*C + c] = token routed there (dropped -> zeros via scatter mask)
        xe = jnp.zeros((E * C, D), h_s.dtype)
        src = jnp.repeat(h_s, m.top_k, axis=0)  # [S*K, D] token per assignment
        xe = xe.at[slot.reshape(-1)].add(
            jnp.where(valid.reshape(-1, 1), src, 0.0)
        )
        return xe, slot, contrib

    xe, slot, contrib = jax.vmap(one_sample)(h, idx, w)  # xe [B, E*C, D]
    xe = xe.reshape(B, E, C, D)
    if m.mode == "ep" and ctx.mesh is not None and ctx.sp_size > 1:
        # token-major -> expert-major resharding = the EP all-to-all
        xe = jax.lax.with_sharding_constraint(
            xe,
            jax.sharding.NamedSharding(
                ctx.mesh,
                jax.sharding.PartitionSpec(ctx.eff_batch_spec(B), ctx.sp_axis, None, None),
            ),
        )
    up = jnp.einsum("becd,edf->becf", xe, p["we1"])
    gate = jnp.einsum("becd,edf->becf", xe, p["we3"])
    ye = jnp.einsum("becf,efd->becd", act(up) * gate, p["we2"])
    if m.mode == "ep" and ctx.mesh is not None and ctx.sp_size > 1:
        ye = jax.lax.with_sharding_constraint(
            ye,
            jax.sharding.NamedSharding(
                ctx.mesh,
                jax.sharding.PartitionSpec(ctx.eff_batch_spec(B), None, None, None),
            ),
        )
    ye = ye.reshape(B, E * C, D)

    def combine_one(ye_s, slot_s, contrib_s):
        got = ye_s[slot_s.reshape(-1)].reshape(S, m.top_k, D)
        return jnp.sum(got * contrib_s.astype(got.dtype), axis=1)

    out = jax.vmap(combine_one)(ye, slot, contrib)  # [B, S, D]

    if m.num_shared:
        g = jax.nn.sigmoid((h @ p["shared_gate"]).astype(jnp.float32)).astype(h.dtype)
        shared = (act(h @ p["ws1"]) * (h @ p["ws3"])) @ p["ws2"]
        out = out + g * shared

    # back to the sequence-parallel layout
    out = ctx.constrain(out, "seq", None)
    return x + out.astype(x.dtype), aux
