"""Model assembly: init / forward / loss / prefill / decode for every family.

Layers are stacked along a leading L dim and iterated with ``lax.scan`` (+
optional remat) so the lowered HLO is depth-independent — essential for the
512-device dry-run compiles.  Family switches:

  dense   — attention + gated MLP
  moe     — attention + MoE (TP or EP mode)
  ssm     — SSD blocks only (attention-free; Mesh-Attention N/A)
  hybrid  — parallel attention + SSD heads, then MLP (hymba)
  audio   — whisper-style encoder(full attn)-decoder(causal+cross) w/ stub
  vlm     — pixtral: decoder backbone + patch-embedding merge (stub frontend)

Decode uses the striped KV cache (core/decode_attention) for attention
families, O(1) state updates for SSM, and absorbed-latent MLA decode
(DeepSeek-style matrix absorption) for MiniCPM3 — the cache stores the
256-d latent, not 40 decompressed heads.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import kv_quant
from repro.kernels import ops as kops
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import dense_init, layer_norm, rms_norm, rope, vocab_cross_entropy
from repro.models.mlp import init_mlp_params, mlp_block
from repro.parallel.context import ParallelCtx

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "init_cache",
    "prefill",
    "prefill_packed",
    "prefill_chunk",
    "decode_step",
    "verify_step",
    "param_count",
]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key, dtype=jnp.float32, ctx: Optional[ParallelCtx] = None):
    ctx = ctx or ParallelCtx()
    keys = jax.random.split(key, 12)
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    p: Dict = {"embed": dense_init(keys[0], (V, D), in_axis=-1, dtype=dtype)}

    layers: Dict = {}
    if cfg.family != "ssm":
        layers["attn"] = attn.init_attention_params(keys[1], cfg, L, dtype)
    if cfg.ssm is not None:
        layers["ssm"] = ssm_mod.init_ssm_params(keys[2], cfg, L, dtype)
    if cfg.moe is not None:
        layers["moe"] = moe_mod.init_moe_params(keys[3], cfg, L, dtype, ctx)
    elif cfg.family != "ssm" and cfg.d_ff > 0:
        layers["mlp"] = init_mlp_params(keys[4], cfg, L, dtype)
    if cfg.encoder_layers:
        layers["xattn"] = attn.init_cross_attention_params(keys[5], cfg, L, dtype)
    p["layers"] = layers

    if cfg.norm == "layernorm":
        p["final_ln"] = jnp.ones((D,), dtype)
        p["final_ln_b"] = jnp.zeros((D,), dtype)
    else:
        p["final_ln"] = jnp.zeros((D,), dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[6], (D, V), dtype=dtype)

    if cfg.encoder_layers:
        Le = cfg.encoder_layers
        enc_layers = {
            "attn": attn.init_attention_params(keys[7], cfg, Le, dtype),
            "mlp": init_mlp_params(keys[8], cfg, Le, dtype),
        }
        enc = {"layers": enc_layers}
        if cfg.norm == "layernorm":
            enc["final_ln"] = jnp.ones((D,), dtype)
            enc["final_ln_b"] = jnp.zeros((D,), dtype)
        else:
            enc["final_ln"] = jnp.zeros((D,), dtype)
        p["encoder"] = enc
    if cfg.frontend:
        p["frontend"] = {"proj": dense_init(keys[9], (cfg.frontend_dim, D), dtype=dtype)}
    return p


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------


def _final_norm(x, p, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["final_ln"], p["final_ln_b"])
    return rms_norm(x, p["final_ln"])


def _decoder_block(x, lp, cfg: ModelConfig, ctx: ParallelCtx, positions, enc=None,
                   segments=None):
    """One decoder layer. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        return ssm_mod.ssm_block(x, lp["ssm"], cfg, ctx), aux
    if cfg.hybrid:
        a = attn.attention_block(x, lp["attn"], cfg, ctx, positions, segments=segments) - x
        s = ssm_mod.ssm_block(x, lp["ssm"], cfg, ctx) - x
        x = x + 0.5 * (a + s)
    else:
        x = attn.attention_block(x, lp["attn"], cfg, ctx, positions, segments=segments)
    if enc is not None:
        x = attn.cross_attention_block(x, enc, lp["xattn"], cfg, ctx)
    if cfg.moe is not None:
        x, aux = moe_mod.moe_block(x, lp["moe"], cfg, ctx)
    elif cfg.d_ff > 0:
        x = mlp_block(x, lp["mlp"], cfg, ctx)
    return x, aux


def _encoder_block(x, lp, cfg: ModelConfig, ctx: ParallelCtx, positions):
    x = attn.attention_block(x, lp["attn"], cfg, ctx, positions, causal=False)
    return mlp_block(x, lp["mlp"], cfg, ctx)


def _stack_scan(f, carry, xs, ctx: ParallelCtx):
    """lax.scan over stacked layers, or a python unroll (ctx.unroll_layers —
    used by the dry-run so XLA cost analysis sees every layer)."""
    if not ctx.unroll_layers:
        return lax.scan(f, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        carry, y = f(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def _scan_layers(x, layers, body, ctx: ParallelCtx):
    """scan over stacked layer params, accumulating aux loss."""

    def f(carry, lp):
        x, aux = carry
        x, a = body(x, lp)
        return (x, aux + a), None

    if ctx.remat:
        f = jax.checkpoint(f, prevent_cse=False)
    (x, aux), _ = _stack_scan(f, (x, jnp.float32(0.0)), layers, ctx)
    return x, aux


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------


def _encode_audio(params, cfg, ctx, frames):
    """Stubbed conv frontend: mel frames -> projected embeddings -> encoder."""
    x = frames.astype(params["embed"].dtype) @ params["frontend"]["proj"]
    x = ctx.constrain(x, "seq", None)
    pos = jnp.arange(cfg.encoder_seq, dtype=jnp.int32)
    enc = params["encoder"]

    def body(h, lp):
        return _encoder_block(h, lp, cfg, ctx, pos), jnp.float32(0.0)

    x, _ = _scan_layers(x, enc["layers"], body, ctx)
    if cfg.norm == "layernorm":
        x = layer_norm(x, enc["final_ln"], enc["final_ln_b"])
    else:
        x = rms_norm(x, enc["final_ln"])
    return x


def _merge_patches(x, params, positions, patches, num_patches):
    """VLM stub: positions < num_patches take projected patch embeddings
    (works under striping: gathered by true position)."""
    px = patches.astype(x.dtype) @ params["frontend"]["proj"]  # [B, P, D]
    idx = jnp.clip(positions, 0, num_patches - 1)
    gathered = jnp.take(px, idx, axis=1)  # [B, S, D]
    mask = (positions < num_patches)[None, :, None]
    return jnp.where(mask, gathered, x)


def forward(params, cfg: ModelConfig, ctx: ParallelCtx, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (logits [B,S,V], aux_loss). batch: tokens [B,S], positions [S],
    optional segments [S] (packed multi-document rows: causal within each
    document), frames [B,S_enc,F] (audio) / patches [B,P,F] (vlm)."""
    tokens = batch["tokens"]
    positions = batch["positions"]
    segments = batch.get("segments")
    if segments is not None and cfg.ssm is not None:
        raise ValueError(
            "packed multi-document batches are attention-only: the SSD "
            "recurrent state has no per-document reset"
        )
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vision_stub":
        x = _merge_patches(x, params, positions, batch["patches"], cfg.num_patches)
    x = ctx.constrain(x, "seq", None)

    enc = None
    if cfg.encoder_layers:
        enc = _encode_audio(params, cfg, ctx, batch["frames"])

    body = functools.partial(
        _decoder_block, cfg=cfg, ctx=ctx, positions=positions, enc=enc, segments=segments
    )
    x, aux = _scan_layers(x, params["layers"], lambda h, lp: body(h, lp), ctx)
    x = _final_norm(x, params, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, ctx: ParallelCtx, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(params, cfg, ctx, batch)
    ce = vocab_cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce + 0.01 * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------


def _attn_cache_dims(cfg: ModelConfig):
    """(kv_heads, k_dim, v_dim) as stored in the cache."""
    if cfg.mla is not None:
        m = cfg.mla
        d = m.kv_lora_rank + m.qk_rope_head_dim
        return 1, d, d  # absorbed-latent cache: one "head" of latent width
    return cfg.num_kv_heads, cfg.hd, cfg.hd


def init_cache(cfg: ModelConfig, batch: int, cap: int, dtype=jnp.bfloat16, ctx=None,
               paged=None, kv_dtype: str = "fp"):
    """Decode cache with a PER-SLOT position vector ``pos: [B]`` — each batch
    row (serving slot) may sit at a different depth, which is what lets the
    continuous-batching engine decode mixed-depth slots in one jitted step.

    ``paged`` (a ``repro.serve.kv_pool.PagedLayout``) switches the attention
    K/V to a physical page pool ``[L, num_pages, n*page_size, Hkv, D]`` plus
    an int32 block table ``"bt": [batch, max_pages]`` (-1 = unallocated):
    memory scales with allocated pages, not ``batch x cap``, and identical
    prompt prefixes can share refcounted pages.  SSM / cross-attention state
    stays per-slot dense (it is O(1) or encoder-sized per slot).

    ``kv_dtype`` ("fp" | "int8" | "fp8", paged only) stores the page pool
    quantized with per-(token, kv-head) scales in side tables
    ``"k_scale"/"v_scale": [L, num_pages, n*page_size, Hkv]`` f32 that share
    the pool's physical indexing (same page ids, same columns)."""
    L = cfg.num_layers
    cache: Dict = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family != "ssm":
        hkv, dk, dv = _attn_cache_dims(cfg)
        if paged is not None:
            n = ctx.sp_size if ctx is not None else 1
            if paged.n != n:
                raise ValueError(
                    f"paged layout is sharded over n={paged.n} but the ctx has "
                    f"sp_size={n}"
                )
            if paged.virtual_cap < cap:
                raise ValueError(
                    f"paged virtual capacity {paged.virtual_cap} < cap {cap}"
                )
            store = kv_quant.storage_dtype(kv_dtype, dtype)
            cache["k"] = jnp.zeros((L, paged.num_pages, paged.chunk, hkv, dk), store)
            cache["v"] = jnp.zeros((L, paged.num_pages, paged.chunk, hkv, dv), store)
            cache["bt"] = jnp.full((batch, paged.max_pages), -1, jnp.int32)
            if kv_dtype != "fp":
                shape = (L, paged.num_pages, paged.chunk, hkv)
                cache["k_scale"] = jnp.zeros(shape, kv_quant.SCALE_DTYPE)
                cache["v_scale"] = jnp.zeros(shape, kv_quant.SCALE_DTYPE)
        else:
            if kv_dtype != "fp":
                raise ValueError("quantized KV storage requires the paged cache")
            cache["k"] = jnp.zeros((L, batch, cap, hkv, dk), dtype)
            cache["v"] = jnp.zeros((L, batch, cap, hkv, dv), dtype)
    if cfg.ssm is not None:
        cache["ssm"] = ssm_mod.init_ssm_cache(cfg, L, batch, dtype)
    if cfg.encoder_layers:
        # cross-attention K/V precomputed from the encoder at prefill
        H, hd = cfg.num_heads, cfg.hd
        cache["cross_k"] = jnp.zeros((L, batch, cfg.encoder_seq, H, hd), dtype)
        cache["cross_v"] = jnp.zeros((L, batch, cfg.encoder_seq, H, hd), dtype)
    return cache


def _decode_qkv(h, lp, cfg: ModelConfig, pos):
    """Cache-space projections for decode / chunk append. h [B,S,D] ->
    (q [B,S,Hq,dk], k_new [B,S,hkv,dk], v_new [B,S,hkv,dv], scale).
    ``pos`` is a scalar, a [B] per-slot vector (S=1 decode), or a full [B,S]
    position grid (continuous-prefill chunks)."""
    B, S = h.shape[0], h.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 2:
        positions = pos  # [B, S] chunk grid
    elif pos.ndim == 1:
        positions = pos[:, None]
    else:
        positions = jnp.full((1,), pos, jnp.int32)
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        cq = rms_norm(h @ lp["wq_a"], lp["q_ln"])
        q = (cq @ lp["wq_b"]).reshape(B, S, cfg.num_heads, qk)
        q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
        q_rope = rope(q_rope, positions, cfg.rope_theta)
        kv_a = h @ lp["wkv_a"]
        c_kv = rms_norm(kv_a[..., : m.kv_lora_rank], lp["kv_ln"])
        k_rope = rope(kv_a[..., None, m.kv_lora_rank :], positions, cfg.rope_theta)
        # absorb W^{kv_b}_K into q: q_lat[h, r] = sum_n q_nope[h,n] Wb[r, h, n]
        wb = lp["wkv_b"].reshape(m.kv_lora_rank, cfg.num_heads, -1)
        wb_k = wb[..., : m.qk_nope_head_dim]
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wb_k)
        q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)  # [B,S,H,kvr+rope]
        kv_new = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)  # latent "K"
        scale = qk**-0.5
        return q_eff, kv_new, kv_new, scale
    hd = cfg.hd
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = rope(q.reshape(B, S, cfg.num_heads, hd), positions, cfg.rope_theta)
    k = rope(k.reshape(B, S, cfg.num_kv_heads, hd), positions, cfg.rope_theta)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v, hd**-0.5


def _decode_attn_out(o, h_in, lp, cfg: ModelConfig):
    B, S = o.shape[0], o.shape[1]
    if cfg.mla is not None:
        m = cfg.mla
        o_lat = o[..., : m.kv_lora_rank]  # latent-space values
        wb = lp["wkv_b"].reshape(m.kv_lora_rank, cfg.num_heads, -1)
        wb_v = wb[..., m.qk_nope_head_dim :]
        ov = jnp.einsum("bshr,rhv->bshv", o_lat, wb_v)
        return h_in + ov.reshape(B, S, -1) @ lp["wo"]
    return h_in + o.reshape(B, S, -1) @ lp["wo"]


def _decode_block(x, lp, cache_l, cfg: ModelConfig, ctx: ParallelCtx, pos, bt=None):
    """One layer's decode. cache_l: dict of this layer's cache slices; ``bt``
    is the (layer-shared) block table when the K/V cache is paged."""
    new_cache = dict(cache_l)
    if cfg.family == "ssm":
        y, new_cache["ssm"] = ssm_mod.ssm_decode_step(x, lp["ssm"], cache_l["ssm"], cfg)
        return y, new_cache

    h = rms_norm(x, lp["attn"]["ln"]) if cfg.norm == "rmsnorm" else layer_norm(
        x, lp["attn"]["ln"], lp["attn"]["ln_b"]
    )
    q, k_new, v_new, scale = _decode_qkv(h, lp["attn"], cfg, pos)
    # the decode cache is ALWAYS striped (even for contiguous-train archs):
    # prefill restripes K/V once; appends then stay load-balanced forever
    ks, vs = cache_l.get("k_scale"), cache_l.get("v_scale")
    if ks is not None:
        o, ck, cv, ks, vs = attn.decode_attention_step(
            q, k_new, v_new, cache_l["k"], cache_l["v"], pos, ctx,
            window=cfg.window, layout="striped", scale=scale, block_table=bt,
            k_scale=ks, v_scale=vs,
        )
        new_cache["k_scale"], new_cache["v_scale"] = ks, vs
    else:
        o, ck, cv = attn.decode_attention_step(
            q, k_new, v_new, cache_l["k"], cache_l["v"], pos, ctx,
            window=cfg.window, layout="striped", scale=scale, block_table=bt,
        )
    new_cache["k"], new_cache["v"] = ck, cv
    y = _decode_attn_out(o, x, lp["attn"], cfg)

    if cfg.hybrid:
        s, new_cache["ssm"] = ssm_mod.ssm_decode_step(x, lp["ssm"], cache_l["ssm"], cfg)
        y = x + 0.5 * ((y - x) + (s - x))

    if cfg.encoder_layers:
        # cross-attention against the precomputed encoder K/V
        hc = rms_norm(y, lp["xattn"]["ln"]) if cfg.norm == "rmsnorm" else layer_norm(
            y, lp["xattn"]["ln"], lp["xattn"]["ln_b"]
        )
        B = y.shape[0]
        qc = (hc @ lp["xattn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.hd)
        oc, _ = kops.block_attention(
            qc, cache_l["cross_k"], cache_l["cross_v"], kops.full_band()
        )
        y = y + oc.reshape(B, 1, -1) @ lp["xattn"]["wo"]

    if cfg.moe is not None:
        y, _ = moe_mod.moe_block(y, lp["moe"], cfg, ctx)
    elif cfg.d_ff > 0:
        y = mlp_block(y, lp["mlp"], cfg, ctx)
    return y, new_cache


def decode_step(params, cache, tokens, cfg: ModelConfig, ctx: ParallelCtx):
    """One greedy decode step over all slots.
    tokens [B,1] -> (next [B,1], new cache, logits [B,1,V]).

    ``cache["pos"]`` is the per-slot position vector [B] (a scalar still
    works for legacy callers); every row advances by one — rows holding
    retired/free slots tick harmlessly (their cache writes are masked past
    capacity and their outputs are ignored by the engine).

    A paged cache's block table ``cache["bt"]`` is threaded to the decode
    backend VERBATIM (layer-shared device operand): ``ctx.decode_kernel``
    picks whether it drives a page gather or is scalar-prefetched into the
    native split-K kernel (kernels/paged_decode.py)."""
    pos = cache["pos"]
    bt = cache.get("bt")  # paged K/V: block table, shared by every layer
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.constrain(x, None, None)

    layer_cache = {k: v for k, v in cache.items() if k not in ("pos", "bt")}

    def body(x, inp):
        lp, cl = inp
        x, new_cl = _decode_block(x, lp, cl, cfg, ctx, pos, bt=bt)
        return x, new_cl

    x, new_layer_cache = _stack_scan(body, x, (params["layers"], layer_cache), ctx)
    x = _final_norm(x, params, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    nxt = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
    new_cache = dict(new_layer_cache)
    new_cache["pos"] = pos + 1
    if bt is not None:
        new_cache["bt"] = bt
    return nxt, new_cache, logits


def prefill_chunk(params, cfg: ModelConfig, ctx: ParallelCtx, batch: Dict, cache):
    """Continuous prefill: append one C-token chunk per slot into the live
    cache and run prefix-causal attention over everything resident.

    ``batch`` carries fixed-shape [B(=num_slots), C] operands so ONE jitted
    trace serves every tick:

      * ``tokens``  [B, C] int32 — chunk tokens, right-padded per row
      * ``starts``  [B] int32 — absolute position of each row's chunk base
      * ``lens``    [B] int32 — valid tokens per row (0 = inactive row:
        nothing is written and the row's output is garbage to be ignored)
      * ``write_starts`` [B] int32 — skip KV writes below this absolute
        position (a shared prefix already resident in the paged pool)
      * ``pos_set`` [B] int32 — new ``cache["pos"]`` per row, or -1 to keep
        the current value (mid-prefill rows stay parked past capacity so the
        shared decode step's writes keep dropping)

    Returns (logits [B, V] at each row's LAST valid chunk token, new cache).
    The logits row is only meaningful for rows whose final chunk this is —
    the engine samples the first generated token from it that same tick, so
    a chunked request's first token lands on exactly the tick its one-shot
    twin would have produced it.  Token-for-token equivalence with one-shot
    ``prefill`` holds because the chunk path runs the SAME banded kernel,
    stripe math, and lse-psum combine (bitwise on the reference backend).

    Works on the dense sharded cache and the paged pool (``cache["bt"]``);
    attention-only decoder archs (no SSM state, no cross-attention, no
    frontend) — the same restriction packed/paged prefill already has.
    """
    tokens = batch["tokens"]
    lens = jnp.asarray(batch["lens"], jnp.int32)
    pos_set = jnp.asarray(batch["pos_set"], jnp.int32)
    C = tokens.shape[1]
    x, new_layer_cache, bt = _chunk_forward(
        params, cfg, ctx, tokens, batch["starts"], lens, batch["write_starts"],
        cache,
    )
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    last = jnp.clip(lens - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)  # [B, 1, D]
    logits = x_last[:, 0] @ head.astype(x.dtype)  # [B, V]
    new_cache = dict(cache)
    new_cache.update(new_layer_cache)
    new_cache["pos"] = jnp.where(pos_set >= 0, pos_set, cache["pos"])
    if bt is not None:
        new_cache["bt"] = bt
    return logits, new_cache


def _chunk_forward(params, cfg: ModelConfig, ctx: ParallelCtx, tokens, starts,
                   lens, write_starts, cache):
    """Shared core of ``prefill_chunk`` and ``verify_step``: append a
    [B, C] chunk batch into the live cache through the banded multi-row
    attention path and return the final-norm hidden states for EVERY chunk
    position.  Returns ``(x [B, C, D], new_layer_cache, bt)``."""
    if cfg.ssm is not None or cfg.encoder_layers or cfg.frontend is not None:
        raise ValueError("chunked prefill serves attention-only decoder archs")
    starts = jnp.asarray(starts, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    write_starts = jnp.asarray(write_starts, jnp.int32)
    C = tokens.shape[1]
    positions = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    bt = cache.get("bt")  # paged K/V: block table, shared by every layer
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.constrain(x, None, None)
    layer_cache = {k: v for k, v in cache.items() if k not in ("pos", "bt")}

    def body(x, inp):
        lp, cl = inp
        new_cl = dict(cl)
        h = rms_norm(x, lp["attn"]["ln"]) if cfg.norm == "rmsnorm" else layer_norm(
            x, lp["attn"]["ln"], lp["attn"]["ln_b"]
        )
        q, k_new, v_new, scale = _decode_qkv(h, lp["attn"], cfg, positions)
        # the decode cache is ALWAYS striped; chunk rows scatter straight to
        # their owner shards exactly like single-token appends
        ks, vs = cl.get("k_scale"), cl.get("v_scale")
        if ks is not None:
            o, ck, cv, ks, vs = attn.chunk_attention_step(
                q, k_new, v_new, cl["k"], cl["v"], starts, lens, write_starts,
                ctx, window=cfg.window, layout="striped", scale=scale,
                block_table=bt, k_scale=ks, v_scale=vs,
            )
            new_cl["k_scale"], new_cl["v_scale"] = ks, vs
        else:
            o, ck, cv = attn.chunk_attention_step(
                q, k_new, v_new, cl["k"], cl["v"], starts, lens, write_starts,
                ctx, window=cfg.window, layout="striped", scale=scale,
                block_table=bt,
            )
        new_cl["k"], new_cl["v"] = ck, cv
        y = _decode_attn_out(o, x, lp["attn"], cfg)
        if cfg.moe is not None:
            y, _ = moe_mod.moe_block(y, lp["moe"], cfg, ctx)
        elif cfg.d_ff > 0:
            y = mlp_block(y, lp["mlp"], cfg, ctx)
        return y, new_cl

    x, new_layer_cache = _stack_scan(body, x, (params["layers"], layer_cache), ctx)
    x = _final_norm(x, params, cfg)
    return x, new_layer_cache, bt


def verify_step(params, cfg: ModelConfig, ctx: ParallelCtx, batch: Dict, cache,
                return_logits: bool = False):
    """Speculative verify: score K candidate tokens per slot in ONE banded
    chunk launch and commit the longest accepted prefix in-graph.

    ``batch`` carries fixed-shape [B(=num_slots), K] operands (one jit trace
    serves every tick):

      * ``tokens`` [B, K] int32 — column 0 is the row's CURRENT token
        (exactly what vanilla decode would feed this tick), columns
        ``1 .. K-1`` the proposer's draft
      * ``starts`` [B] int32 — each row's current cache position (the
        current token's K/V is written there, as in plain decode)
      * ``lens``   [B] int32 — 0: inactive row (nothing written, ``pos``
        unchanged); 1: a plain one-token decode tick; ``k``: verify a
        ``k-1``-token draft
      * ``write_starts`` [B] int32 — forwarded to the chunk scatter
        (normally == starts)

    Greedy longest-accepted-prefix: with ``y[i] = argmax`` of the logits at
    chunk position i, draft token ``tokens[i+1]`` is ACCEPTED while it
    equals ``y[i]`` — each accepted position's context is by then fully
    committed tokens, so ``y[i]`` is bitwise what vanilla decode would have
    produced at that step.  The committed tokens are ``y[0 .. commit-1]``
    with ``commit = accepted + 1`` (the output at the last accepted
    position is always kept: it is vanilla decode's next token whether or
    not any draft survived).  K/V for positions past the committed prefix
    is stale speculative data — invisible behind the band (reads stop at
    ``pos``) and rewritten before ``pos`` ever reaches it; the paged engine
    additionally frees now-unneeded tail pages (allocator rollback).

    Returns ``(y [B, K] int32, commit [B] int32, new cache)`` with
    ``pos = starts + commit`` for active rows; ``return_logits`` appends the
    raw per-position logits ``[B, K, V]`` (debug / error-bound checks)."""
    tokens = batch["tokens"]
    starts = jnp.asarray(batch["starts"], jnp.int32)
    lens = jnp.asarray(batch["lens"], jnp.int32)
    B, K = tokens.shape
    x, new_layer_cache, bt = _chunk_forward(
        params, cfg, ctx, tokens, starts, lens, batch["write_starts"], cache
    )
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)  # [B, K, V]
    y = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B, K]
    if K > 1:
        match = (tokens[:, 1:] == y[:, :-1]) & (
            jnp.arange(1, K, dtype=jnp.int32)[None, :] < lens[:, None]
        )
        accepted = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    else:
        accepted = jnp.zeros((B,), jnp.int32)
    commit = jnp.where(lens > 0, jnp.minimum(accepted + 1, lens), 0)
    new_cache = dict(cache)
    new_cache.update(new_layer_cache)
    new_cache["pos"] = jnp.where(lens > 0, starts + commit, cache["pos"])
    if bt is not None:
        new_cache["bt"] = bt
    if return_logits:
        return y, commit, new_cache, logits
    return y, commit, new_cache


def _cache_scatter_indices(cfg: ModelConfig, S: int, cap: int, n: int):
    """Static map: prefill K/V index j -> striped-cache global index.

    Striped cache convention: position p lives at global index
    (p % n) * (cap/n) + p // n (shard p % n, slot p // n).  For striped-train
    archs the prefill array index j already means position
    (j // (S/n)) + n*(j % (S/n)), which maps to contiguous per-shard blocks —
    zero data movement.  Contiguous-train archs (hymba) pay one restripe.
    """
    import numpy as np

    j = np.arange(S)
    if n <= 1:
        return jnp.asarray(j)
    if cfg.causal_layout == "striped":
        p = (j // (S // n)) + n * (j % (S // n))
    else:
        p = j
    g = (p % n) * (cap // n) + p // n
    return jnp.asarray(g)


def _paged_prefill_coords(positions, bt_rows, n: int, page_size: int, write_mask):
    """Scatter coordinates for writing true positions ``positions`` [S]
    through a block table into the pool ``[num_pages, n*page_size, ...]``
    (striped cache convention: position p lives on shard p % n at local
    index p // n, i.e. pool column (p % n) * page_size + (p // n) % page_size
    of logical page (p // n) // page_size).  ``bt_rows`` is one request's
    row [max_pages], or a per-token [S, max_pages] (packed prefill, each
    token routed through its own document's slot).  Masked / unallocated
    tokens get an out-of-range page index so ``mode="drop"`` discards them."""
    max_pages = bt_rows.shape[-1]
    p = jnp.asarray(positions, jnp.int32)
    j = p // n
    lp = j // page_size
    col = (p % n) * page_size + j % page_size
    lp_c = jnp.clip(lp, 0, max_pages - 1)
    if bt_rows.ndim == 1:
        page = bt_rows[lp_c]
    else:
        page = jnp.take_along_axis(bt_rows, lp_c[:, None], axis=1)[:, 0]
    write = write_mask & (page >= 0) & (lp < max_pages)
    return jnp.where(write, page, jnp.int32(2**30)), col


def _project_kv_for_cache(h, lp, cfg: ModelConfig, positions):
    """The K/V (or MLA latent) a prefill writes into the cache for ``h``
    [B, S, D] at ``positions`` [S]."""
    B, S = h.shape[0], h.shape[1]
    if cfg.mla is not None:
        m = cfg.mla
        kv_a = h @ lp["wkv_a"]
        c_kv = rms_norm(kv_a[..., : m.kv_lora_rank], lp["kv_ln"])
        k_rope = rope(kv_a[..., None, m.kv_lora_rank :], positions, cfg.rope_theta)
        lat = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)
        return lat, lat
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        k, v = k + lp["bk"], v + lp["bv"]
    k = rope(k.reshape(B, S, cfg.num_kv_heads, cfg.hd), positions, cfg.rope_theta)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.hd)
    return k, v


def prefill(params, cfg: ModelConfig, ctx: ParallelCtx, batch: Dict, cache):
    """Forward over the prompt, writing the striped KV cache per layer.

    For striped-layout archs the prefill chunks ARE the cache shards (token t
    on shard t mod n) — K/V land with no resharding; this is the paper's
    locality property carried into serving.

    ``batch`` may carry an optional ``"length": [B]`` of true prompt lengths
    when tokens are right-padded to a bucket (the continuous-batching
    engine's bucketed prefill): the returned logits are taken at each row's
    own last REAL position and ``cache["pos"]`` starts each row at its own
    length.  Causality makes the trailing pad tokens invisible to the real
    ones, and decode overwrites each pad's cache entry before first reading
    that position.

    A PAGED ``cache`` (it carries ``"bt"``) is the whole slot pool: K/V
    scatter through the block-table row of ``batch["slot"]`` (int32 scalar)
    straight into the physical pages.  ``batch["shared_len"]`` (int32 scalar,
    default 0) marks a prefix admitted as SHARED pages — those positions are
    skipped (the owner's K/V is already there and other slots are reading
    it); pads (``positions >= length``) never touch the pool, so no pages are
    spent on bucket padding.  Requires batch=1 tokens and an attention-only
    decoder arch (SSM state and cross-attention K/V stay per-slot dense).
    """
    tokens, positions = batch["tokens"], batch["positions"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vision_stub":
        x = _merge_patches(x, params, positions, batch["patches"], cfg.num_patches)
    x = ctx.constrain(x, "seq", None)
    enc = None
    if cfg.encoder_layers:
        enc = _encode_audio(params, cfg, ctx, batch["frames"])

    S = tokens.shape[1]
    has_attn = cfg.family != "ssm"
    has_ssm = cfg.ssm is not None
    paged = "bt" in cache
    if paged:
        if cfg.ssm is not None or cfg.encoder_layers:
            raise ValueError("the paged cache serves attention-only decoder archs")
        if tokens.shape[0] != 1:
            raise ValueError("paged prefill writes one request (batch=1) per call")
        n = max(ctx.sp_size, 1)
        page_size = cache["k"].shape[2] // n
        slot = jnp.asarray(batch["slot"], jnp.int32)
        shared_len = jnp.asarray(batch.get("shared_len", 0), jnp.int32)
        length_s = (
            batch["length"].astype(jnp.int32)[0] if "length" in batch else jnp.int32(S)
        )
        write_mask = (positions < length_s) & (positions >= shared_len)
        page_idx, col_idx = _paged_prefill_coords(
            positions, cache["bt"][slot], n, page_size, write_mask
        )
        g_idx = None
    else:
        cap = cache["k"].shape[2] if has_attn else None
        g_idx = _cache_scatter_indices(cfg, S, cap, ctx.sp_size) if has_attn else None
    keys = [
        k for k in ("k", "v", "k_scale", "v_scale", "ssm", "cross_k", "cross_v")
        if k in cache
    ]
    layer_cache = {k: cache[k] for k in keys}
    # quantized pool: prefill quantizes at write time, exactly like appends
    kv_dtype = (
        ("int8" if cache["k"].dtype == jnp.int8 else "fp8")
        if "k_scale" in cache else "fp"
    )

    def _kv_for_cache(h, lp):
        return _project_kv_for_cache(h, lp, cfg, positions)

    def body(x, inp):
        lp, cl = inp
        new_cl = dict(cl)
        aux = jnp.float32(0.0)
        if has_attn:
            h = rms_norm(x, lp["attn"]["ln"]) if cfg.norm == "rmsnorm" else layer_norm(
                x, lp["attn"]["ln"], lp["attn"]["ln_b"]
            )
            kk, vv = _kv_for_cache(h, lp["attn"])
            if paged and kv_dtype != "fp":
                qk, sk = kv_quant.quantize(kk[0], kv_dtype)
                qv, sv = kv_quant.quantize(vv[0], kv_dtype)
                new_cl["k"] = cl["k"].at[page_idx, col_idx].set(qk, mode="drop")
                new_cl["v"] = cl["v"].at[page_idx, col_idx].set(qv, mode="drop")
                new_cl["k_scale"] = cl["k_scale"].at[page_idx, col_idx].set(
                    sk, mode="drop"
                )
                new_cl["v_scale"] = cl["v_scale"].at[page_idx, col_idx].set(
                    sv, mode="drop"
                )
            elif paged:
                new_cl["k"] = cl["k"].at[page_idx, col_idx].set(
                    kk[0].astype(cl["k"].dtype), mode="drop"
                )
                new_cl["v"] = cl["v"].at[page_idx, col_idx].set(
                    vv[0].astype(cl["v"].dtype), mode="drop"
                )
            else:
                new_cl["k"] = cl["k"].at[:, g_idx].set(kk.astype(cl["k"].dtype))
                new_cl["v"] = cl["v"].at[:, g_idx].set(vv.astype(cl["v"].dtype))
        if cfg.encoder_layers:
            B = x.shape[0]
            new_cl["cross_k"] = (enc @ lp["xattn"]["wk"]).reshape(
                B, cfg.encoder_seq, cfg.num_heads, cfg.hd
            ).astype(cl["cross_k"].dtype)
            new_cl["cross_v"] = (enc @ lp["xattn"]["wv"]).reshape(
                B, cfg.encoder_seq, cfg.num_heads, cfg.hd
            ).astype(cl["cross_v"].dtype)
        # run the block; collect SSM final state where present
        if cfg.family == "ssm":
            x, st = ssm_mod.ssm_block(x, lp["ssm"], cfg, ctx, return_state=True)
            new_cl["ssm"] = {
                "conv": st["conv"].astype(cl["ssm"]["conv"].dtype),
                "state": st["state"],
            }
        elif cfg.hybrid:
            a = attn.attention_block(x, lp["attn"], cfg, ctx, positions) - x
            sx, st = ssm_mod.ssm_block(x, lp["ssm"], cfg, ctx, return_state=True)
            new_cl["ssm"] = {
                "conv": st["conv"].astype(cl["ssm"]["conv"].dtype),
                "state": st["state"],
            }
            x = x + 0.5 * (a + (sx - x))
            if cfg.d_ff > 0:
                x = mlp_block(x, lp["mlp"], cfg, ctx)
        else:
            x, aux = _decoder_block(x, lp, cfg, ctx, positions, enc=enc)
        return x, new_cl

    if ctx.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, new_layer_cache = _stack_scan(body, x, (params["layers"], layer_cache), ctx)
    x = _final_norm(x, params, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    B = tokens.shape[0]
    if "length" in batch:
        # right-padded bucket: each row's last real token sits where
        # positions == length-1 (striping scrambles index != position)
        length = batch["length"].astype(jnp.int32)
        last_idx = jnp.argmax(positions[None, :] == (length[:, None] - 1), axis=1)
        x_last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)
        new_pos = length
    else:
        # under striping the LAST POSITION is not the last index
        last_idx = jnp.argmax(positions)
        x_last = jnp.take(x, last_idx[None], axis=1)
        new_pos = jnp.full((B,), S, jnp.int32)
    logits = x_last @ head.astype(x.dtype)
    new_cache = dict(cache)
    new_cache.update(new_layer_cache)
    if paged:
        # the pool cache's pos covers every slot; only this one was prefilled
        new_cache["pos"] = cache["pos"].at[slot].set(length_s)
    else:
        new_cache["pos"] = new_pos
    return logits, new_cache


def prefill_packed(params, cfg: ModelConfig, ctx: ParallelCtx, batch: Dict, cache):
    """Packed multi-document prefill: several prompts share ONE batch row.

    The row carries a document (segment-id) attention mask — causal within
    each prompt, nothing across prompts — and each document's K/V is
    scattered into ITS OWN slot row of the pool cache, so one forward pass
    prefills several serving slots.

    ``batch`` (all in the row's striped order where applicable):
      tokens    [1, P]  the packed, right-padded row
      positions [P]     per-document positions (restart at each doc start)
      segments  [P]     document id per token; pads carry id >= k
      doc_lens  [k]     true prompt lengths (runtime)
      slots     [k]     pool slot per document (runtime)
      shared_lens [k]   optional: tokens admitted as SHARED pages per doc
                        (paged cache only) — skipped by the scatter

    ``cache`` is the POOL cache ([L, num_slots, cap, ...]), or the PAGED pool
    ([L, num_pages, n*page_size, ...] + block table ``"bt"``) — each
    document's K/V then scatters through its slot's block-table row, and
    positions below ``shared_lens[d]`` are left to the pages' owner.  Returns
    (first-token logits [k, V], new cache).  Attention-only decoder archs:
    the SSD recurrent state has no per-document reset, encoder/frontend
    archs have per-row side inputs that do not pack.
    """
    if cfg.ssm is not None or cfg.encoder_layers or cfg.frontend:
        raise ValueError("packed prefill supports attention-only decoder archs")
    tokens, positions = batch["tokens"], batch["positions"]
    segments = batch["segments"]
    doc_lens = batch["doc_lens"].astype(jnp.int32)
    slots = batch["slots"].astype(jnp.int32)
    k_docs = slots.shape[0]
    n = ctx.sp_size
    paged = "bt" in cache
    # quantized pool: packed prefill quantizes at write time like appends
    kv_dtype = (
        ("int8" if cache["k"].dtype == jnp.int8 else "fp8")
        if "k_scale" in cache else "fp"
    )

    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.constrain(x, "seq", None)

    pad = segments >= k_docs
    seg_c = jnp.clip(segments, 0, k_docs - 1)
    if paged:
        # paged coordinates per token: document d's position p goes through
        # slot slots[d]'s block-table row to (page, n*page_size column);
        # pads and shared-prefix positions are dropped by the scatter
        page_size = cache["k"].shape[2] // max(n, 1)
        shared = batch.get("shared_lens")
        shared = (
            jnp.zeros((k_docs,), jnp.int32) if shared is None
            else jnp.asarray(shared, jnp.int32)
        )
        write_mask = (~pad) & (positions >= shared[seg_c])
        row_idx, g_idx = _paged_prefill_coords(
            positions, cache["bt"][slots[seg_c]], max(n, 1), page_size, write_mask
        )
    else:
        nslots, cap = cache["k"].shape[1], cache["k"].shape[2]
        # cache coordinates per token: document d's position p lands in slot
        # row slots[d] at the striped cache index (p % n)*(cap/n) + p//n;
        # pads get an out-of-range row and are dropped by the scatter
        row_idx = jnp.where(pad, nslots, slots[seg_c])
        if n > 1:
            g_idx = (positions % n) * (cap // n) + positions // n
        else:
            g_idx = positions

    def body(x, inp):
        lp, cl = inp
        new_cl = dict(cl)
        h = rms_norm(x, lp["attn"]["ln"]) if cfg.norm == "rmsnorm" else layer_norm(
            x, lp["attn"]["ln"], lp["attn"]["ln_b"]
        )
        kk, vv = _project_kv_for_cache(h, lp["attn"], cfg, positions)
        if kv_dtype != "fp":
            qk, sk = kv_quant.quantize(kk[0], kv_dtype)
            qv, sv = kv_quant.quantize(vv[0], kv_dtype)
            new_cl["k"] = cl["k"].at[row_idx, g_idx].set(qk, mode="drop")
            new_cl["v"] = cl["v"].at[row_idx, g_idx].set(qv, mode="drop")
            new_cl["k_scale"] = cl["k_scale"].at[row_idx, g_idx].set(
                sk, mode="drop"
            )
            new_cl["v_scale"] = cl["v_scale"].at[row_idx, g_idx].set(
                sv, mode="drop"
            )
        else:
            new_cl["k"] = cl["k"].at[row_idx, g_idx].set(
                kk[0].astype(cl["k"].dtype), mode="drop"
            )
            new_cl["v"] = cl["v"].at[row_idx, g_idx].set(
                vv[0].astype(cl["v"].dtype), mode="drop"
            )
        x, _ = _decoder_block(x, lp, cfg, ctx, positions, segments=segments)
        return x, new_cl

    if ctx.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    layer_cache = {
        key: cache[key]
        for key in ("k", "v", "k_scale", "v_scale") if key in cache
    }
    x, new_layer_cache = _stack_scan(body, x, (params["layers"], layer_cache), ctx)
    x = _final_norm(x, params, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # document d's last real token sits where segments == d AND positions ==
    # doc_lens[d]-1 (striping scrambles index != position)
    match = (segments[None, :] == jnp.arange(k_docs)[:, None]) & (
        positions[None, :] == (doc_lens - 1)[:, None]
    )
    last_idx = jnp.argmax(match, axis=1)  # [k]
    x_last = x[0, last_idx]  # [k, D]
    logits = x_last @ head.astype(x.dtype)
    new_cache = dict(cache)
    new_cache.update(new_layer_cache)
    new_cache["pos"] = cache["pos"].at[slots].set(doc_lens)
    return logits, new_cache
