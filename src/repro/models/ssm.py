"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

The SSD scan is computed in chunked dual form: quadratic attention-like
matmuls within chunks + a linear state recurrence across chunks — the same
structure the Pallas kernel (kernels/ssd_scan.py) tiles for VMEM.

Sequence parallelism: Mesh-Attention does not apply (no Q·Kᵀ — see DESIGN.md
§Arch-applicability); instead the sequence is sharded *contiguously* over the
model axis and the recurrence crosses devices through its (tiny) state:

  1. each device runs the chunked scan with h0 = 0, producing its final
     state S_i and total decay T_i (both O(H·P·N) — KBs, not chunks),
  2. one all-gather of {(S_i, T_i)} and a closed-form prefix combine give the
     true incoming state h0_i = sum_{j<i} (prod_{j<k<i} T_k) S_j,
  3. outputs are corrected in closed form: y_t += C_t · (cumdecay_t · h0_i);
     the causal depthwise conv exchanges a (width-1)-token halo by ppermute.

Communication per layer is O(n · H·P·N) bytes — negligible next to attention
— which is why the roofline for mamba2/hymba cells is compute/memory-bound.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.parallel.context import ParallelCtx

__all__ = ["init_ssm_params", "ssm_block", "ssm_dims", "init_ssm_cache", "ssm_decode_step"]


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.n_groups, s.state_dim, s.head_dim


def init_ssm_params(key, cfg: ModelConfig, L: int, dtype) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_inner, H, G, N, Pd = ssm_dims(cfg)
    conv_dim = d_inner + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((L, D), dtype),
        # fused input projection -> (z, x, B, C, dt)
        "in_proj": dense_init(ks[0], (L, D, 2 * d_inner + 2 * G * N + H), dtype=dtype),
        "conv_w": dense_init(ks[1], (L, s.conv_width, conv_dim), in_axis=-2, dtype=dtype),
        "conv_b": jnp.zeros((L, conv_dim), dtype),
        "A_log": jnp.zeros((L, H), jnp.float32),  # A = -exp(A_log) = -1 init
        "D_skip": jnp.ones((L, H), jnp.float32),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "out_ln": jnp.zeros((L, d_inner), dtype),
        "out_proj": dense_init(ks[2], (L, d_inner, D), dtype=dtype),
    }


# --------------------------------------------------------------------------
# chunked SSD (local sequence)
# --------------------------------------------------------------------------


def _ssd_chunked(
    x: jnp.ndarray,  # [B, S, H, P] (fp32)
    dt: jnp.ndarray,  # [B, S, H]  (fp32, softplus applied)
    A: jnp.ndarray,  # [H] (negative, fp32)
    Bm: jnp.ndarray,  # [B, S, H, N] (groups already broadcast)
    Cm: jnp.ndarray,  # [B, S, H, N]
    chunk: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """-> (y_zero [B,S,H,P], h_in_chunks [B,nc,H,P,N], cumT [B,nc,H], extras)

    y_zero is the output with zero initial state; h_in_chunks are the
    incoming states per chunk under h0=0; cumT[z] = decay from sequence start
    to the start of chunk z.  The device-level correction only needs:
        y = y_zero + einsum(C_t, exp(Acum_t) * cumT[z] * h0)
    Also returns (final_state, total_decay) for the cross-device combine.
    """
    Bb, S, H, Pd = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    c = chunk
    xr = x.reshape(Bb, nc, c, H, Pd)
    dtr = dt.reshape(Bb, nc, c, H)
    Br = Bm.reshape(Bb, nc, c, H, N)
    Cr = Cm.reshape(Bb, nc, c, H, N)
    a = dtr * A  # [B,nc,c,H] negative
    Acum = jnp.cumsum(a, axis=2)  # inclusive

    # intra-chunk (dual quadratic form): y[t] = sum_{s<=t} L[t,s] (C_t.B_s) dt_s x_s
    Ldec = jnp.exp(Acum[:, :, :, None, :] - Acum[:, :, None, :, :])  # [B,nc,t,s,H]
    mask = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(mask[None, None, :, :, None], Ldec, 0.0)
    scores = jnp.einsum("bzthn,bzshn->bztsh", Cr, Br)
    y_intra = jnp.einsum("bztsh,bzsh,bzshp->bzthp", L * scores, dtr, xr)

    # chunk summary states: contribution of chunk z to its end-state
    decay_to_end = jnp.exp(Acum[:, :, -1:, :] - Acum)  # [B,nc,c,H]
    chunk_state = jnp.einsum("bzch,bzch,bzchn,bzchp->bzhpn", decay_to_end, dtr, Br, xr)
    T = jnp.exp(Acum[:, :, -1, :])  # total decay per chunk [B,nc,H]

    # inter-chunk prefix (h0 = 0)
    def step(h, inp):
        cs, t = inp
        h_in = h
        h = t[:, :, None, None] * h + cs
        return h, h_in

    hT, h_in_chunks = lax.scan(
        step, jnp.zeros((Bb, H, Pd, N), jnp.float32),
        (chunk_state.transpose(1, 0, 2, 3, 4), T.transpose(1, 0, 2)),
    )
    h_in_chunks = h_in_chunks.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    y_inter = jnp.einsum("bzthn,bzth,bzhpn->bzthp", Cr, jnp.exp(Acum), h_in_chunks)
    y_zero = (y_intra + y_inter).reshape(Bb, S, H, Pd)

    cumT = jnp.exp(jnp.cumsum(jnp.sum(a, axis=2), axis=1) - jnp.sum(a, axis=2))  # decay to chunk start
    total_decay = jnp.exp(jnp.sum(a, axis=(1, 2)))  # [B,H]
    return y_zero, (Cr, Acum, cumT), hT, total_decay


def _apply_initial_state(y_zero, extras, h0):
    """Closed-form correction for a nonzero initial state."""
    Cr, Acum, cumT = extras
    Bb, nc, c, H, N = Cr.shape
    corr = jnp.einsum(
        "bzthn,bzth,bzh,bhpn->bzthp", Cr, jnp.exp(Acum), cumT, h0
    )
    return y_zero + corr.reshape(y_zero.shape)


def ssd_scan(x, dt, A, Bm, Cm, chunk, h0=None):
    """Single-device SSD: returns (y, final_state)."""
    y_zero, extras, hT, total = _ssd_chunked(x, dt, A, Bm, Cm, chunk)
    if h0 is not None:
        y_zero = _apply_initial_state(y_zero, extras, h0)
        hT = hT + total[:, :, None, None] * h0
    return y_zero, hT


# --------------------------------------------------------------------------
# distributed core (conv halo + state passing) — runs inside shard_map
# --------------------------------------------------------------------------


def _conv1d_causal(xin, w, b, halo):
    """Depthwise causal conv. xin [B,S,C], w [width,C], halo [B,width-1,C]."""
    width = w.shape[0]
    xp = jnp.concatenate([halo, xin], axis=1)
    out = sum(
        xp[:, i : i + xin.shape[1]] * w[i][None, None, :] for i in range(width)
    )
    return out + b[None, None, :]


def _ssm_core(zxbcdt, p, cfg: ModelConfig, axis_name: Optional[str], n: int):
    """From fused projection to gated SSD output (pre out_proj).

    Returns (y, hT_global [B,H,P,N] fp32, conv_tail [B,w-1,conv_dim]) — the
    final recurrence state and conv window, identical on every device (needed
    for prefill -> decode continuity).
    """
    s = cfg.ssm
    d_inner, H, G, N, Pd = ssm_dims(cfg)
    Bb, S, _ = zxbcdt.shape
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + G * N, 2 * d_inner + 2 * G * N], axis=-1
    )
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    width = s.conv_width
    if axis_name is not None and n > 1:
        # halo exchange: last width-1 tokens from the left neighbour
        # (device 0 has no source pair -> ppermute fills zeros = causal pad)
        tail = conv_in[:, -(width - 1) :, :]
        halo = lax.ppermute(tail, axis_name, [(i, i + 1) for i in range(n - 1)])
    else:
        halo = jnp.zeros((Bb, width - 1, conv_in.shape[-1]), conv_in.dtype)
    conv_out = jax.nn.silu(_conv1d_causal(conv_in, p["conv_w"], p["conv_b"], halo))
    xc, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)

    xh = xc.reshape(Bb, S, H, Pd).astype(jnp.float32)
    Bh = jnp.repeat(Bc.reshape(Bb, S, G, N), H // G, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cc.reshape(Bb, S, G, N), H // G, axis=2).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y_zero, extras, hT, total = _ssd_chunked(xh, dtf, A, Bh, Ch, min(s.chunk, S))
    conv_tail = conv_in[:, -(width - 1) :, :]
    if axis_name is not None and n > 1:
        i = lax.axis_index(axis_name)
        # gather every device's (zero-init final state, total decay) — a few
        # KB per device; this is the entire cross-device cost of the SSD scan
        allS = lax.all_gather(hT, axis_name)  # [n,B,H,P,N]
        allT = lax.all_gather(total, axis_name)  # [n,B,H]
        # h0_i = sum_{j<i} (prod_{j<k<i} T_k) S_j   (static unroll over n)
        h0 = jnp.zeros_like(hT)
        for j in range(n):
            contrib = allS[j]
            decay = jnp.ones_like(total)
            for k in range(j + 1, n):
                decay = jnp.where(k < i, decay * allT[k], decay)
            h0 = h0 + jnp.where(j < i, (decay[:, :, None, None] * contrib), 0.0)
        y_zero = _apply_initial_state(y_zero, extras, h0)
        hT = hT + total[:, :, None, None] * h0
        # global final state (same value on every device): prefix over ALL j
        hT_global = jnp.zeros_like(hT)
        for j in range(n):
            dacc = jnp.ones_like(total)
            for k in range(j + 1, n):
                dacc = dacc * allT[k]
            hT_global = hT_global + dacc[:, :, None, None] * allS[j]
        # global conv tail = last device's tail
        all_tails = lax.all_gather(conv_tail, axis_name)
        conv_tail = all_tails[n - 1]
        hT = hT_global

    y = y_zero + p["D_skip"][None, None, :, None].astype(jnp.float32) * xh
    y = y.reshape(Bb, S, d_inner).astype(z.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_ln"])
    return y, hT, conv_tail


def ssm_block(
    x: jnp.ndarray, p: dict, cfg: ModelConfig, ctx: ParallelCtx, *, return_state: bool = False
):
    h = rms_norm(x, p["ln"])
    zxbcdt = h @ p["in_proj"]
    n = ctx.sp_size
    if n > 1:
        bs = ctx.eff_batch_spec(x.shape[0])
        spec = P(bs, ctx.sp_axis, None)
        rep3 = P(bs, None, None)
        rep4 = P(bs, None, None, None)
        core = shard_map(
            functools.partial(_ssm_core, cfg=cfg, axis_name=ctx.sp_axis, n=n),
            mesh=ctx.shard_map_mesh(),
            in_specs=(spec, P()),
            out_specs=(spec, rep4, rep3),
            check_vma=False,
        )
        y, hT, conv_tail = core(zxbcdt, p)
    else:
        y, hT, conv_tail = _ssm_core(zxbcdt, p, cfg, None, 1)
    out = x + y @ p["out_proj"]
    if return_state:
        return out, {"state": hT, "conv": conv_tail.astype(x.dtype)}
    return out


# --------------------------------------------------------------------------
# decode (O(1) per token; states replicated over the model axis)
# --------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, L: int, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, H, G, N, Pd = ssm_dims(cfg)
    conv_dim = d_inner + 2 * G * N
    return {
        "conv": jnp.zeros((L, batch, s.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((L, batch, H, Pd, N), jnp.float32),
    }


def ssm_decode_step(x, p, cache_l, cfg: ModelConfig):
    """x [B, 1, D]; cache_l = {conv [B,w-1,C], state [B,H,P,N]} (one layer).

    Returns (y [B,1,D] residual-added, new cache_l).
    """
    s = cfg.ssm
    d_inner, H, G, N, Pd = ssm_dims(cfg)
    Bb = x.shape[0]
    h = rms_norm(x, p["ln"])
    zxbcdt = h @ p["in_proj"]
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + G * N, 2 * d_inner + 2 * G * N], axis=-1
    )
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)  # [B,1,C]
    window = jnp.concatenate([cache_l["conv"], conv_in], axis=1)  # [B,w,C]
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"][None, :]
    )[:, None, :]
    new_conv = window[:, 1:, :]
    xc, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)
    xh = xc.reshape(Bb, H, Pd).astype(jnp.float32)
    Bh = jnp.repeat(Bc.reshape(Bb, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cc.reshape(Bb, G, N), H // G, axis=1).astype(jnp.float32)
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])  # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtf * A)[..., None, None]
    hstate = decay * cache_l["state"] + jnp.einsum("bh,bhp,bhn->bhpn", dtf, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", hstate, Ch) + p["D_skip"][None, :, None] * xh
    y = y.reshape(Bb, 1, d_inner).astype(z.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_ln"])
    return x + y @ p["out_proj"], {"conv": new_conv, "state": hstate}
