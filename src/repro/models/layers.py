"""Shared building blocks: norms, rotary embeddings, initializers, losses."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "layer_norm",
    "rope",
    "dense_init",
    "vocab_cross_entropy",
]


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope(
    x: jnp.ndarray,  # [B, S, H, D]
    positions: jnp.ndarray,  # [S] or [B, S] int32 (true token positions; striped-aware)
    theta: float = 10000.0,
) -> jnp.ndarray:
    """Rotary position embedding over the last dim (pairs interleaved as
    [first half, second half], llama convention)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32) -> jnp.ndarray:
    """Truncated-normal fan-in init (scale 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = fan_in**-0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def vocab_cross_entropy(
    logits: jnp.ndarray,  # [B, S, V] (any float dtype; reductions in fp32)
    labels: jnp.ndarray,  # [B, S] int32
    mask: Optional[jnp.ndarray] = None,  # [B, S] 0/1
) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
