"""Architecture registry: one module per assigned architecture (plus the
paper's own attention benchmark config).  ``--arch <id>`` resolves here."""

from repro.configs.base import (
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_config,
    register,
)

ALL_ARCHS = [
    "pixtral-12b",
    "mamba2-370m",
    "whisper-base",
    "qwen2.5-32b",
    "gemma-7b",
    "granite-8b",
    "minicpm3-4b",
    "mixtral-8x7b",
    "qwen2-moe-a2.7b",
    "hymba-1.5b",
]

# the paper's §4.1 attention configuration embedded in a llama-style body,
# used by the paper-table benchmarks
PAPER_ARCH = "paper-mha-7b"
