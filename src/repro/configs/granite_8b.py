"""Granite-8B (code): llama-architecture dense transformer.

[arXiv:2405.04324; hf] — 36L, d_model=4096, 32 heads (GQA kv=8),
d_ff=14336, vocab=49152.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        source="arXiv:2405.04324 (hf)",
    )
)
