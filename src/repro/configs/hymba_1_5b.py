"""Hymba-1.5B: hybrid-head transformer — parallel attention + Mamba heads
inside every layer.

[arXiv:2411.13676; hf] — 32L, d_model=1600, 25 heads (GQA kv=5, head_dim=64),
d_ff=5504, vocab=32001, ssm_state=16.  Attention heads use sliding-window
(per the paper, most layers are SWA); SSM heads run the SSD scan in parallel
and the two outputs are mean-fused.  Meta-tokens are omitted (stub noted in
DESIGN.md).  Contiguous (non-striped) sequence layout because of the SSM
recurrence.
"""

from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        window=1024,
        hybrid=True,
        ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_width=4),
        source="arXiv:2411.13676 (hf)",
    )
)
