"""Whisper-base: encoder-decoder audio transformer, conv frontend stubbed.

[arXiv:2212.04356; unverified] — 6 encoder + 6 decoder layers, d_model=512,
8 heads (MHA), d_ff=2048, vocab=51865.  LayerNorm + plain GELU MLP.  The conv
frontend is a STUB: ``input_specs()`` provides 80-d mel-frame features; a
learned projection stands in for the two conv layers (1500 frames / 30 s).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-base",
        family="audio",
        num_layers=6,  # decoder layers
        encoder_layers=6,
        encoder_seq=1536,  # whisper's 1500 frames padded to a multiple of the
        # 16-wide model axis so the encoder sequence shards evenly
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        mlp_act="gelu",
        mlp_gated=False,
        norm="layernorm",
        frontend="audio_stub",
        frontend_dim=80,
        tie_embeddings=True,
        source="arXiv:2212.04356 (unverified)",
    )
)
