"""Model / parallelism / run configuration.

One ``ModelConfig`` describes every architecture in the assigned pool; family
behaviour (MoE, SSM, hybrid, encoder-decoder, modality frontend) is switched
by optional sub-configs.  ``reduced()`` produces the family-preserving small
config used by the per-arch CPU smoke tests; the full configs are exercised
only through the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "MLAConfig",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "register",
    "get_config",
    "list_archs",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0  # qwen2-moe: shared experts (merged into one MLP)
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # renormalize top-k weights to sum to 1
    mode: str = "tp"  # "tp": d_ff sharded over model | "ep": expert-parallel a2a


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int  # N
    head_dim: int = 64  # P
    expand: int = 2  # d_inner = expand * d_model
    conv_width: int = 4
    n_groups: int = 1
    dt_min: float = 0.001
    dt_max: float = 0.1
    chunk: int = 64  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads (gemma: 256)
    mlp_act: str = "silu"  # silu -> SwiGLU, gelu -> GeGLU (gated in both cases)
    mlp_gated: bool = True  # whisper uses a plain (ungated) GELU MLP
    qkv_bias: bool = False  # qwen2.5 / minicpm3 style
    window: Optional[int] = None  # sliding-window attention (mixtral, hymba)
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm (whisper)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    hybrid: bool = False  # hymba: parallel attn + ssm heads in each layer
    # encoder-decoder (whisper): encoder_layers > 0 enables cross-attention
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30 s of audio frames after conv stub
    frontend: Optional[str] = None  # "audio_stub" | "vision_stub"
    frontend_dim: int = 0  # raw feature dim entering the stub projection
    num_patches: int = 0  # vlm: image patch embeddings per sample
    source: str = ""  # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def causal_layout(self) -> str:
        """Striped layout balances the causal mask (paper §3.7) but breaks the
        SSM recurrence's contiguity, so SSM/hybrid archs shard contiguously."""
        return "contiguous" if (self.ssm is not None) else "striped"

    def reduced(self) -> "ModelConfig":
        """Family-preserving small config for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32 if self.head_dim else None,
            d_ff=256,
            vocab_size=512,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=32 if self.encoder_layers else self.encoder_seq,
            num_patches=8 if self.num_patches else 0,
            frontend_dim=16 if self.frontend_dim else 0,
            window=16 if self.window else None,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                d_ff_shared=128 if self.moe.d_ff_shared else 0,
            )
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk=8
            )
        if self.mla:
            changes["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                qk_rope_head_dim=16, v_head_dim=32,
            )
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the module to trigger registration
        import importlib

        module = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{module}")
    return _REGISTRY[name]


def list_archs():
    from repro.configs import ALL_ARCHS

    return list(ALL_ARCHS)
