"""Mixtral-8x7B: sparse MoE (8 experts, top-2) with sliding-window attention.

[arXiv:2401.04088; hf] — 32L, d_model=4096, 32 heads (GQA kv=8),
expert d_ff=14336, vocab=32000, SWA window 4096.  8 experts < the 16-wide
model axis, so the production MoE mode is "tp" (expert d_ff sharded);
EP mode is exercised on divisible fake-device meshes in tests.
"""

from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        window=4096,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336, mode="tp"),
        source="arXiv:2401.04088 (hf)",
    )
)
