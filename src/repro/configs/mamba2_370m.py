"""Mamba2-370m: attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; unverified] — 48L, d_model=1024, vocab=50280,
ssm_state=128.  d_inner = 2*d_model = 2048 -> 32 SSD heads of P=64.
Mesh-Attention is INAPPLICABLE (no Q·Kᵀ); the SSD scan is sequence-sharded
with chunked state passing (see DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=32,  # SSD heads (d_inner / head_dim)
        num_kv_heads=32,
        d_ff=0,  # attention-free, MLP-free (SSD blocks only)
        vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4),
        tie_embeddings=True,
        source="arXiv:2405.21060 (unverified)",
    )
)
