"""Qwen2-MoE-A2.7B (Qwen1.5-MoE-A2.7B): fine-grained MoE, 60 routed top-4 +
4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 24L, d_model=2048, 16 heads (kv=16),
expert d_ff=1408 (shared expert 4x1408=5632), vocab=151936.  60 experts pad
to 64 for expert parallelism over the 16-wide model axis (4 per device;
padding experts receive -inf router logits).
"""

from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            d_ff_expert=1408,
            num_shared=4,
            d_ff_shared=5632,
            mode="ep",
        ),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B (hf)",
    )
)
