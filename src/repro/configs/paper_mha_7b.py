"""The paper's §4.1 attention configuration (32 heads x head_dim 128 =
hidden 4096, MHA) embedded in a llama-7B-style dense body — used by the
paper-table benchmarks (Tables 3/4, Figs. 8/9/10) and as the most
"representative of the paper's technique" hillclimb cell.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="paper-mha-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,  # MHA, as in the paper's main tables
        head_dim=128,
        d_ff=11008,
        vocab_size=32000,
        source="paper §4.1 attention config; llama-7b body",
    )
)
