"""Pixtral-12B: Pixtral-ViT frontend (stub) + Mistral-Nemo decoder backbone.

[hf:mistralai/Pixtral-12B-2409; unverified] — 40L, d_model=5120, 32 heads
(GQA kv=8, head_dim=128), d_ff=14336, vocab=131072.  The vision frontend is
a STUB per the assignment: ``input_specs()`` supplies precomputed 1024-d
patch embeddings which a learned projection maps into the token stream.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        mlp_act="silu",
        rope_theta=1e6,
        frontend="vision_stub",
        frontend_dim=1024,
        num_patches=1024,
        source="hf:mistralai/Pixtral-12B-2409 (unverified)",
    )
)
