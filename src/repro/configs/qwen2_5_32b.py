"""Qwen2.5-32B: dense GQA transformer with QKV bias.

[hf:Qwen/Qwen2.5-0.5B family scaling; hf] — 64L, d_model=5120, 40 heads
(GQA kv=8, head_dim=128), d_ff=27648, vocab=152064.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        source="hf:Qwen/Qwen2.5-32B (hf)",
    )
)
