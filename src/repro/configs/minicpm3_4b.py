"""MiniCPM3-4B: dense transformer with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf] — 62L, d_model=2560, 40 heads (kv=40),
d_ff=6400, vocab=73448.  MLA compresses Q through a 768-rank bottleneck and
KV through a 256-rank latent; distributed attention operates on the
decompressed per-head K/V (the latent is what the cache stores).
"""

from repro.configs.base import MLAConfig, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        qkv_bias=False,
        mla=MLAConfig(
            q_lora_rank=768,
            kv_lora_rank=256,
            qk_nope_head_dim=64,
            qk_rope_head_dim=32,
            v_head_dim=64,
        ),
        source="hf:openbmb/MiniCPM3-4B (hf)",
    )
)
