"""Gradient compression for the cross-pod all-reduce.

At 512+ chips the pod axis crosses data-center-network (DCN) links that are
an order of magnitude slower than ICI, so the once-per-step gradient
all-reduce across pods dominates unless compressed.  We provide:

  * int8 linear quantization with **error feedback** (the quantization
    residual is added back into the next step's gradient — Seide et al.
    2014 / Karimireddy et al. 2019), which keeps SGD/Adam convergence
    unbiased in practice;
  * top-k sparsification with error feedback (magnitude pruning per leaf);
  * ``compressed_psum``: a drop-in for ``lax.psum`` on a named (pod) axis
    that quantizes before the wire and dequantizes after.

Tests (tests/test_substrate.py + the dist battery) validate convergence
parity on a toy regression against the uncompressed baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["CompressionConfig", "init_error_state", "compress_grads", "compressed_psum"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "int8"  # "int8" | "topk" | "none"
    topk_frac: float = 0.05
    error_feedback: bool = True


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _topk_mask(x: jnp.ndarray, frac: float) -> jnp.ndarray:
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_grads(grads, err, cfg: CompressionConfig):
    """-> (decompressed grads as transmitted, new error state).

    Models the wire format locally (quantize -> dequantize) so the SAME code
    path runs on CPU tests and in the shard_map'd cross-pod reduction.
    """
    if cfg.kind == "none":
        return grads, err

    def one(g, e):
        gf = g.astype(jnp.float32) + (e if cfg.error_feedback else 0.0)
        if cfg.kind == "int8":
            q, s = _quantize_int8(gf)
            out = _dequantize_int8(q, s)
        else:
            out = gf * _topk_mask(gf, cfg.topk_frac)
        new_e = gf - out
        return out.astype(g.dtype), new_e

    flat, tdef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(err)
    outs, errs = zip(*(one(g, e) for g, e in zip(flat, eflat)))
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, errs)


def compressed_psum(grads, axis_name: str, err, cfg: CompressionConfig):
    """Quantize -> psum over ``axis_name`` -> average.  Returns (mean grads,
    new error state).  Call inside shard_map with the pod axis manual."""
    n = lax.psum(1, axis_name)
    sent, err = compress_grads(grads, err, cfg)
    summed = jax.tree.map(lambda g: lax.psum(g, axis_name) / n, sent)
    return summed, err
