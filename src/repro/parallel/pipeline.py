"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

The production dry-run mesh is DP x SP/TP (the paper's focus is
attention-level parallelism), but at >512-node scale depth must also shard.
This module provides a static fill-drain (GPipe) schedule as a composable
primitive:

  * the layer stack [L, ...] is reshaped to [n_stages, L/n_stages, ...] and
    sharded over "pipe" (each stage holds its contiguous layer slice),
  * the batch is split into M microbatches; activations flow stage->stage
    through ``ppermute`` once per tick; the loop runs M + n_stages - 1 ticks
    (bubble fraction = (S-1)/(M+S-1)),
  * everything is differentiable by plain autodiff (JAX transposes the
    ppermutes), so ``jax.grad`` through ``pipeline_apply`` trains.

The schedule is lock-step and static — every stage computes every tick
(garbage in the bubbles is masked at the edges), which is the standard
SPMD-friendly formulation.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["pipeline_apply", "pipeline_stages"]


def pipeline_stages(stacked_params, n_stages: int):
    """[L, ...] pytree -> [n_stages, L/n_stages, ...] (shard dim 0 on 'pipe')."""

    def f(x):
        L = x.shape[0]
        if L % n_stages:
            raise ValueError(f"{L} layers not divisible by {n_stages} stages")
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(f, stacked_params)


def _stage_perm(n_stages: int):
    return [(s, s + 1) for s in range(n_stages - 1)]


def pipeline_apply(
    layer_fn: Callable,  # (layer_params, x) -> x  (one layer)
    staged_params,  # pytree with leading [n_stages, L/S, ...] dims
    x: jnp.ndarray,  # [M, mb, ...] microbatched input (replicated over pipe)
    *,
    mesh,
    n_stages: int,
    axis: str = "pipe",
    extra_specs=P(),
) -> jnp.ndarray:
    """Run the microbatches through the pipeline; returns [M, mb, ...]
    outputs (replicated over the pipe axis for downstream use)."""
    M = x.shape[0]
    perm = _stage_perm(n_stages)

    def stage_fn(params_slice, x_in):
        def body(h, lp):
            return layer_fn(lp, h), None

        h, _ = lax.scan(body, x_in, params_slice)
        return h

    def inner(staged, xs):
        i = lax.axis_index(axis)
        my_params = jax.tree.map(lambda p: p[0], staged)  # [1, L/S, ...] -> [L/S, ...]
        buf = jnp.zeros_like(xs[0])
        n_ticks = M + n_stages - 1
        outs = jnp.zeros((M,) + xs.shape[1:], xs.dtype)

        def tick(carry, t):
            buf, outs = carry
            mb_idx = jnp.clip(t, 0, M - 1)
            inject = xs[mb_idx]
            x_in = jnp.where((i == 0) & (t < M), inject, buf)
            y = stage_fn(my_params, x_in)
            # stage s produced microbatch (t - s); valid on the LAST stage
            # when 0 <= t - (S-1) < M
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            is_valid = (i == n_stages - 1) & (t >= n_stages - 1)
            upd = jnp.where(is_valid, y, outs[out_idx])
            outs = lax.dynamic_update_index_in_dim(outs, upd, out_idx, 0)
            buf = lax.ppermute(y, axis, perm) if n_stages > 1 else y
            return (buf, outs), None

        (buf, outs), _ = lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # broadcast the last stage's collected outputs to every stage
        stage_hot = (i == n_stages - 1).astype(outs.dtype)
        outs = lax.psum(outs * stage_hot, axis)
        return outs

    f = shard_map(
        inner,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), staged_params), extra_specs),
        out_specs=P(),
        check_vma=False,
    )
    return f(staged_params, x)
