"""Runtime parallelism context threaded through the model code.

``ParallelCtx`` carries the mesh handle and the axis roles; model layers use
it to (a) place sharding constraints on activations, (b) wrap attention in
``shard_map`` over the sequence-parallel axis with the configured
Mesh-Attention tile/schedule, and (c) pick MoE/SSM distribution modes.
``ParallelCtx()`` (no mesh) is the single-device mode used by smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

__all__ = ["ParallelCtx"]


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ()  # e.g. ("pod", "data")
    sp_axis: Optional[str] = None  # sequence-parallel axis (e.g. "model")
    # --- Mesh-Attention configuration (the paper's knobs) ---
    attn_impl: str = "mesh"  # any registered dispatch backend (mesh | ring | ulysses | ...)
    mesh_a: Optional[int] = None  # tile height; None -> divisor closest to sqrt(n)
    allow_concurrent_rings: bool = False
    bwd_wire: str = "qdod"
    comm_overlap: str = "overlap"  # ring transport: serial (permutes barriered
    # ahead of the blocks) | overlap (in flight during them, default) | bidir
    # (half-payload ppermute pairs over both ring directions); bitwise-equal
    block_q: int = 128
    block_kv: int = 128
    attn_autotune: bool = False  # pick (a, b) + schedules via the simulator
    # (Figure 6) through the on-disk plan cache instead of the sqrt-n heuristic
    plan_cache_dir: Optional[str] = None  # None -> dispatch's default cache dir
    decode_kernel: str = "auto"  # flash-decode variant: auto (paged -> the
    # split-K native kernel where Pallas runs, else the gather/band
    # reference) | native | gather
    # --- other knobs ---
    remat: bool = True
    unroll_layers: bool = False  # python-loop the layer stack (dry-run cost
    # extrapolation: XLA cost_analysis counts a while-loop body once)
    param_dtype: object = None  # set by launcher (jnp dtype); None -> float32
    # --- beyond-paper optimizations (EXPERIMENTS.md §Perf) ---
    grads_rs: bool = False  # constrain grads to the param sharding so XLA
    # emits reduce-scatters instead of all-reduce-to-replicated
    mla_latent_wire: bool = False  # MLA: circulate the 288-wide latent on the
    # KV ring instead of 2*H*dk decompressed heads (forward-only paths)

    @property
    def sp_size(self) -> int:
        if self.mesh is None or self.sp_axis is None:
            return 1
        return self.mesh.shape[self.sp_axis]

    @property
    def batch_spec(self):
        return tuple(self.batch_axes) if self.batch_axes else None

    def eff_batch_axes(self, b: int):
        """Largest-product subset of batch_axes whose sizes' product divides
        b (e.g. long_500k's global_batch=1 leaves the data axis idle)."""
        if self.mesh is None or not self.batch_axes:
            return ()
        axes = list(self.batch_axes)
        best: tuple = ()
        best_prod = 1
        for mask in range(1, 1 << len(axes)):
            sub = tuple(a for i, a in enumerate(axes) if mask >> i & 1)
            prod = 1
            for a in sub:
                prod *= self.mesh.shape[a]
            if b % prod == 0 and prod > best_prod:
                best, best_prod = sub, prod
        return best

    def eff_batch_spec(self, b: int):
        sub = self.eff_batch_axes(b)
        return sub if sub else None

    def act_spec(self, *dims, batch: Optional[int] = None):
        """PartitionSpec for activations: first dim batch, rest as given
        ('seq' -> sp_axis, None otherwise)."""
        parts = [self.batch_spec if batch is None else self.eff_batch_spec(batch)]
        for d in dims:
            parts.append(self.sp_axis if d == "seq" else None)
        return P(*parts)

    def constrain(self, x, *dims):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, self.act_spec(*dims, batch=x.shape[0]))
        )

    def tile_a(self) -> int:
        from repro.core.tiling import best_square_a

        if self.mesh_a is not None:
            return self.mesh_a
        return best_square_a(self.sp_size)

    def shard_map_mesh(self):
        """Mesh to hand to nested shard_map calls: when tracing already
        happens under a mesh context (e.g. inside a partial-manual
        shard_map over the pod axis), the AMBIENT abstract mesh must be
        used — its axis_types carry which axes are already manual."""
        am = compat.get_abstract_mesh()
        if am is not None and am.shape_tuple:
            return am
        return self.mesh
