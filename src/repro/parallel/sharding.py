"""Parameter / optimizer / activation sharding rules.

Strategies:
  * train ("cp_fsdp"): context parallelism for attention (seq over `model`)
    + ZeRO-style parameter sharding.  Each weight's largest shardable dim is
    sharded over ("data","model") combined when divisible, else over "data"
    with the next dim over "model" — XLA all-gathers per layer inside the
    scan.  Params stay replicated across pods (cross-pod traffic is gradient
    all-reduce only, optionally compressed).
  * serve ("tp"): Megatron row/column parallelism over `model` so decode
    never gathers weights: QKV/up projections column-parallel, O/down
    row-parallel (psum per block), vocab sharded for embed/lm_head.

Specs are produced per-leaf with tree_map_with_path; divisibility is always
checked against the actual mesh, so any assigned architecture (e.g. expert
d_ff 1408) gets a legal, if less aggressive, sharding.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.context import ParallelCtx

__all__ = ["param_specs", "param_shardings", "opt_specs", "batch_specs"]

# name-based roles for the serve (TP) strategy
_COL_PARALLEL = {
    "wq", "wk", "wv", "w1", "w3", "ws1", "ws3", "wq_b", "wkv_b", "in_proj",
    "bq", "bk", "bv", "lm_head",
}
_ROW_PARALLEL = {"wo", "w2", "ws2", "out_proj"}
_EXPERT_COL = {"we1", "we3"}
_EXPERT_ROW = {"we2"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _axsize(ctx: ParallelCtx, name: str) -> int:
    if ctx.mesh is None or name not in ctx.mesh.shape:
        return 1
    return ctx.mesh.shape[name]


def _train_spec(name: str, shape, ctx: ParallelCtx, *, for_opt: bool) -> P:
    dp = _axsize(ctx, "data")
    mp = _axsize(ctx, "model")
    nd = len(shape)
    if name in ("we1", "we3", "we2") and nd == 4:
        # expert weights [L, E, d_in, d_out]: EP when the (padded) expert
        # count divides the model axis (the dispatch all-to-all reshards
        # tokens), else TP on the expert FFN dim (mixtral: 8 experts < 16)
        E = shape[1]
        spec = [None, None, None, None]
        if mp > 1 and E % mp == 0:
            spec[1] = "model"
            big = 2 if shape[2] >= shape[3] else 3
            if dp > 1 and shape[big] % dp == 0:
                spec[big] = "data"
        else:
            ff = 3 if name in ("we1", "we3") else 2
            other = 2 if ff == 3 else 3
            if mp > 1 and shape[ff] % mp == 0:
                spec[ff] = "model"
            if dp > 1 and shape[other] % dp == 0:
                spec[other] = "data"
        return P(*spec)
    if name == "embed":
        if shape[0] % (dp * mp) == 0 and dp * mp > 1:
            return P(("data", "model"), *([None] * (nd - 1)))
        if shape[0] % mp == 0 and mp > 1:
            return P("model", *([None] * (nd - 1)))
        return P(*([None] * nd))
    # stacked layer tensors: never shard the leading L dim
    start = 1 if nd >= 2 else 0
    dims = list(range(start, nd))
    if not dims:
        return P(*([None] * nd))
    order = sorted(dims, key=lambda d: -shape[d])
    spec = [None] * nd
    big = order[0]
    if dp * mp > 1 and shape[big] % (dp * mp) == 0:
        spec[big] = ("data", "model")
        return P(*spec)
    if dp > 1 and shape[big] % dp == 0:
        spec[big] = "data"
        for d in order[1:]:
            if mp > 1 and shape[d] % mp == 0:
                spec[d] = "model"
                break
        return P(*spec)
    if mp > 1 and shape[big] % mp == 0:
        spec[big] = "model"
        return P(*spec)
    return P(*spec)


def _serve_spec(name: str, shape, ctx: ParallelCtx) -> P:
    mp = _axsize(ctx, "model")
    nd = len(shape)
    if mp <= 1:
        return P(*([None] * nd))

    def ok(d):
        return shape[d] % mp == 0

    spec = [None] * nd
    if name == "embed":
        if ok(0):
            spec[0] = "model"
        return P(*spec)
    if name in _COL_PARALLEL and ok(nd - 1):
        spec[nd - 1] = "model"
        return P(*spec)
    if name in _ROW_PARALLEL and nd >= 2 and ok(nd - 2):
        spec[nd - 2] = "model"
        return P(*spec)
    if name in _EXPERT_COL and ok(nd - 1):
        spec[nd - 1] = "model"
        return P(*spec)
    if name in _EXPERT_ROW and nd >= 2 and ok(nd - 2):
        spec[nd - 2] = "model"
        return P(*spec)
    return P(*spec)


def param_specs(params, ctx: ParallelCtx, strategy: str = "train"):
    """Pytree of PartitionSpec matching ``params``."""

    def f(path, leaf):
        name = _leaf_name(path)
        if strategy == "serve":
            return _serve_spec(name, leaf.shape, ctx)
        return _train_spec(name, leaf.shape, ctx, for_opt=False)

    return jax.tree_util.tree_map_with_path(f, params)


def param_shardings(params, ctx: ParallelCtx, strategy: str = "train"):
    if ctx.mesh is None:
        return jax.tree.map(lambda _: None, params)
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s), param_specs(params, ctx, strategy)
    )


def opt_specs(params, ctx: ParallelCtx):
    """Adam moments use the same (maximally 2-D) sharding as the params."""
    return param_specs(params, ctx, "train")


def batch_specs(cfg, ctx: ParallelCtx, *, kind: str = "train", batch: Optional[int] = None):
    """Sharding specs for one batch dict (tokens/labels/positions/...)."""
    bs = ctx.batch_spec if batch is None else ctx.eff_batch_spec(batch)
    seq = ctx.sp_axis if kind in ("train", "prefill") else None
    specs = {
        "tokens": P(bs, seq),
        "labels": P(bs, seq),
        "positions": P(seq),
        "segments": P(seq),  # packed-document ids ride with the tokens
        "mask": P(bs, seq),
    }
    if cfg.frontend == "audio_stub":
        # encoder frame count need not divide the model axis; keep seq local
        specs["frames"] = P(bs, None, None)
    if cfg.frontend == "vision_stub":
        specs["patches"] = P(bs, None, None)
    return specs
