"""Training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        [--reduced] [--steps 100] [--seq 128] [--batch 8] \
        [--ckpt-dir DIR] [--compress] [--multi-pod]

On real hardware the mesh comes from `make_production_mesh()`; on this
container pass --fake-devices N to emulate (sets XLA_FLAGS; must be first).
"""

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="family-preserving small config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress", action="store_true", help="int8+EF cross-pod grad compression")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--tile-a", type=int, default=None)
    ap.add_argument("--attn", default="mesh", choices=["mesh", "ring", "ulysses"])
    ap.add_argument("--docs", type=int, default=None,
                    help="pack N documents per row (segment-masked attention)")
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_context, make_production_mesh
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.compression import CompressionConfig
    from repro.parallel.context import ParallelCtx
    from repro.train.loop import TrainConfig, fit

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    n = jax.device_count()
    if n >= 512 and args.multi_pod:
        ctx = make_context(multi_pod=True, mesh_a=args.tile_a, attn_impl=args.attn)
    elif n >= 256:
        ctx = make_context(multi_pod=False, mesh_a=args.tile_a, attn_impl=args.attn)
    elif n >= 8:
        shape, axes = ((2, 2, 2), ("pod", "data", "model")) if args.multi_pod else ((2, 4), ("data", "model"))
        mesh = jax.make_mesh(shape, axes)
        ctx = ParallelCtx(
            mesh=mesh,
            batch_axes=("pod", "data") if args.multi_pod else ("data",),
            sp_axis="model", mesh_a=args.tile_a, attn_impl=args.attn,
            block_q=16, block_kv=16,
        )
    else:
        ctx = ParallelCtx()
    print(f"devices={n} mesh={'none' if ctx.mesh is None else dict(ctx.mesh.shape)}")

    tcfg = TrainConfig(
        steps=args.steps, seq=args.seq, batch=args.batch,
        ckpt_dir=args.ckpt_dir,
        compression=CompressionConfig(kind="int8") if args.compress else None,
        docs=args.docs,
    )
    out = fit(cfg, ctx, tcfg, AdamWConfig(total_steps=args.steps),
              hooks={"on_step": lambda s, m: (s % 10 == 0) and print(
                  f"step {s}: loss {float(m['loss']):.4f}")})
    print(f"done: step={out['step']} final_loss={out.get('final_loss')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
