"""Serving entry point: batched greedy generation.

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b --reduced \
        [--fake-devices 8] [--batch 4] [--prompt-len 16] [--new-tokens 8]
"""

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--fake-devices", type=int, default=0)
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = jax.device_count()
    if n >= 8:
        mesh = jax.make_mesh((n // 4, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                          block_q=16, block_kv=16)
    else:
        ctx = ParallelCtx()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), ctx=ctx)
    eng = ServeEngine(cfg, params, ctx=ctx, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    for i, row in enumerate(out):
        print(f"request {i}: {row.tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
