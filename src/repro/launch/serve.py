"""Serving entry point: static-batch generation or streaming continuous
batching over the slot-pool engine.

    # static batch (legacy)
    PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b --reduced \
        [--fake-devices 8] [--batch 4] [--prompt-len 16] [--new-tokens 8]

    # streaming: replay a mixed-length arrival trace through the scheduler
    PYTHONPATH=src python -m repro.launch.serve --reduced --stream \
        [--fake-devices 8] [--trace 16:0,32:1,64:2,16:4] [--slots 4]

``--trace`` is a comma list of ``prompt_len[:arrival_tick]`` items; slots at
different depths decode in a single jitted step per tick.  Add
``--prefill-chunk 64 [--tick-token-budget 128]`` to ingest prompts through
the continuous-prefill path, interleaved with decode.

Robustness knobs: ``--oversubscribe 1.5`` admits against 1.5x the physical
page pool (preempt-and-recompute under pressure), ``--deadline-ticks`` /
``--cancel idx:tick`` exercise the lifecycle paths, ``--chaos-seed N``
replays a seeded fault trace (squeezes + NaN ticks + dropped grants), and
``--check-deterministic`` reruns everything and exits 1 unless statuses,
streams, and chaos events reproduce exactly — the CI chaos-smoke gate.
"""

import argparse
import json
import os
import sys


def _parse_trace(spec: str):
    items = []
    for part in spec.split(","):
        if ":" in part:
            ln, tick = part.split(":")
        else:
            ln, tick = part, 0
        items.append((int(ln), int(tick)))
    return items


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching: replay --trace through the scheduler")
    ap.add_argument("--trace", default="16:0,32:1,64:2,16:4",
                    help="comma list of prompt_len[:arrival_tick]")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (block tables + prefix sharing)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="local positions per page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical pool size in pages (paged mode)")
    ap.add_argument("--decode-kernel", default="auto",
                    choices=("auto", "native", "gather"),
                    help="flash-decode variant: auto (paged -> split-K "
                         "native kernel), native, or the gather oracle")
    ap.add_argument("--kv-dtype", default="fp", choices=("fp", "int8", "fp8"),
                    help="paged-pool storage: fp keeps cache_dtype; int8/fp8 "
                         "store quantized pages + per-(token, kv-head) f32 "
                         "scales, dequantized in-kernel (requires --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous prefill: ingest prompts in chunks of "
                         "this many tokens, interleaved with decode")
    ap.add_argument("--tick-token-budget", type=int, default=None,
                    help="cap decode+prefill-chunk tokens per tick "
                         "(requires --prefill-chunk)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode: verify up to this many tokens "
                         "per slot per tick (0 disables; needs >= 2)")
    ap.add_argument("--spec-draft", default="ngram", choices=("ngram", "off"),
                    help="draft proposer for speculative decode")
    ap.add_argument("--spec-max-misses", type=int, default=4,
                    help="suspend a slot's drafting after this many "
                         "consecutive zero-accept verify ticks (0 = never)")
    ap.add_argument("--check-spec-identical", action="store_true",
                    help="replay the --stream trace again with spec_k=0 and "
                         "exit nonzero unless every token stream matches")
    ap.add_argument("--oversubscribe", type=float, default=1.0,
                    help="admit against this multiple of the physical page "
                         "pool; > 1.0 enables preempt-and-recompute under "
                         "pressure (requires --paged and --prefill-chunk)")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="retire every request (status 'deadline', partial "
                         "tokens kept) this many ticks after its arrival")
    ap.add_argument("--health-every", type=int, default=0,
                    help="run the engine.health() invariant sweep every N "
                         "ticks (0 = only on demand)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a seeded deterministic fault trace (pool "
                         "squeezes + NaN ticks + dropped grants) from "
                         "testing/chaos.py")
    ap.add_argument("--chaos-ticks", type=int, default=24,
                    help="horizon the chaos event schedule is drawn over")
    ap.add_argument("--cancel", default=None,
                    help="comma list of request_index:tick cancellations "
                         "applied during the --stream replay")
    ap.add_argument("--check-deterministic", action="store_true",
                    help="replay the whole --stream run (same seed, fresh "
                         "engine + fresh chaos injector) and exit nonzero "
                         "unless statuses, token streams, and chaos events "
                         "all match exactly")
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = jax.device_count()
    if n >= 8:
        mesh = jax.make_mesh((n // 4, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                          block_q=16, block_kv=16)
    else:
        ctx = ParallelCtx()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), ctx=ctx)
    def make_serve(spec_k):
        return ServeConfig(
            max_seq=args.max_seq, num_slots=args.slots, paged=args.paged,
            page_size=args.page_size, num_pages=args.num_pages,
            decode_kernel=args.decode_kernel, kv_dtype=args.kv_dtype,
            prefill_chunk=args.prefill_chunk,
            tick_token_budget=args.tick_token_budget,
            spec_k=spec_k, spec_draft=args.spec_draft,
            spec_max_misses=args.spec_max_misses or None,
            oversubscribe=args.oversubscribe,
            health_every=args.health_every,
        )

    def make_chaos():
        if args.chaos_seed is None:
            return None
        from repro.testing.chaos import ChaosConfig, ChaosInjector
        return ChaosInjector(ChaosConfig(seed=args.chaos_seed,
                                         ticks=args.chaos_ticks))

    chaos = make_chaos()
    eng = ServeEngine(cfg, params, ctx=ctx, serve=make_serve(args.spec_k),
                      chaos=chaos)
    rng = np.random.default_rng(0)

    if args.stream:
        trace = _parse_trace(args.trace)
        prompts = [
            rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32)
            for ln, _ in trace
        ]
        cancels = {}
        if args.cancel:
            for part in args.cancel.split(","):
                idx, t = part.split(":")
                cancels.setdefault(int(t), []).append(int(idx))

        def replay(engine, quiet=False):
            rids = [
                engine.submit(p, max_new_tokens=args.new_tokens,
                              arrival_tick=tick,
                              deadline_ticks=args.deadline_ticks)
                for p, (_, tick) in zip(prompts, trace)
            ]
            ticks = 0
            while engine.has_work:
                for idx in cancels.get(engine._tick, []):
                    engine.cancel(rids[idx])
                for req in engine.step():
                    if not quiet:
                        print(
                            f"rid={req.rid} len={len(req.prompt)} slot={req.slot} "
                            f"arrived@{req.arrival_tick} admitted@{req.admit_tick} "
                            f"finished@{req.finish_tick} status={req.status}: "
                            f"{req.generated}"
                        )
                ticks += 1
            return rids, ticks

        rids, ticks = replay(eng)
        summary = {
            "requests": len(trace),
            "ticks": ticks,
            "prefill_traces": {str(k): v for k, v in eng.prefill_trace_counts.items()},
            "decode_traces": eng.decode_trace_count,
        }
        if args.prefill_chunk:
            stats = eng.tick_stats()
            summary["chunk_traces"] = eng.chunk_trace_count
            summary["chunk_launches"] = eng.chunk_launches
            summary["prefill_tokens"] = int(sum(stats["prefill_tokens"]))
            summary["decode_tokens"] = int(sum(stats["decode_tokens"]))
        if eng._spec_on:
            kv = eng.kv_cache_stats()
            summary["speculative"] = {
                "spec_k": args.spec_k,
                "verify_launches": eng.verify_launches,
                "spec_proposed": eng.spec_proposed,
                "spec_accepted": eng.spec_accepted,
                "spec_accept_rate": kv["spec_accept_rate"],
                "spec_rolled_back_pages": kv.get("spec_rolled_back_pages", 0.0),
            }
        if args.paged:
            summary["kv_cache"] = eng.kv_cache_stats()
        if args.kv_dtype != "fp":
            kv = eng.kv_cache_stats()
            summary["quantized_kv"] = {
                "kv_dtype": args.kv_dtype,
                "quantized_pages": kv["quantized_pages"],
                "scale_entries_in_use": kv["scale_entries_in_use"],
                "scale_table_bytes": kv["scale_table_bytes"],
                "dequant_fallbacks": kv["dequant_fallbacks"],
            }
        if (args.oversubscribe > 1.0 or args.chaos_seed is not None
                or args.deadline_ticks is not None or args.cancel):
            statuses = {}
            for rid in rids:
                s = eng._finished[rid].status
                statuses[s] = statuses.get(s, 0) + 1
            kv = eng.kv_cache_stats()
            summary["robustness"] = {
                "oversubscribe": args.oversubscribe,
                "statuses": statuses,
                "preemptions": kv["preemptions"],
                "recompute_tokens": kv["recompute_tokens"],
                "cancelled": kv["cancelled"],
                "deadline_expired": kv["deadline_expired"],
                "numeric_errors": kv["numeric_errors"],
                "rejected_requests": kv["rejected_requests"],
                "health_sweeps": kv["health_sweeps"],
                "chaos_dropped_grants": kv["chaos_dropped_grants"],
                "chaos_events": chaos.events if chaos is not None else [],
            }
        print(json.dumps(summary))
        if args.check_deterministic:
            # gate: a fresh engine + fresh injector replaying the identical
            # (seed, trace, faults) triple must reproduce every outcome
            chaos2 = make_chaos()
            ref = ServeEngine(cfg, params, ctx=ctx,
                              serve=make_serve(args.spec_k), chaos=chaos2)
            ref_rids, _ = replay(ref, quiet=True)
            for rid, ref_rid in zip(rids, ref_rids):
                a, b = eng._finished[rid], ref._finished[ref_rid]
                if a.status != b.status or a.generated != b.generated:
                    print(
                        f"check-deterministic: rid={rid} run1 "
                        f"({a.status}, {a.generated}) != run2 "
                        f"({b.status}, {b.generated})", file=sys.stderr,
                    )
                    return 1
            if chaos is not None and chaos.events != chaos2.events:
                print(
                    f"check-deterministic: chaos traces diverged:\n"
                    f"  run1 {chaos.events}\n  run2 {chaos2.events}",
                    file=sys.stderr,
                )
                return 1
            print(
                f"check-deterministic: {len(rids)} outcomes and "
                f"{len(chaos.events) if chaos is not None else 0} chaos "
                f"events reproduced exactly"
            )
        if args.check_spec_identical:
            # gate: the speculative run above must be token-identical to a
            # vanilla greedy replay of the exact same trace
            if not eng._spec_on:
                print("check-spec-identical needs --spec-k >= 2", file=sys.stderr)
                return 1
            ref = ServeEngine(cfg, params, ctx=ctx, serve=make_serve(0))
            ref_rids, _ = replay(ref, quiet=True)
            for rid, ref_rid in zip(rids, ref_rids):
                got = eng._finished[rid].generated
                want = ref._finished[ref_rid].generated
                if got != want:
                    print(
                        f"check-spec-identical: rid={rid} speculative stream "
                        f"{got} != vanilla {want}", file=sys.stderr,
                    )
                    return 1
            print(f"check-spec-identical: {len(rids)} streams match vanilla greedy")
        return 0

    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    for i, row in enumerate(out):
        print(f"request {i}: {row.tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
