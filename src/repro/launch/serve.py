"""Serving entry point: static-batch generation or streaming continuous
batching over the slot-pool engine.

    # static batch (legacy)
    PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b --reduced \
        [--fake-devices 8] [--batch 4] [--prompt-len 16] [--new-tokens 8]

    # streaming: replay a mixed-length arrival trace through the scheduler
    PYTHONPATH=src python -m repro.launch.serve --reduced --stream \
        [--fake-devices 8] [--trace 16:0,32:1,64:2,16:4] [--slots 4]

``--trace`` is a comma list of ``prompt_len[:arrival_tick]`` items; slots at
different depths decode in a single jitted step per tick.  Add
``--prefill-chunk 64 [--tick-token-budget 128]`` to ingest prompts through
the continuous-prefill path, interleaved with decode.
"""

import argparse
import json
import os
import sys


def _parse_trace(spec: str):
    items = []
    for part in spec.split(","):
        if ":" in part:
            ln, tick = part.split(":")
        else:
            ln, tick = part, 0
        items.append((int(ln), int(tick)))
    return items


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching: replay --trace through the scheduler")
    ap.add_argument("--trace", default="16:0,32:1,64:2,16:4",
                    help="comma list of prompt_len[:arrival_tick]")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (block tables + prefix sharing)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="local positions per page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical pool size in pages (paged mode)")
    ap.add_argument("--decode-kernel", default="auto",
                    choices=("auto", "native", "gather"),
                    help="flash-decode variant: auto (paged -> split-K "
                         "native kernel), native, or the gather oracle")
    ap.add_argument("--kv-dtype", default="fp", choices=("fp", "int8", "fp8"),
                    help="paged-pool storage: fp keeps cache_dtype; int8/fp8 "
                         "store quantized pages + per-(token, kv-head) f32 "
                         "scales, dequantized in-kernel (requires --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous prefill: ingest prompts in chunks of "
                         "this many tokens, interleaved with decode")
    ap.add_argument("--tick-token-budget", type=int, default=None,
                    help="cap decode+prefill-chunk tokens per tick "
                         "(requires --prefill-chunk)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode: verify up to this many tokens "
                         "per slot per tick (0 disables; needs >= 2)")
    ap.add_argument("--spec-draft", default="ngram", choices=("ngram", "off"),
                    help="draft proposer for speculative decode")
    ap.add_argument("--spec-max-misses", type=int, default=4,
                    help="suspend a slot's drafting after this many "
                         "consecutive zero-accept verify ticks (0 = never)")
    ap.add_argument("--check-spec-identical", action="store_true",
                    help="replay the --stream trace again with spec_k=0 and "
                         "exit nonzero unless every token stream matches")
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = jax.device_count()
    if n >= 8:
        mesh = jax.make_mesh((n // 4, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                          block_q=16, block_kv=16)
    else:
        ctx = ParallelCtx()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), ctx=ctx)
    def make_serve(spec_k):
        return ServeConfig(
            max_seq=args.max_seq, num_slots=args.slots, paged=args.paged,
            page_size=args.page_size, num_pages=args.num_pages,
            decode_kernel=args.decode_kernel, kv_dtype=args.kv_dtype,
            prefill_chunk=args.prefill_chunk,
            tick_token_budget=args.tick_token_budget,
            spec_k=spec_k, spec_draft=args.spec_draft,
            spec_max_misses=args.spec_max_misses or None,
        )

    eng = ServeEngine(cfg, params, ctx=ctx, serve=make_serve(args.spec_k))
    rng = np.random.default_rng(0)

    if args.stream:
        trace = _parse_trace(args.trace)
        prompts = [
            rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32)
            for ln, _ in trace
        ]

        def replay(engine, quiet=False):
            rids = [
                engine.submit(p, max_new_tokens=args.new_tokens, arrival_tick=tick)
                for p, (_, tick) in zip(prompts, trace)
            ]
            ticks = 0
            while engine.has_work:
                for req in engine.step():
                    if not quiet:
                        print(
                            f"rid={req.rid} len={len(req.prompt)} slot={req.slot} "
                            f"arrived@{req.arrival_tick} admitted@{req.admit_tick} "
                            f"finished@{req.finish_tick}: {req.generated}"
                        )
                ticks += 1
            return rids, ticks

        rids, ticks = replay(eng)
        summary = {
            "requests": len(trace),
            "ticks": ticks,
            "prefill_traces": {str(k): v for k, v in eng.prefill_trace_counts.items()},
            "decode_traces": eng.decode_trace_count,
        }
        if args.prefill_chunk:
            stats = eng.tick_stats()
            summary["chunk_traces"] = eng.chunk_trace_count
            summary["chunk_launches"] = eng.chunk_launches
            summary["prefill_tokens"] = int(sum(stats["prefill_tokens"]))
            summary["decode_tokens"] = int(sum(stats["decode_tokens"]))
        if eng._spec_on:
            kv = eng.kv_cache_stats()
            summary["speculative"] = {
                "spec_k": args.spec_k,
                "verify_launches": eng.verify_launches,
                "spec_proposed": eng.spec_proposed,
                "spec_accepted": eng.spec_accepted,
                "spec_accept_rate": kv["spec_accept_rate"],
                "spec_rolled_back_pages": kv.get("spec_rolled_back_pages", 0.0),
            }
        if args.paged:
            summary["kv_cache"] = eng.kv_cache_stats()
        if args.kv_dtype != "fp":
            kv = eng.kv_cache_stats()
            summary["quantized_kv"] = {
                "kv_dtype": args.kv_dtype,
                "quantized_pages": kv["quantized_pages"],
                "scale_entries_in_use": kv["scale_entries_in_use"],
                "scale_table_bytes": kv["scale_table_bytes"],
                "dequant_fallbacks": kv["dequant_fallbacks"],
            }
        print(json.dumps(summary))
        if args.check_spec_identical:
            # gate: the speculative run above must be token-identical to a
            # vanilla greedy replay of the exact same trace
            if not eng._spec_on:
                print("check-spec-identical needs --spec-k >= 2", file=sys.stderr)
                return 1
            ref = ServeEngine(cfg, params, ctx=ctx, serve=make_serve(0))
            ref_rids, _ = replay(ref, quiet=True)
            for rid, ref_rid in zip(rids, ref_rids):
                got = eng._finished[rid].generated
                want = ref._finished[ref_rid].generated
                if got != want:
                    print(
                        f"check-spec-identical: rid={rid} speculative stream "
                        f"{got} != vanilla {want}", file=sys.stderr,
                    )
                    return 1
            print(f"check-spec-identical: {len(rids)} streams match vanilla greedy")
        return 0

    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    for i, row in enumerate(out):
        print(f"request {i}: {row.tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
