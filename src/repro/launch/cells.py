"""Dry-run cell construction: (architecture x input shape x mesh) -> a
lowerable jitted step with fully-specified shardings and ShapeDtypeStruct
inputs (no allocation — the 'shannon/kernels' pattern).

Cell kinds (see configs.base.SHAPES):
  train_4k    -> train_step  (loss + grads + AdamW update, remat'd scan)
  prefill_32k -> prefill     (forward + striped-cache writes, no grads)
  decode_32k  -> decode_step (one token against a seq_len cache)
  long_500k   -> decode_step; only sub-quadratic archs (SSM/hybrid/SWA) —
                 full-attention archs are recorded as SKIP per the assignment.

MODEL_FLOPS for the roofline: 6·N_params·D_tokens for training (3x forward
for fwd+bwd), 2·N·D for inference steps; MoE uses active params only.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state
from repro.parallel import sharding as shd
from repro.parallel.context import ParallelCtx

__all__ = ["cell_applicable", "build_cell", "active_params", "model_flops"]


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (assignment rule: SKIP)"
        )
    return True, ""


def active_params(cfg: ModelConfig) -> float:
    """Parameter count active per token (MoE counts top_k + shared experts)."""
    abs_params = jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
    )
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abs_params))
    if cfg.moe is None:
        return float(total)
    # subtract inactive routed experts
    m = cfg.moe
    flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
    routed = sum(
        int(np.prod(x.shape))
        for path, x in flat
        if any(getattr(e, "key", "") in ("we1", "we2", "we3") for e in path)
    )
    active_routed = routed * (m.top_k / max(1, m.num_experts))
    return float(total - routed + active_routed)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def _named(ctx, spec):
    return NamedSharding(ctx.mesh, spec) if ctx.mesh is not None else None


def _batch_structs(cfg: ModelConfig, ctx: ParallelCtx, seq: int, batch: int, kind: str):
    from repro.data.pipeline import batch_spec_shapes

    shapes = batch_spec_shapes(cfg, seq, batch)
    specs = shd.batch_specs(cfg, ctx, kind=kind, batch=batch)
    structs = {}
    shardings = {}
    for k, (shp, dt) in shapes.items():
        structs[k] = jax.ShapeDtypeStruct(shp, dt)
        shardings[k] = _named(ctx, specs[k])
    return structs, shardings


def _abstract_params(cfg: ModelConfig, ctx: ParallelCtx, strategy: str):
    abs_p = jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16, ctx)
    )
    shardings = shd.param_shardings(abs_p, ctx, strategy)
    return abs_p, shardings


def _cache_structs(cfg: ModelConfig, ctx: ParallelCtx, batch: int, cap: int):
    abs_c = jax.eval_shape(lambda: tfm.init_cache(cfg, batch, cap, dtype=jnp.bfloat16))
    bs = ctx.eff_batch_spec(batch)

    def spec_for(path, leaf):
        name = None
        for e in reversed(path):
            if hasattr(e, "key"):
                name = str(e.key)
                break
        nd = len(leaf.shape)
        if name in ("k", "v"):
            return P(None, bs, ctx.sp_axis, None, None)
        if name in ("cross_k", "cross_v"):
            return P(None, bs, ctx.sp_axis, None, None)
        if name in ("conv", "state"):
            return P(None, bs, *([None] * (nd - 2)))
        return P()  # pos: per-slot [B] vector, replicated

    specs = jax.tree_util.tree_map_with_path(spec_for, abs_c)
    shardings = jax.tree.map(lambda s: _named(ctx, s), specs)
    return abs_c, shardings


def build_cell(arch: str, shape_name: str, ctx: ParallelCtx, cfg: Optional[ModelConfig] = None):
    """-> (jitted_fn, example_args (ShapeDtypeStructs)) ready to .lower()."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"SKIP: {why}")

    if shape.kind == "train":
        abs_p, p_shard = _abstract_params(cfg, ctx, "train")
        abs_o = jax.eval_shape(init_opt_state, abs_p)
        o_shard = OptState(
            _named(ctx, P()),
            shd.param_shardings(abs_p, ctx, "train"),
            shd.param_shardings(abs_p, ctx, "train"),
        )
        b_structs, b_shard = _batch_structs(cfg, ctx, shape.seq_len, shape.global_batch, "train")
        opt_cfg = AdamWConfig(total_steps=10000)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: tfm.loss_fn(p, cfg, ctx, batch), has_aux=True
            )(params)
            if ctx.grads_rs and ctx.mesh is not None:
                # force the gradient reduction into the params' sharded layout
                # (reduce-scatter) instead of all-reduce-to-replicated
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, p_shard
                )
            params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
            return params, opt_state, loss

        fn = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        return fn, (abs_p, abs_o, b_structs)

    if shape.kind == "prefill":
        # NOTE (§Perf hypothesis B3, REFUTED): serve/TP weight sharding for
        # prefill does NOT remove the per-layer weight gathers because the
        # model axis is double-booked (sequence CP + TP weights) — GSPMD must
        # gather one side anyway.  Proper fix = Megatron SP<->TP transitions
        # per block; prefill keeps the train (FSDP) sharding.
        abs_p, p_shard = _abstract_params(cfg, ctx, "train")
        abs_c, c_shard = _cache_structs(cfg, ctx, shape.global_batch, shape.seq_len)
        b_structs, b_shard = _batch_structs(
            cfg, ctx, shape.seq_len, shape.global_batch, "prefill"
        )

        def prefill_step(params, batch, cache):
            return tfm.prefill(params, cfg, ctx, batch, cache)

        fn = jax.jit(
            prefill_step,
            in_shardings=(p_shard, b_shard, c_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(2,),
        )
        return fn, (abs_p, b_structs, abs_c)

    # decode
    abs_p, p_shard = _abstract_params(cfg, ctx, "serve")
    abs_c, c_shard = _cache_structs(cfg, ctx, shape.global_batch, shape.seq_len)
    tok_struct = jax.ShapeDtypeStruct((shape.global_batch, 1), np.int32)
    tok_shard = _named(ctx, P(ctx.eff_batch_spec(shape.global_batch), None))

    def serve_step(params, cache, tokens):
        return tfm.decode_step(params, cache, tokens, cfg, ctx)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_shard, c_shard, tok_shard),
        out_shardings=(tok_shard, c_shard, None),
        donate_argnums=(1,),
    )
    return fn, (abs_p, abs_c, tok_struct)
