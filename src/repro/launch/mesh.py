"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods x 256 =
512 chips as (pod=2, data=16, model=16) — the pod axis is pure data
parallelism across DCN.  A FUNCTION (not a module constant) so importing
never touches jax device state; the dry-run forces 512 host devices before
any jax import (see launch/dryrun.py).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_context"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_context(mesh=None, *, multi_pod: bool = False, **kw):
    """ParallelCtx wired to the production axis roles."""
    from repro.parallel.context import ParallelCtx

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    return ParallelCtx(mesh=mesh, batch_axes=batch_axes, sp_axis="model", **kw)
