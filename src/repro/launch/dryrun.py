import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks the device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch ID ...] [--shape NAME ...] [--mesh single|multi|both]
        [--out benchmarks/results/dryrun] [--force]

Success criterion (deliverable e): ``.lower().compile()`` succeeds for every
cell on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh.  Results
are written incrementally as JSON (one file per cell) so a long sweep can be
resumed; benchmarks and EXPERIMENTS.md read these files.
"""

import argparse
import json
import time
import traceback

import jax


def _metrics(compiled):
    from repro.launch import hlo_analysis as ha

    ca = compiled.cost_analysis() or {}
    coll = ha.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def _layer_cost_extrapolation(arch, shape_name, ctx, cfg):
    """XLA cost analysis counts a while-loop (scan) body ONCE, so the
    full-depth compile undercounts per-layer work by ~L.  Lower UNROLLED
    1-layer and 2-layer variants of the same cell at full width; the delta is
    one true layer's cost and base = cost(1) - delta covers embed/loss:
        corrected_total = base + L * delta.
    """
    import dataclasses

    from repro.launch.cells import build_cell

    uctx = dataclasses.replace(ctx, unroll_layers=True)
    out = {}
    for L in (1, 2):
        cfg_l = dataclasses.replace(
            cfg,
            num_layers=L,
            encoder_layers=min(cfg.encoder_layers, L) if cfg.encoder_layers else 0,
        )
        fn, args = build_cell(arch, shape_name, uctx, cfg=cfg_l)
        out[L] = _metrics(fn.lower(*args).compile())
    L_full = cfg.num_layers

    def extrap(key):
        if key == "coll":
            d = {
                k: out[2]["coll"][k] - out[1]["coll"][k] for k in out[1]["coll"]
            }
            return {
                k: max(0.0, out[1]["coll"][k] - d[k] + L_full * d[k]) for k in d
            }
        delta = out[2][key] - out[1][key]
        return max(0.0, out[1][key] - delta + L_full * delta)

    return {
        "flops": extrap("flops"),
        "bytes": extrap("bytes"),
        "coll": extrap("coll"),
        "one_layer": out[1],
        "two_layer": out[2],
    }


def _cell_result(arch, shape_name, mesh_kind, *, perf_overrides=None):
    from repro.configs import SHAPES, get_config
    from repro.launch import hlo_analysis as ha
    from repro.launch.cells import build_cell, cell_applicable, model_flops
    from repro.launch.mesh import make_context, make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "skip" if not ok else "pending",
    }
    if not ok:
        rec["reason"] = why
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    ctx = make_context(mesh, **(perf_overrides or {}))
    chips = mesh.size

    # 1) the deliverable: full-depth lower + compile must succeed
    t0 = time.time()
    fn, args = build_cell(arch, shape_name, ctx)
    lowered = fn.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    rec["status"] = "ok"
    raw = _metrics(compiled)
    rec["raw_flops_per_device"] = raw["flops"]
    rec["raw_bytes_per_device"] = raw["bytes"]
    rec["raw_collective_bytes_per_device"] = raw["coll"]

    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for name in (
                "temp_size_in_bytes",
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                val = getattr(ma, name, None)
                if val is not None:
                    rec[name] = int(val)
    except Exception as e:  # pragma: no cover - backend dependent
        rec["memory_analysis_error"] = str(e)

    # 2) per-layer cost extrapolation for the roofline terms
    ext = _layer_cost_extrapolation(arch, shape_name, ctx, cfg)
    rec["flops_per_device"] = ext["flops"]
    rec["bytes_per_device"] = ext["bytes"]
    rec["collective_bytes_per_device"] = ext["coll"]
    rec["roofline"] = ha.roofline_terms(
        ext["flops"],
        ext["bytes"],
        ext["coll"]["total"],
        chips=chips,
        model_flops=model_flops(cfg, shape),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tile-a", type=int, default=None, help="Mesh-Attention tile height override")
    ap.add_argument("--attn", default=None, choices=[None, "mesh", "ring", "ulysses"])
    ap.add_argument("--tag", default="", help="suffix for result files (perf experiments)")
    ap.add_argument("--no-remat", action="store_true", help="disable activation remat")
    ap.add_argument("--grads-rs", action="store_true", help="reduce-scatter gradients")
    ap.add_argument("--mla-wire", action="store_true", help="MLA latent KV wire")
    ap.add_argument("--concurrent-rings", action="store_true", help="Q+KV permutes per step")
    args = ap.parse_args()

    from repro.configs import ALL_ARCHS, SHAPES

    archs = args.arch or ALL_ARCHS
    shapes = args.shape or list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = {}
    if args.tile_a is not None:
        overrides["mesh_a"] = args.tile_a
    if args.attn:
        overrides["attn_impl"] = args.attn
    if args.no_remat:
        overrides["remat"] = False
    if args.grads_rs:
        overrides["grads_rs"] = True
    if args.mla_wire:
        overrides["mla_latent_wire"] = True
    if args.concurrent_rings:
        overrides["allow_concurrent_rings"] = True

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                name = f"{arch}__{shape}__{mesh_kind}{args.tag}.json"
                path = os.path.join(args.out, name)
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] cached {name}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...", flush=True)
                try:
                    rec = _cell_result(arch, shape, mesh_kind, perf_overrides=overrides)
                except Exception as e:  # noqa: BLE001
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "status": "error", "error": str(e),
                        "tb": traceback.format_exc(),
                    }
                    failures += 1
                    print(f"[dryrun]   ERROR: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(
                        f"[dryrun]   ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                        f"dominant={r['dominant']} compute={r['compute_s']:.4f}s "
                        f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s",
                        flush=True,
                    )
                elif rec["status"] == "skip":
                    print(f"[dryrun]   SKIP: {rec['reason']}", flush=True)
    print(f"[dryrun] done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
