"""Post-compilation HLO analysis: collective-byte accounting + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT collective
traffic, so we parse the optimized HLO text and sum the payloads of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
converted to per-device wire bytes with ring-algorithm conventions:

    all-gather          result x (g-1)/g
    all-reduce          2 x result x (g-1)/g
    reduce-scatter      result x (g-1)          (operand = result x g)
    all-to-all          result x (g-1)/g
    collective-permute  result                  (one neighbour hop)

g = collective group size parsed from replica_groups.  Hardware constants
(TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s per ICI link.

Each op kind also gets an ``<op>-count`` entry (number of HLO ops of that
kind).  Under ``comm_overlap="bidir"`` the mesh executors ship every logical
ring hop as a PAIR of half-payload collective-permutes: the pair's bytes sum
to exactly one hop's traffic (so the byte totals here stay mode-invariant and
comparable to theory), but the raw op count doubles — collapse it with
``core.am.logical_ppermute_steps`` before comparing against schedule step
counts, so a pair is one logical step, not two.
"""

from __future__ import annotations

import re
from typing import Dict, Optional

__all__ = ["collective_bytes", "roofline_terms", "HW"]

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = _DTYPE_BYTES[dt]
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by op kind (+ 'total', + '<op>-count' op tallies)."""
    out: Dict[str, float] = {op: 0.0 for op in _OPS}
    counts: Dict[str, int] = {op: 0 for op in _OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        lhs, rhs = stripped.split(" = ", 1)
        op = None
        for cand in _OPS:
            # count the op once: either the sync form or the -start form
            if re.search(rf"\b{cand}(-start)?\(", rhs):
                op = cand
                break
        if op is None:
            continue
        if re.search(rf"\b{op}-done\(", rhs):
            continue
        # result shape(s): before the op name; tuples for -start forms
        head = rhs.split(op)[0]
        shapes = [_shape_bytes(f"{d}[{s}]") for d, s in _SHAPE_RE.findall(head)]
        if not shapes:
            continue
        payload = max(shapes)
        g = _group_size(rhs)
        if op == "all-gather":
            wire = payload * (g - 1) / g
        elif op == "all-reduce":
            wire = 2 * payload * (g - 1) / g
        elif op == "reduce-scatter":
            wire = payload * (g - 1)
        elif op == "all-to-all":
            wire = payload * (g - 1) / g
        else:  # collective-permute: payload crosses one link
            wire = payload
        out[op] += wire
        counts[op] += 1
    out["total"] = sum(out[op] for op in _OPS)
    for op in _OPS:
        out[f"{op}-count"] = counts[op]
    return out


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_per_device: float,
    *,
    chips: int,
    model_flops: Optional[float] = None,
) -> Dict[str, float]:
    """The three §Roofline terms, in seconds, using the assignment's formula
    with HLO_FLOPs = total across chips = per-device x chips (the compiled
    module is the per-partition program)."""
    total_flops = flops_per_device * chips
    total_bytes = bytes_per_device * chips
    total_coll = collective_per_device * chips
    compute_t = total_flops / (chips * HW["peak_flops"])
    memory_t = total_bytes / (chips * HW["hbm_bw"])
    coll_t = total_coll / (chips * HW["link_bw"])
    dominant = max(
        ("compute", compute_t), ("memory", memory_t), ("collective", coll_t),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant,
        "flops_per_device": flops_per_device,
        "bytes_per_device": bytes_per_device,
        "collective_bytes_per_device": collective_per_device,
    }
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / max(total_flops, 1.0)
    return out
