"""Jit'd public wrappers around the Pallas kernels with ref.py fallbacks.

Backend policy (``REPRO_KERNELS`` env var or ``set_backend()``):
  * "auto"   (default): compiled Pallas on TPU, pure-jnp ref elsewhere —
             the CPU container validates kernels with interpret=True in
             tests, but models/benchmarks run the fast XLA reference.
  * "pallas" : Pallas with interpret=True off-TPU (slow; correctness runs).
  * "ref"    : always the jnp oracle.

Two API layers:
  * ``flash_attention``  — differentiable (custom_vjp pairing the fwd kernel
    with the dq/dkv kernels); band must be static Python ints.  This is what
    the single-device model code uses.
  * ``block_attention`` / ``block_attention_bwd`` — non-differentiable
    building blocks taking a *dynamic* int32[4] band (offsets may come from
    ``jax.lax.axis_index`` inside shard_map).  ``core/mesh_attention.py``
    assembles the paper's distributed forward/backward out of these, defining
    its own custom_vjp at the distributed-op level (Algorithms 2/3).
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ref

Band = ref.Band

_BACKEND = os.environ.get("REPRO_KERNELS", "auto")


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("auto", "pallas", "ref"):
        raise ValueError(name)
    _BACKEND = name


def current_backend() -> str:
    return _BACKEND


def pallas_enabled() -> bool:
    """Does the current backend policy run Pallas kernels (compiled on TPU,
    or interpret-mode under REPRO_KERNELS=pallas)?  "auto" off-TPU runs the
    fast XLA reference instead — perf-default code paths key off this."""
    return _use_pallas()[0]


def _use_pallas() -> Tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    on_tpu = jax.default_backend() == "tpu"
    if _BACKEND == "ref":
        return False, False
    if _BACKEND == "pallas":
        return True, not on_tpu
    return on_tpu, False


def full_band() -> Tuple[int, int, int, int]:
    return (0, 0, -ref.BAND_INF, ref.BAND_INF)


def block_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    band,  # int32[4] array or 4-tuple (entries may be traced)
    *,
    scale: Optional[float] = None,
    stride_q: int = 1,
    stride_kv: int = 1,
    block_q: int = fa.DEFAULT_BLOCK_Q,
    block_kv: int = fa.DEFAULT_BLOCK_KV,
    seg_q: Optional[jnp.ndarray] = None,  # [Sq] int32 segment ids (documents)
    seg_kv: Optional[jnp.ndarray] = None,  # [Skv]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One AM-block attention: (o, lse); no autodiff rule (see module doc)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    band = jnp.asarray(band, jnp.int32)
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        return fa.flash_attention_fwd(
            q, k, v, band,
            scale=scale, stride_q=stride_q, stride_kv=stride_kv,
            block_q=block_q, block_kv=block_kv, interpret=interpret,
            seg_q=seg_q, seg_kv=seg_kv,
        )
    return ref.attention_ref(
        q, k, v, scale=scale, band=tuple(band), stride_q=stride_q, stride_kv=stride_kv,
        seg_q=seg_q, seg_kv=seg_kv,
    )


def block_attention_bwd(
    q, k, v, o, lse, do, band,
    *,
    scale: Optional[float] = None,
    stride_q: int = 1,
    stride_kv: int = 1,
    block_q: int = fa.DEFAULT_BLOCK_Q,
    block_kv: int = fa.DEFAULT_BLOCK_KV,
    delta: Optional[jnp.ndarray] = None,
    seg_q: Optional[jnp.ndarray] = None,
    seg_kv: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One AM-block backward from saved (o, lse): (dq, dk, dv).

    Either ``o`` or ``delta`` (= rowsum(do*o), [B,Sq,H]) must be given.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    band = jnp.asarray(band, jnp.int32)
    use_pallas, interpret = _use_pallas()
    if use_pallas:
        return fa.flash_attention_bwd(
            q, k, v, o, lse, do, band,
            scale=scale, stride_q=stride_q, stride_kv=stride_kv,
            block_q=block_q, block_kv=block_kv, interpret=interpret, delta=delta,
            seg_q=seg_q, seg_kv=seg_kv,
        )
    return ref.attention_bwd_ref(
        q, k, v, o, lse, do,
        scale=scale, band=tuple(band), stride_q=stride_q, stride_kv=stride_kv,
        delta=delta, seg_q=seg_q, seg_kv=seg_kv,
    )


# --------------------------------------------------------------------------
# differentiable single-device attention (static band)
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, band, scale, stride_q, stride_kv):
    o, _ = block_attention(
        q, k, v, band, scale=scale, stride_q=stride_q, stride_kv=stride_kv
    )
    return o


def _flash_fwd(q, k, v, band, scale, stride_q, stride_kv):
    o, lse = block_attention(
        q, k, v, band, scale=scale, stride_q=stride_q, stride_kv=stride_kv
    )
    return o, (q, k, v, o, lse)


def _flash_bwd(band, scale, stride_q, stride_kv, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = block_attention_bwd(
        q, k, v, o, lse, do, band,
        scale=scale, stride_q=stride_q, stride_kv=stride_kv,
    )
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


# segment-masked variant: the int32 seg operands are data (packed documents),
# so they ride as traced args with a None cotangent
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash_seg(q, k, v, seg_q, seg_kv, band, scale, stride_q, stride_kv):
    o, _ = block_attention(
        q, k, v, band, scale=scale, stride_q=stride_q, stride_kv=stride_kv,
        seg_q=seg_q, seg_kv=seg_kv,
    )
    return o


def _flash_seg_fwd(q, k, v, seg_q, seg_kv, band, scale, stride_q, stride_kv):
    o, lse = block_attention(
        q, k, v, band, scale=scale, stride_q=stride_q, stride_kv=stride_kv,
        seg_q=seg_q, seg_kv=seg_kv,
    )
    return o, (q, k, v, seg_q, seg_kv, o, lse)


def _flash_seg_bwd(band, scale, stride_q, stride_kv, res, do):
    q, k, v, seg_q, seg_kv, o, lse = res
    dq, dk, dv = block_attention_bwd(
        q, k, v, o, lse, do, band,
        scale=scale, stride_q=stride_q, stride_kv=stride_kv,
        seg_q=seg_q, seg_kv=seg_kv,
    )
    return dq, dk, dv, None, None


_flash_seg.defvjp(_flash_seg_fwd, _flash_seg_bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    band: Optional[Tuple[int, int, int, int]] = None,
    scale: Optional[float] = None,
    stride_q: int = 1,
    stride_kv: int = 1,
    seg_q: Optional[jnp.ndarray] = None,  # [Sq] int32 segment ids
    seg_kv: Optional[jnp.ndarray] = None,  # [Skv]
) -> jnp.ndarray:
    """Differentiable attention; the band is static (causal/window/custom),
    optionally composed with runtime segment ids (packed documents)."""
    if band is None:
        if causal:
            hi = (window - 1) if window else ref.BAND_INF
            band = (0, 0, 0, hi)
        elif window:
            band = (0, 0, -(window - 1), window - 1)
        else:
            band = full_band()
    if scale is None:
        scale = q.shape[-1] ** -0.5
    band = tuple(int(x) for x in band)
    if seg_q is not None:
        if seg_kv is None:
            seg_kv = seg_q
        return _flash_seg(
            q, k, v, jnp.asarray(seg_q, jnp.int32), jnp.asarray(seg_kv, jnp.int32),
            band, float(scale), stride_q, stride_kv,
        )
    return _flash(q, k, v, band, float(scale), stride_q, stride_kv)


combine_partials = ref.combine_partials
