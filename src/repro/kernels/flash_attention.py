"""Pallas TPU flash-attention kernels (forward, backward-dQ, backward-dKV).

TARGET: TPU v5e MXU/VMEM.  Validated on CPU with ``interpret=True`` against
``kernels/ref.py`` (see tests/test_kernels.py).

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
    innermost, sequential ("arbitrary") axis, carrying the online-softmax
    state (m, l, acc) in VMEM scratch across kv steps — HBM->VMEM streaming
    of K/V blocks is done by the Pallas pipeline via BlockSpec index maps.
  * block_q × block_kv default 128×128: MXU-aligned (128 lanes) and the
    working set (q, k, v, acc at fp32) stays well under VMEM (~16 MB).
  * the mask is a *band* in token space, parameterized by a dynamic int32[4]
    SMEM operand (q_offset, kv_offset, lo, hi) and static strides — one
    kernel covers full / causal / striped-causal (paper §3.7) / sliding
    window, and the offsets may depend on ``jax.lax.axis_index`` inside
    shard_map (they are *data*, not trace-time constants).
  * fully-masked blocks are skipped at runtime with ``pl.when`` predication
    (the striped-causal schedule makes whole blocks invisible ~half the
    time, recovering the causal FLOP saving block-wise).
  * GQA: K/V carry Hkv heads; index maps divide the query head index.

All softmax arithmetic is fp32 regardless of the input dtype; matmuls use
``preferred_element_type=float32`` so the MXU accumulates in fp32.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import vma_struct
from repro.kernels.ref import BAND_INF, NEG_INF

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def _struct(shape, dtype, *like):
    """ShapeDtypeStruct whose varying-manual-axes set is the union of the
    inputs' — required for pallas_call outputs under shard_map(check_vma)."""
    return vma_struct(shape, dtype, *like)


def _block_visible(band_ref, iq, ik, bq, bk, stride_q, stride_kv):
    """Any (row, col) in this (q-block, kv-block) pair inside the band?"""
    q0 = band_ref[0] + stride_q * (iq * bq)
    q1 = band_ref[0] + stride_q * (iq * bq + bq - 1)
    k0 = band_ref[1] + stride_kv * (ik * bk)
    k1 = band_ref[1] + stride_kv * (ik * bk + bk - 1)
    dmax = q1 - k0
    dmin = q0 - k1
    return (dmax >= band_ref[2]) & (dmin <= band_ref[3])


def _band_mask_block(band_ref, iq, ik, bq, bk, stride_q, stride_kv):
    rows = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    qpos = band_ref[0] + stride_q * (iq * bq + rows)
    kpos = band_ref[1] + stride_kv * (ik * bk + cols)
    diff = qpos - kpos
    return (diff >= band_ref[2]) & (diff <= band_ref[3])


def _mask_block(band_ref, segq_ref, segk_ref, iq, ik, bq, bk, stride_q, stride_kv):
    """Band mask, composed with the segment-id (packed-document) mask when
    the seg refs are present: (i, j) visible iff in-band AND same segment."""
    mask = _band_mask_block(band_ref, iq, ik, bq, bk, stride_q, stride_kv)
    if segq_ref is not None:
        segq = segq_ref[0, :]  # [bq]
        segk = segk_ref[0, :]  # [bk]
        mask &= segq[:, None] == segk[None, :]
    return mask


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _fwd_kernel(
    band_ref,  # int32[4] in SMEM: (q_off, kv_off, lo, hi)
    q_ref,  # [1, 1, bq, D] VMEM
    k_ref,  # [1, 1, bk, D]
    v_ref,  # [1, 1, bk, D]
    *rest,  # [segq_ref [1, bq], segk_ref [1, bk],] o_ref, lse_ref, scratch...
    scale: float,
    stride_q: int,
    stride_kv: int,
    nk: int,
    has_seg: bool = False,
):
    if has_seg:
        segq_ref, segk_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    else:
        segq_ref = segk_ref = None
        o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    iq, ik = pl.program_id(2), pl.program_id(3)
    bq, d = q_ref.shape[2], q_ref.shape[3]
    bk = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(_block_visible(band_ref, iq, ik, bq, bk, stride_q, stride_kv))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _mask_block(band_ref, segq_ref, segk_ref, iq, ik, bq, bk, stride_q, stride_kv)
        m_prev = m_ref[...]
        m_cur = jnp.max(jnp.where(mask, s, NEG_INF), axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse = jnp.where(l > 0, m_ref[...] + jnp.log(l_safe), NEG_INF)
        lse_ref[0, 0] = lse[:, 0].astype(lse_ref.dtype)


def _seg_operands(seg_q, seg_kv, block_q, block_kv):
    """Segment ids as [1, S] int32 pallas operands + their BlockSpecs."""
    sq = jnp.asarray(seg_q, jnp.int32)[None, :]
    sk = jnp.asarray(seg_kv, jnp.int32)[None, :]
    specs = [
        pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (0, iq)),
        pl.BlockSpec((1, block_kv), lambda b, h, iq, ik: (0, ik)),
    ]
    return [sq, sk], specs


def flash_attention_fwd(
    q: jnp.ndarray,  # [B, Sq, H, D]
    k: jnp.ndarray,  # [B, Skv, Hkv, D]
    v: jnp.ndarray,
    band: jnp.ndarray,  # int32[4]; may be traced (e.g. from axis_index)
    *,
    scale: float,
    stride_q: int = 1,
    stride_kv: int = 1,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = True,
    seg_q: Optional[jnp.ndarray] = None,  # [Sq] int32 segment ids
    seg_kv: Optional[jnp.ndarray] = None,  # [Skv]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (o [B,Sq,H,D], lse [B,H,Sq])."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    if Sq % block_q or Skv % block_kv:
        raise ValueError(f"seq lengths ({Sq},{Skv}) not divisible by blocks ({block_q},{block_kv})")
    if H % Hkv:
        raise ValueError(f"H={H} not divisible by Hkv={Hkv}")
    group = H // Hkv
    nq, nk = Sq // block_q, Skv // block_kv
    has_seg = seg_q is not None

    qt = q.transpose(0, 2, 1, 3)  # [B, H, Sq, D]
    kt = k.transpose(0, 2, 1, 3)  # [B, Hkv, Skv, D]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, stride_q=stride_q, stride_kv=stride_kv, nk=nk,
        has_seg=has_seg,
    )
    grid = (B, H, nq, nk)
    out_shape = [
        _struct((B, H, Sq, D), q.dtype, q, k, v, band),
        _struct((B, H, Sq), jnp.float32, q, k, v, band),
    ]
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
    ]
    operands = [band.astype(jnp.int32), qt, kt, vt]
    if has_seg:
        seg_ops, seg_specs = _seg_operands(seg_q, seg_kv, block_q, block_kv)
        operands += seg_ops
        in_specs += seg_specs
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        name="mesh_flash_fwd",
    )(*operands)
    return o.transpose(0, 2, 1, 3), lse


# --------------------------------------------------------------------------
# backward: dQ  (grid over q blocks, kv innermost)
# --------------------------------------------------------------------------


def _dq_kernel(
    band_ref,
    q_ref,  # [1,1,bq,D]
    k_ref,  # [1,1,bk,D]
    v_ref,
    do_ref,  # [1,1,bq,D]
    lse_ref,  # [1,1,bq]
    delta_ref,  # [1,1,bq]
    *rest,  # [segq_ref, segk_ref,] dq_ref, acc_ref
    scale: float,
    stride_q: int,
    stride_kv: int,
    nk: int,
    has_seg: bool = False,
):
    if has_seg:
        segq_ref, segk_ref, dq_ref, acc_ref = rest
    else:
        segq_ref = segk_ref = None
        dq_ref, acc_ref = rest
    iq, ik = pl.program_id(2), pl.program_id(3)
    bq, d = q_ref.shape[2], q_ref.shape[3]
    bk = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_block_visible(band_ref, iq, ik, bq, bk, stride_q, stride_kv))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
        delta = delta_ref[0, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _mask_block(band_ref, segq_ref, segk_ref, iq, ik, bq, bk, stride_q, stride_kv)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta) * scale
        acc_ref[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


# --------------------------------------------------------------------------
# backward: dK/dV  (grid over kv blocks, q x head-group innermost)
# --------------------------------------------------------------------------


def _dkv_kernel(
    band_ref,
    q_ref,  # [1,1,bq,D]
    k_ref,  # [1,1,bk,D]
    v_ref,
    do_ref,  # [1,1,bq,D]
    lse_ref,  # [1,1,bq]
    delta_ref,  # [1,1,bq]
    *rest,  # [segq_ref, segk_ref,] dk_ref, dv_ref, dk_acc, dv_acc
    scale: float,
    stride_q: int,
    stride_kv: int,
    inner: int,  # = group * nq
    nq: int,
    has_seg: bool = False,
):
    if has_seg:
        segq_ref, segk_ref, dk_ref, dv_ref, dk_acc, dv_acc = rest
    else:
        segq_ref = segk_ref = None
        dk_ref, dv_ref, dk_acc, dv_acc = rest
    ik, it = pl.program_id(2), pl.program_id(3)
    iq = it % nq
    bq, d = q_ref.shape[2], q_ref.shape[3]
    bk = k_ref.shape[2]

    @pl.when(it == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(_block_visible(band_ref, iq, ik, bq, bk, stride_q, stride_kv))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
        delta = delta_ref[0, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _mask_block(band_ref, segq_ref, segk_ref, iq, ik, bq, bk, stride_q, stride_kv)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)  # [bq, bk]
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(it == inner - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    o: Optional[jnp.ndarray],
    lse: jnp.ndarray,  # [B, H, Sq]
    do: jnp.ndarray,
    band: jnp.ndarray,
    *,
    scale: float,
    stride_q: int = 1,
    stride_kv: int = 1,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = True,
    delta: Optional[jnp.ndarray] = None,  # [B, Sq, H]
    seg_q: Optional[jnp.ndarray] = None,  # [Sq] int32 segment ids
    seg_kv: Optional[jnp.ndarray] = None,  # [Skv]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """FlashAttention backward from saved (o, lse): (dq, dk, dv)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    group = H // Hkv
    nq, nk = Sq // block_q, Skv // block_kv
    band = band.astype(jnp.int32)
    has_seg = seg_q is not None

    if delta is None:
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    delta = delta.astype(jnp.float32).transpose(0, 2, 1)  # [B, H, Sq]

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = do.transpose(0, 2, 1, 3)

    interp_params = dict(interpret=interpret)

    dq_kernel = functools.partial(
        _dq_kernel, scale=scale, stride_q=stride_q, stride_kv=stride_kv, nk=nk,
        has_seg=has_seg,
    )
    dq_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
    ]
    dq_operands = [band, qt, kt, vt, dot, lse, delta]
    if has_seg:
        seg_ops, seg_specs = _seg_operands(seg_q, seg_kv, block_q, block_kv)
        dq_operands += seg_ops
        dq_specs += seg_specs
    dqt = pl.pallas_call(
        dq_kernel,
        grid=(B, H, nq, nk),
        in_specs=dq_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        out_shape=_struct((B, H, Sq, D), q.dtype, q, k, v, do, band),
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        name="mesh_flash_dq",
        **interp_params,
    )(*dq_operands)

    inner = group * nq
    dkv_kernel = functools.partial(
        _dkv_kernel, scale=scale, stride_q=stride_q, stride_kv=stride_kv, inner=inner, nq=nq,
        has_seg=has_seg,
    )
    dkv_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec(
            (1, 1, block_q, D),
            lambda b, hkv, ik, it, g=group, nq_=nq: (b, hkv * g + it // nq_, it % nq_, 0),
        ),
        pl.BlockSpec((1, 1, block_kv, D), lambda b, hkv, ik, it: (b, hkv, ik, 0)),
        pl.BlockSpec((1, 1, block_kv, D), lambda b, hkv, ik, it: (b, hkv, ik, 0)),
        pl.BlockSpec(
            (1, 1, block_q, D),
            lambda b, hkv, ik, it, g=group, nq_=nq: (b, hkv * g + it // nq_, it % nq_, 0),
        ),
        pl.BlockSpec(
            (1, 1, block_q),
            lambda b, hkv, ik, it, g=group, nq_=nq: (b, hkv * g + it // nq_, it % nq_),
        ),
        pl.BlockSpec(
            (1, 1, block_q),
            lambda b, hkv, ik, it, g=group, nq_=nq: (b, hkv * g + it // nq_, it % nq_),
        ),
    ]
    dkv_operands = [band, qt, kt, vt, dot, lse, delta]
    if has_seg:
        dkv_operands += [jnp.asarray(seg_q, jnp.int32)[None, :],
                         jnp.asarray(seg_kv, jnp.int32)[None, :]]
        dkv_specs += [
            pl.BlockSpec((1, block_q), lambda b, hkv, ik, it, nq_=nq: (0, it % nq_)),
            pl.BlockSpec((1, block_kv), lambda b, hkv, ik, it: (0, ik)),
        ]
    dkt, dvt = pl.pallas_call(
        dkv_kernel,
        grid=(B, Hkv, nk, inner),
        in_specs=dkv_specs,
        out_specs=[
            pl.BlockSpec((1, 1, block_kv, D), lambda b, hkv, ik, it: (b, hkv, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, hkv, ik, it: (b, hkv, ik, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_kv, D), jnp.float32),
            pltpu.VMEM((block_kv, D), jnp.float32),
        ],
        out_shape=[
            _struct((B, Hkv, Skv, D), k.dtype, q, k, v, do, band),
            _struct((B, Hkv, Skv, D), v.dtype, q, k, v, do, band),
        ],
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        name="mesh_flash_dkv",
        **interp_params,
    )(*dkv_operands)

    return (
        dqt.transpose(0, 2, 1, 3),
        dkt.transpose(0, 2, 1, 3),
        dvt.transpose(0, 2, 1, 3),
    )
