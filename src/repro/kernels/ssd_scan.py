"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (arXiv:2405.21060).

TARGET: TPU v5e.  Validated on CPU with interpret=True against
``ref.ssd_ref`` (sequential recurrence oracle) and the jnp chunked dual form.

TPU-native structure:
  * grid = (batch, heads, chunks); the chunk axis is sequential
    ("arbitrary"), carrying the [P, N] recurrence state in VMEM scratch —
    the cross-chunk linear recurrence never touches HBM.
  * within a chunk the dual quadratic form runs on the MXU:
    L ⊙ (C·Bᵀ) matmuls with the decay matrix built from a cumulative-sum
    expressed as a lower-triangular ones-matmul (MXU-friendly, no serial
    scan inside the kernel).
  * chunk length and head dims default to 64/128 lanes (hardware-aligned).

The kernel is forward-only (training uses the autodiff-able jnp dual form in
models/ssm.py; serving and the CP state hand-off use this kernel on TPU).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_fwd"]


def _ssd_kernel(
    A_ref,  # [H] f32 in SMEM
    x_ref,  # [1, 1, c, P]
    dt_ref,  # [1, 1, c]
    b_ref,  # [1, 1, c, N]
    c_ref,  # [1, 1, c, N]
    y_ref,  # [1, 1, c, P] out
    state_ref,  # [1, 1, P, N] out (final state)
    h_ref,  # scratch [P, N] f32
    *,
    nz: int,
):
    z = pl.program_id(2)
    head = pl.program_id(1)

    @pl.when(z == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # [c, P]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [c]
    Bm = b_ref[0, 0].astype(jnp.float32)  # [c, N]
    Cm = c_ref[0, 0].astype(jnp.float32)  # [c, N]
    A = A_ref[head]
    c = x.shape[0]

    a = (dt * A)[:, None]  # [c, 1], negative
    # inclusive cumulative sum as a lower-triangular ones matmul (MXU)
    tril = jnp.tril(jnp.ones((c, c), jnp.float32))
    acum = jax.lax.dot(tril, a, preferred_element_type=jnp.float32)  # [c,1]

    Lmat = jnp.exp(acum - acum[:, 0][None, :]) * tril  # [c, c] decay, masked
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [c, c]
    y = jax.lax.dot(
        (Lmat * scores) * dt[None, :], x, preferred_element_type=jnp.float32
    )  # [c, P] intra-chunk
    h = h_ref[...]
    y += jnp.exp(acum) * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # inter-chunk: exp(acum) * C @ h^T -> [c, P]

    total = jnp.exp(acum[c - 1, 0])
    decay_end = jnp.exp(acum[c - 1, 0] - acum[:, 0])  # [c]
    h_new = total * h + jax.lax.dot_general(
        x * (decay_end * dt)[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [P, N]
    h_ref[...] = h_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(z == nz - 1)
    def _final():
        state_ref[0, 0] = h_new.astype(state_ref.dtype)


def ssd_scan_fwd(
    x: jnp.ndarray,  # [B, S, H, P]
    dt: jnp.ndarray,  # [B, S, H] (softplus already applied)
    A: jnp.ndarray,  # [H] (negative)
    Bm: jnp.ndarray,  # [B, S, G, N]
    Cm: jnp.ndarray,  # [B, S, G, N]
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    nz = S // chunk
    group = H // G

    xt = x.transpose(0, 2, 1, 3)  # [B, H, S, P]
    dtt = dt.transpose(0, 2, 1)  # [B, H, S]
    bt = Bm.transpose(0, 2, 1, 3)  # [B, G, S, N]
    ct = Cm.transpose(0, 2, 1, 3)

    kernel = functools.partial(_ssd_kernel, nz=nz)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nz),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, z: (b, h, z, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, z: (b, h, z)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, z, g=group: (b, h // g, z, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, z, g=group: (b, h // g, z, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, z: (b, h, z, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, z: (b, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="ssd_scan_fwd",
    )(A.astype(jnp.float32), xt, dtt, bt, ct)
    return y.transpose(0, 2, 1, 3), state
