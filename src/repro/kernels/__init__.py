"""Pallas TPU kernels for the compute hot-spots (validated with
interpret=True on CPU against the pure-jnp oracles in ref.py):

  flash_attention — blockwise attention fwd + dq/dkv bwd; band masks cover
                    full / causal / striped-causal (paper §3.7) / sliding
                    window; GQA via head-group index maps.
  ssd_scan        — Mamba-2 SSD chunked scan (state carried in VMEM).
  ops             — jit'd dispatch wrappers (pallas on TPU, ref elsewhere)
                    + the custom_vjp single-device flash_attention.
"""
