"""Paged-native split-K flash-decode Pallas kernel.

The gather-based paged decode (``core/decode_attention.py::paged_cache_gather``
+ the dense band kernel) materializes each slot's full ``[max_pages *
page_size]`` local view from the physical page pool every tick, so decode HBM
traffic scales with *virtual capacity*, not with how deep any request actually
is.  This kernel reads the page pool **in place**:

  * the int32 block table and the per-slot position vector are
    **scalar-prefetched** (``pltpu.PrefetchScalarGridSpec``) so the BlockSpec
    index maps resolve logical page -> physical page before each grid step's
    DMA — the pool is indexed directly, no gathered intermediate ever exists;
  * the grid is ``(batch, split, pages_per_split)`` — **split-K over pages**:
    each split owns a contiguous run of a slot's logical pages and produces a
    partial ``(o, lse)`` carried in VMEM scratch (online softmax over its
    pages); splits combine outside the kernel with a numerically-stable LSE
    reduce (:func:`combine_split_partials`).  Mixed-depth slot pools therefore
    fill the grid with many small independent partials instead of serializing
    every row behind the deepest one;
  * pages a slot never allocated (block table ``-1``), pages past the row's
    depth, and pages a sliding window provably hides are skipped with
    ``pl.when`` predication, and their index maps **clamp to the nearest
    visible page** so the Pallas pipeline re-fetches nothing (consecutive
    equal block indices elide the DMA): HBM bytes/token follow depth;
  * the **partial last page** of a depth not divisible by ``page_size`` is
    masked inside the page by the position band (global position ``<= pos``),
    so the split's lse counts exactly the live tail — the combine then weighs
    it correctly against full pages (asserted exact-vs-oracle in
    tests/test_paged_decode.py).

Geometry matches ``core/decode_attention.py`` verbatim: local slot ``j`` of a
shard holds global position ``kv_offset + stride_kv * j`` (striped:
``(i, n)``; contiguous: ``(i*m, 1)``), and slot ``j`` lives at offset
``j % page_size`` of logical page ``j // page_size``.  A dense ``[B, m]``
cache is the degenerate case: reshape to ``[B * (m/chunk), chunk]`` pages
with the identity block table ``bt[b, c] = b * chunks + c`` — one implicit
page run per row — and this same kernel serves the dense decode path too.

TARGET: TPU v5e.  Off-TPU the kernel runs with ``interpret=True`` (CPU CI);
``REPRO_KERNELS=ref`` callers fall back to the gather path at the
``core/decode_attention.py`` layer instead (the exact oracle).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import vma_struct
from repro.kernels.ref import BAND_INF, NEG_INF

__all__ = [
    "paged_flash_decode",
    "combine_split_partials",
    "default_num_splits",
    "dense_chunk_for",
]

# default logical pages each split-K partial covers; small enough that a few
# allocated pages already spread over several grid cells, big enough that the
# per-split finalize/combine overhead stays negligible
DEFAULT_PAGES_PER_SPLIT = 4

# candidate chunk sizes (local positions) for viewing a DENSE cache row as an
# implicit page run; the largest divisor of m wins, capped MXU-friendly
_DENSE_CHUNKS = (128, 64, 32, 16, 8, 4, 2, 1)


def default_num_splits(max_pages: int) -> int:
    return max(1, -(-max_pages // DEFAULT_PAGES_PER_SPLIT))


def dense_chunk_for(m: int) -> int:
    """Page size for the dense-cache-as-one-page-run view of a [B, m] slice:
    the largest candidate dividing m (always found — 1 divides everything),
    so the reshape in ``sharded_cache_decode`` is exact."""
    return next(c for c in _DENSE_CHUNKS if c <= m and m % c == 0)


def combine_split_partials(
    o_parts: jnp.ndarray,  # [B, S, H, D] fp32 per-split partial outputs
    lse_parts: jnp.ndarray,  # [B, S, H] fp32 per-split lse (NEG_INF = empty)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Numerically-stable LSE reduce over the split axis -> ([B,1,H,D] fp32,
    [B,H,1] fp32), the same (o, lse) contract the banded partial returns.

    Empty splits (lse == NEG_INF) must contribute weight 0 even when EVERY
    split is empty (then m == NEG_INF and exp(lse - m) would be 1): the
    nonempty mask guards that, and a fully-hidden row combines to the exact
    empty-band result (o = 0, lse = NEG_INF) the psum combine expects.
    """
    m = jnp.maximum(jnp.max(lse_parts, axis=1), NEG_INF)  # [B, H]
    nonempty = lse_parts > NEG_INF / 2
    w = jnp.where(nonempty, jnp.exp(lse_parts - m[:, None]), 0.0)  # [B, S, H]
    den = jnp.sum(w, axis=1)  # [B, H]
    num = jnp.einsum("bsh,bshd->bhd", w, o_parts)
    den_safe = jnp.where(den > 0, den, 1.0)
    o = num / den_safe[..., None]
    lse = jnp.where(den > 0, m + jnp.log(den_safe), NEG_INF)
    return o[:, None], lse[..., None]  # [B,1,H,D], [B,H,1]


def _decode_kernel(
    # scalar prefetch (SMEM)
    bt_ref,  # [B, max_pages] int32 block table; -1 = unallocated
    pos_ref,  # [B] int32 per-slot positions
    off_ref,  # [1] int32 kv_offset (may be traced from axis_index)
    # blocks (VMEM)
    q_ref,  # [1, H, D]
    k_ref,  # [1, page_size, Hkv, D] one physical page
    v_ref,
    # quantized pools add two [1, page_size, Hkv] fp32 scale blocks here,
    # then outputs o [1,1,H,D] / lse [1,1,H], then scratch acc/m/l
    *rest,
    scale: float,
    stride_kv: int,
    page_size: int,
    max_pages: int,
    pages_per_split: int,
    hi: int,  # window - 1, or BAND_INF for no window
    group: int,  # H // Hkv (GQA)
    hkv: int,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    b, s, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    lp = s * pages_per_split + p  # logical page this grid step covers
    pos_b = pos_ref[b]
    kv_off = off_ref[0]
    page_lo = kv_off + stride_kv * (lp * page_size)  # first global pos in page
    page_hi = kv_off + stride_kv * (lp * page_size + page_size - 1)
    win_lo = jnp.maximum(pos_b - hi, 0)  # oldest visible global position
    visible = (
        (lp < max_pages)
        & (bt_ref[b, jnp.minimum(lp, max_pages - 1)] >= 0)
        & (page_lo <= pos_b)  # page starts at or before the row's depth
        & (page_hi >= win_lo)  # page ends inside the sliding window
    )

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [H, D]
        k = k_ref[0].astype(jnp.float32)  # [page_size, Hkv, D]
        v = v_ref[0].astype(jnp.float32)
        if quantized:
            # dequantize IN VMEM, right after the page's DMA: the scale tile
            # rode along as an extra prefetched operand through the same
            # clamped index map, so HBM moved 1-byte elements + one fp32
            # scale per (token, kv-head) instead of fp32 K/V
            k = k * ks_ref[0][:, :, None].astype(jnp.float32)
            v = v * vs_ref[0][:, :, None].astype(jnp.float32)
        s_rows = []
        for hk in range(hkv):  # GQA: per-kv-head [group, page_size] scores
            s_rows.append(jax.lax.dot_general(
                q[hk * group : (hk + 1) * group], k[:, hk, :],
                (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
            ))
        sc = jnp.concatenate(s_rows, axis=0) * scale  # [H, page_size]
        cols = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        gpos = page_lo + stride_kv * cols  # global position per column
        # the band masks the partial last page (columns past pos) AND any
        # in-page window tail — exactly the dense band kernel's predicate
        mask = (gpos <= pos_b) & (gpos >= win_lo)
        m_prev = m_ref[...]
        m_cur = jnp.max(jnp.where(mask, sc, NEG_INF), axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pw = jnp.where(mask, jnp.exp(sc - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pw, axis=1, keepdims=True)
        o_rows = []
        for hk in range(hkv):
            o_rows.append(jax.lax.dot(
                pw[hk * group : (hk + 1) * group], v[:, hk, :],
                preferred_element_type=jnp.float32,
            ))
        acc_ref[...] = acc_ref[...] * alpha + jnp.concatenate(o_rows, axis=0)
        m_ref[...] = m_new

    @pl.when(p == pages_per_split - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = acc_ref[...] / l_safe
        lse_ref[0, 0] = jnp.where(
            l[:, 0] > 0, m_ref[:, 0] + jnp.log(l_safe[:, 0]), NEG_INF
        )


def paged_flash_decode(
    q: jnp.ndarray,  # [B, 1, H, D] the new token's queries
    k_pool: jnp.ndarray,  # [num_pages, page_size, Hkv, D] local page pool
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32; -1 = unallocated
    pos,  # int32 scalar or [B]: attends to global positions <= pos
    kv_offset,  # int32 (may be traced): global position of local slot 0
    *,
    stride_kv: int,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    num_splits: Optional[int] = None,
    interpret: Optional[bool] = None,
    k_scale: Optional[jnp.ndarray] = None,  # [num_pages, page_size, Hkv] f32
    v_scale: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """This shard's decode partial straight off the page pool: returns
    (o [B,1,H,D] in q.dtype, lse [B,H,1] fp32) — the same contract as the
    gather path's banded partial, ready for the cross-shard psum combine.

    ``k_scale``/``v_scale`` mark a quantized pool (int8 / fp8 elements):
    each page's scale tile is fetched through the same clamped index map and
    K/V are dequantized in VMEM right after the DMA."""
    B, _, H, D = q.shape
    num_pages, page_size, hkv, _ = k_pool.shape
    max_pages = block_table.shape[1]
    if H % hkv:
        raise ValueError(f"H={H} not divisible by Hkv={hkv}")
    group = H // hkv
    if scale is None:
        scale = D**-0.5
    hi = (window - 1) if window else BAND_INF
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    off = jnp.reshape(jnp.asarray(kv_offset, jnp.int32), (1,))
    bt = jnp.asarray(block_table, jnp.int32)
    if num_splits is None:
        num_splits = default_num_splits(max_pages)
    num_splits = max(1, min(int(num_splits), max_pages))
    pages_per_split = -(-max_pages // num_splits)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def kv_index_map(b, s, p, bt_ref, pos_ref, off_ref):
        # clamp invisible steps to the nearest VISIBLE logical page so runs of
        # skipped steps keep the block index constant and the pipeline elides
        # their DMAs (depth-proportional HBM traffic, not capacity)
        lp = s * pages_per_split + p
        pos_b, kv_off = pos_ref[b], off_ref[0]
        lp_hi = (pos_b - kv_off) // (stride_kv * page_size)  # last visible
        win_lo = jnp.maximum(pos_b - hi, 0)
        j_lo = (win_lo - kv_off + stride_kv - 1) // stride_kv
        lp_lo = jnp.maximum(j_lo, 0) // page_size  # first visible
        lp_hi = jnp.clip(lp_hi, 0, max_pages - 1)
        lp_lo = jnp.clip(lp_lo, 0, lp_hi)
        lp_eff = jnp.clip(lp, lp_lo, lp_hi)
        return (jnp.maximum(bt_ref[b, lp_eff], 0), 0, 0, 0)

    def scale_index_map(b, s, p, bt_ref, pos_ref, off_ref):
        # the scale tile rides the pool's physical-page resolution verbatim
        return kv_index_map(b, s, p, bt_ref, pos_ref, off_ref)[:3]

    quantized = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, H, D), lambda b, s, p, *_: (b, 0, 0)),
        pl.BlockSpec((1, page_size, hkv, D), kv_index_map),
        pl.BlockSpec((1, page_size, hkv, D), kv_index_map),
    ]
    operands = [bt, pos, off, q[:, 0], k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, page_size, hkv), scale_index_map),
            pl.BlockSpec((1, page_size, hkv), scale_index_map),
        ]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, num_splits, pages_per_split),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, H, D), lambda b, s, p, *_: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, H), lambda b, s, p, *_: (b, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _decode_kernel,
        scale=float(scale), stride_kv=stride_kv, page_size=page_size,
        max_pages=max_pages, pages_per_split=pages_per_split, hi=hi,
        group=group, hkv=hkv, quantized=quantized,
    )
    like = tuple(operands)
    o_parts, lse_parts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            vma_struct((B, num_splits, H, D), jnp.float32, *like),
            vma_struct((B, num_splits, H), jnp.float32, *like),
        ],
        interpret=interpret,
        compiler_params=None
        if interpret
        else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        name="paged_flash_decode",
    )(*operands)
    o, lse = combine_split_partials(o_parts, lse_parts)
    return o.astype(q.dtype), lse
