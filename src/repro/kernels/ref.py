"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU) and the fallback implementation on backends without Pallas support.

Conventions shared with the kernels:
  * q: [B, Sq, H, D]; k, v: [B, Skv, Hkv, D] with H % Hkv == 0 (GQA).
  * outputs: o [B, Sq, H, D] and lse [B, H, Sq] (natural log-sum-exp of the
    scaled scores; ``NEG_INF`` for fully-masked rows).
  * masking is a *band* in token space: position pair (i, j) is visible iff
    ``lo <= (q_offset + stride_q*i) - (kv_offset + stride_kv*j) <= hi``.
    - full attention:      band = None
    - causal:              (0, 0, 0, BAND_INF)
    - striped-causal block between global chunks (qc, kc) of an n-way stripe:
      (qc, kc, 0, BAND_INF) with stride_q = stride_kv = n  (paper §3.7)
    - sliding window W (inclusive of self): (0, 0, 0, W-1) composed with the
      stripes the same way.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
BAND_INF = 2**30

Band = Tuple[int, int, int, int]  # (q_offset, kv_offset, lo, hi) — may be traced


def causal_band(offset: int = 0) -> Band:
    """Visible iff q_pos - kv_pos + offset >= 0 (offset in {0,-1} for striped
    blocks — see core.tiling.striped_causal_offset)."""
    return (offset, 0, 0, BAND_INF)


def band_mask(
    sq: int,
    sk: int,
    band: Band,
    *,
    stride_q: int = 1,
    stride_kv: int = 1,
) -> jnp.ndarray:
    q_off, kv_off, lo, hi = band
    qpos = q_off + stride_q * jnp.arange(sq, dtype=jnp.int32)
    kpos = kv_off + stride_kv * jnp.arange(sk, dtype=jnp.int32)
    diff = qpos[:, None] - kpos[None, :]
    return (diff >= lo) & (diff <= hi)


def repeat_kv(x: jnp.ndarray, h: int) -> jnp.ndarray:
    """Expand Hkv heads to H query heads (GQA)."""
    hkv = x.shape[2]
    if hkv == h:
        return x
    return jnp.repeat(x, h // hkv, axis=2)


def _seg_mask(seg_q: jnp.ndarray, seg_kv: jnp.ndarray) -> jnp.ndarray:
    """[Sq, Skv] visibility from per-token segment ids (packed documents)."""
    return seg_q[:, None] == seg_kv[None, :]


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    scale: Optional[float] = None,
    band: Optional[Band] = None,
    stride_q: int = 1,
    stride_kv: int = 1,
    seg_q: Optional[jnp.ndarray] = None,  # [Sq] int32 segment ids
    seg_kv: Optional[jnp.ndarray] = None,  # [Skv]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (o [B,Sq,H,D], lse [B,H,Sq]); fp32 softmax arithmetic.

    ``seg_q``/``seg_kv`` compose a segment-id (packed-document) mask with the
    band: (i, j) visible iff the band admits it AND seg_q[i] == seg_kv[j].
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    if scale is None:
        scale = D**-0.5
    kr = repeat_kv(k, H)
    vr = repeat_kv(v, H)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    mask = None
    if band is not None:
        mask = band_mask(Sq, Sk, band, stride_q=stride_q, stride_kv=stride_kv)
    if seg_q is not None:
        sm = _seg_mask(seg_q, seg_kv)
        mask = sm if mask is None else (mask & sm)
    if mask is not None:
        s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # fully-masked rows
    p = jnp.exp(s - m)
    if mask is not None:
        p = jnp.where(mask[None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l_safe = jnp.where(l > 0, l, 1.0)
    o = jnp.einsum("bhqk,bkhd->bqhd", p / l_safe, vr.astype(jnp.float32))
    lse = jnp.where(l[..., 0] > 0, m[..., 0] + jnp.log(l_safe[..., 0]), NEG_INF)
    return o.astype(q.dtype), lse.astype(jnp.float32)


def combine_partials(
    o1: jnp.ndarray, lse1: jnp.ndarray, o2: jnp.ndarray, lse2: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Online-softmax reduce of two partial attention outputs over disjoint KV
    sets (the paper's reduce-scatter operator for O chunks, §2.2/Alg. 1).

    o: [B, S, H, D]; lse: [B, H, S].  Safe for NEG_INF (empty) partials.
    """
    m = jnp.maximum(lse1, lse2)
    m = jnp.maximum(m, NEG_INF)
    w1 = jnp.exp(lse1 - m)  # [B,H,S]
    w2 = jnp.exp(lse2 - m)
    tot = w1 + w2
    tot_safe = jnp.where(tot > 0, tot, 1.0)
    c1 = (w1 / tot_safe)[..., None].swapaxes(1, 2)  # [B,S,H,1]
    c2 = (w2 / tot_safe)[..., None].swapaxes(1, 2)
    o = o1 * c1.astype(o1.dtype) + o2 * c2.astype(o2.dtype)
    lse = jnp.where(tot > 0, m + jnp.log(tot_safe), NEG_INF)
    return o, lse


def attention_bwd_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    o: Optional[jnp.ndarray],
    lse: jnp.ndarray,
    do: jnp.ndarray,
    *,
    scale: Optional[float] = None,
    band: Optional[Band] = None,
    stride_q: int = 1,
    stride_kv: int = 1,
    delta: Optional[jnp.ndarray] = None,  # [B, Sq, H]; derived from o if None
    seg_q: Optional[jnp.ndarray] = None,  # [Sq] int32 segment ids
    seg_kv: Optional[jnp.ndarray] = None,  # [Skv]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """FlashAttention-style backward from saved (o, lse): returns dq, dk, dv.

    Identical math to the Pallas backward kernels; note dk/dv sum over the
    GQA query-head group.  ``delta`` (= rowsum(do*o)) may be supplied
    directly — the "QdOΔ wire" optimization circulates it instead of O.
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    if scale is None:
        scale = D**-0.5
    g = H // Hkv
    kr = repeat_kv(k, H).astype(jnp.float32)
    vr = repeat_kv(v, H).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kr) * scale
    p = jnp.exp(s - lse[..., None])  # true softmax weights via final lse
    mask = None
    if band is not None:
        mask = band_mask(Sq, Sk, band, stride_q=stride_q, stride_kv=stride_kv)
    if seg_q is not None:
        sm = _seg_mask(seg_q, seg_kv)
        mask = sm if mask is None else (mask & sm)
    if mask is not None:
        p = jnp.where(mask[None, None], p, 0.0)
    if delta is None:
        delta = jnp.sum(dof * o.astype(jnp.float32), axis=-1)  # [B,Sq,H]
    else:
        delta = delta.astype(jnp.float32)
    dp = jnp.einsum("bqhd,bkhd->bhqk", dof, vr)
    ds = p * (dp - delta.swapaxes(1, 2)[..., None]) * scale
    dq = jnp.einsum("bhqk,bkhd->bqhd", ds, kr)
    dk_full = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
    dv_full = jnp.einsum("bhqk,bqhd->bkhd", p, dof)
    dk = dk_full.reshape(B, Sk, Hkv, g, D).sum(axis=3)
    dv = dv_full.reshape(B, Sk, Hkv, g, D).sum(axis=3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# --------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) oracle — used by kernels/ssd_scan.py
# --------------------------------------------------------------------------


def ssd_ref(
    x: jnp.ndarray,  # [B, S, H, P]   (P = head channel dim)
    dt: jnp.ndarray,  # [B, S, H]     (softplus-activated step sizes)
    A: jnp.ndarray,  # [H]            (negative decay rates)
    Bm: jnp.ndarray,  # [B, S, G, N]  (input projection, G state groups)
    Cm: jnp.ndarray,  # [B, S, G, N]  (output projection)
    *,
    initial_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential reference of the SSD recurrence (arXiv:2405.21060 eq. SSM):

        h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T
        y_t = C_t h_t

    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # [B,S,H,N]
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, None, :])  # [B,S,H]

    if initial_state is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    else:
        h0 = initial_state.astype(jnp.float32)

    def step(h, t):
        d = decay[:, t][..., None, None]  # [B,H,1,1]
        upd = (dtf[:, t][..., None, None] * xf[:, t][..., None]) * Bh[:, t][:, :, None, :]
        h = d * h + upd  # [B,H,P,N]
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t])
        return h, y

    hT, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = ys.transpose(1, 0, 2, 3)  # [B,S,H,P]
    return y.astype(x.dtype), hT
