"""Multi-device correctness battery, runnable as a subprocess.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.testing.dist_check [check ...]

The main pytest process must stay at 1 CPU device (per the dry-run rules), so
tests/test_distributed.py launches this module in a child process with fake
devices and asserts on its JSON report.  Every check compares a distributed
computation against the single-device oracle on the gathered arrays.
"""

from __future__ import annotations

import json
import sys
import traceback

import numpy as np


def _setup():
    import jax

    return jax


def _mk(key, *shape):
    import jax

    return jax.random.normal(key, shape, dtype=jnp_f32())


def jnp_f32():
    import jax.numpy as jnp

    return jnp.float32


# --------------------------------------------------------------------------


def check_mesh_attention_forward():
    """Mesh-Attention fwd == single-device ref for every (a,b), mask, GQA."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map

    from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
    from repro.core.tiling import factorizations, stripe_permutation, unstripe_permutation
    from repro.kernels import ref

    n = 8
    mesh = jax.make_mesh((n,), ("sp",))
    B, S, H, Hkv, D = 2, n * 16, 4, 2, 16
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))

    results = {}
    for a, b in factorizations(n):
        for causal, window in [(False, None), (True, None), (True, 40)]:
            cfg = MeshAttentionConfig(
                axis_name="sp", n=n, a=a, causal=causal, window=window,
                block_q=16, block_kv=16,
            )
            f = shard_map(
                lambda q, k, v, cfg=cfg: mesh_attention(q, k, v, cfg),
                mesh=mesh,
                in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
                out_specs=P(None, "sp"),
            )
            if causal:
                perm = stripe_permutation(S, n)
                inv = unstripe_permutation(S, n)
                o = jax.jit(f)(q[:, perm], k[:, perm], v[:, perm])[:, inv]
                band = ref.causal_band()
                if window:
                    band = (0, 0, 0, window - 1)
            else:
                o = jax.jit(f)(q, k, v)
                band = None
            o_ref, _ = ref.attention_ref(q, k, v, band=band)
            err = float(jnp.max(jnp.abs(o - o_ref)))
            results[f"a{a}b{b}_causal{causal}_w{window}"] = err
            assert err < 2e-5, (a, b, causal, window, err)
    return results


def check_mesh_attention_backward():
    """custom_vjp (Alg. 3 ring program) == autodiff through the dense oracle."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
    from repro.core.tiling import factorizations, stripe_permutation, unstripe_permutation
    from repro.kernels import ref

    n = 8
    mesh = jax.make_mesh((n,), ("sp",))
    B, S, H, Hkv, D = 1, n * 8, 4, 2, 8
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    perm = stripe_permutation(S, n)
    inv = unstripe_permutation(S, n)

    results = {}
    for a, b in factorizations(n):
        for causal in (False, True):
            for wire in ("qdod", "odoq"):
                cfg = MeshAttentionConfig(
                    axis_name="sp", n=n, a=a, causal=causal,
                    block_q=8, block_kv=8, bwd_wire=wire,
                )
                f = shard_map(
                    lambda q, k, v, cfg=cfg: mesh_attention(q, k, v, cfg),
                    mesh=mesh,
                    in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
                    out_specs=P(None, "sp"),
                )

                def loss_dist(q, k, v):
                    if causal:
                        o = f(q[:, perm], k[:, perm], v[:, perm])[:, inv]
                    else:
                        o = f(q, k, v)
                    return jnp.sum(jnp.sin(o))

                def loss_ref(q, k, v):
                    H = q.shape[2]
                    kr, vr = ref.repeat_kv(k, H), ref.repeat_kv(v, H)
                    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * (D**-0.5)
                    if causal:
                        mask = jnp.tril(jnp.ones((S, S), bool))
                        s = jnp.where(mask[None, None], s, -1e30)
                    p = jax.nn.softmax(s, axis=-1)
                    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
                    return jnp.sum(jnp.sin(o))

                g1 = jax.jit(jax.grad(loss_dist, argnums=(0, 1, 2)))(q, k, v)
                g2 = jax.jit(jax.grad(loss_ref, argnums=(0, 1, 2)))(q, k, v)
                errs = [float(jnp.max(jnp.abs(x - y))) for x, y in zip(g1, g2)]
                results[f"a{a}_causal{causal}_{wire}"] = max(errs)
                assert max(errs) < 5e-5, (a, causal, wire, errs)
    return results


def check_mesh_attention_pallas_interpret():
    """One full fwd+bwd config with the Pallas kernels (interpret=True) inside
    the ring program — validates the kernel/ring integration end to end."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
    from repro.core.tiling import stripe_permutation, unstripe_permutation
    from repro.kernels import ops, ref

    ops.set_backend("pallas")
    try:
        n, a = 4, 2
        mesh = jax.make_mesh((n,), ("sp",))
        B, S, H, Hkv, D = 1, n * 16, 2, 1, 8
        key = jax.random.PRNGKey(2)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (B, S, H, D))
        k = jax.random.normal(kk, (B, S, Hkv, D))
        v = jax.random.normal(kv, (B, S, Hkv, D))
        perm = stripe_permutation(S, n)
        inv = unstripe_permutation(S, n)
        cfg = MeshAttentionConfig(
            axis_name="sp", n=n, a=a, causal=True, block_q=8, block_kv=8
        )
        # check_vma=False: the pallas hlo interpreter mixes varying and
        # uniform values inside its grid loop, tripping the vma checker
        # (jax-ml/jax interpreter limitation; compiled TPU path is fine).
        f = shard_map(
            lambda q, k, v: mesh_attention(q, k, v, cfg),
            mesh=mesh,
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp"),
            check_vma=False,
        )

        def loss(q, k, v):
            return jnp.sum(jnp.sin(f(q[:, perm], k[:, perm], v[:, perm])[:, inv]))

        o = jax.jit(f)(q[:, perm], k[:, perm], v[:, perm])[:, inv]
        o_ref, _ = ref.attention_ref(q, k, v, band=ref.causal_band())
        err_o = float(jnp.max(jnp.abs(o - o_ref)))
        assert err_o < 2e-5, err_o

        g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

        def loss_ref(q, k, v):
            kr, vr = ref.repeat_kv(k, H), ref.repeat_kv(v, H)
            s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * (D**-0.5)
            mask = jnp.tril(jnp.ones((S, S), bool))
            s = jnp.where(mask[None, None], s, -1e30)
            o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, axis=-1), vr)
            return jnp.sum(jnp.sin(o))

        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        err_g = max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(g, gr))
        assert err_g < 5e-5, err_g
        return {"fwd_err": err_o, "bwd_err": err_g}
    finally:
        ops.set_backend("auto")


def check_ring_equals_mesh_a1():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
    from repro.core.ring_attention import ring_config

    n = 8
    mesh = jax.make_mesh((n,), ("sp",))
    B, S, H, D = 1, n * 8, 2, 8
    key = jax.random.PRNGKey(3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D)) for kk in jax.random.split(key, 3))

    def run(cfg):
        f = shard_map(
            lambda q, k, v: mesh_attention(q, k, v, cfg),
            mesh=mesh,
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp"),
        )
        return jax.jit(f)(q, k, v)

    o_ring = run(ring_config("sp", n, block_q=8, block_kv=8))
    o_mesh = run(MeshAttentionConfig(axis_name="sp", n=n, a=1, block_q=8, block_kv=8))
    err = float(jnp.max(jnp.abs(o_ring - o_mesh)))
    assert err < 1e-6, err
    return {"err": err}


def check_ulysses():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    from repro.core.ulysses import ulysses_attention
    from repro.kernels import ref

    n = 2  # capped by Hkv=2
    mesh = jax.make_mesh((n,), ("sp",))
    B, S, H, Hkv, D = 2, n * 16, 4, 2, 16
    key = jax.random.PRNGKey(4)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    results = {}
    for causal in (False, True):
        f = shard_map(
            lambda q, k, v, c=causal: ulysses_attention(q, k, v, "sp", n, causal=c),
            mesh=mesh,
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp"),
        )
        o = jax.jit(f)(q, k, v)
        o_ref, _ = ref.attention_ref(q, k, v, band=ref.causal_band() if causal else None)
        err = float(jnp.max(jnp.abs(o - o_ref)))
        results[f"causal{causal}"] = err
        assert err < 2e-5, (causal, err)
    # head-cap limitation must raise
    try:
        ulysses_attention(q[:, :4], k[:, :4], v[:, :4], "sp", 4)
        raise AssertionError("expected ValueError for n > Hkv")
    except ValueError:
        pass
    return results


def check_striped_decode():
    """Incremental striped-cache decode == full attention at every step."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    from repro.core.decode_attention import striped_cache_decode, striped_cache_update
    from repro.kernels import ref

    n = 4
    mesh = jax.make_mesh((n,), ("sp",))
    B, H, Hkv, D = 2, 4, 2, 8
    cap = 8  # local slots -> max context n*cap = 32
    T = 20
    key = jax.random.PRNGKey(5)
    qs = jax.random.normal(key, (T, B, 1, H, D))
    ks = jax.random.normal(jax.random.PRNGKey(6), (T, B, 1, Hkv, D))
    vs = jax.random.normal(jax.random.PRNGKey(7), (T, B, 1, Hkv, D))

    def upd(kc, vc, kn, vn, pos):
        return striped_cache_update(kc, vc, kn, vn, pos, "sp", n)

    def dec(q, kc, vc, pos):
        return striped_cache_decode(q, kc, vc, pos, "sp", n)

    upd_f = jax.jit(
        shard_map(
            upd, mesh=mesh,
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, None), P(None, None), P()),
            out_specs=(P(None, "sp"), P(None, "sp")),
        )
    )
    dec_f = jax.jit(
        shard_map(
            dec, mesh=mesh,
            in_specs=(P(None, None), P(None, "sp"), P(None, "sp"), P()),
            out_specs=P(None, None),
        )
    )
    k_cache = jnp.zeros((B, n * cap, Hkv, D))
    v_cache = jnp.zeros((B, n * cap, Hkv, D))
    max_err = 0.0
    for t in range(T):
        pos = jnp.int32(t)
        k_cache, v_cache = upd_f(k_cache, v_cache, ks[t], vs[t], pos)
        o = dec_f(qs[t], k_cache, v_cache, pos)
        o_ref, _ = ref.attention_ref(
            qs[t], ks[: t + 1, :, 0].transpose(1, 0, 2, 3), vs[: t + 1, :, 0].transpose(1, 0, 2, 3)
        )
        max_err = max(max_err, float(jnp.max(jnp.abs(o - o_ref))))
    assert max_err < 2e-5, max_err
    return {"max_err": max_err}


def check_decode_edge():
    """sharded_cache_decode/update edge cases on 8 fake devices: contiguous
    layout, sliding-window banding, empty-shard (den == 0) safety, and the
    per-slot position vector (mixed depths == per-row scalar decode)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    from repro.core.decode_attention import sharded_cache_decode, sharded_cache_update
    from repro.kernels import ref

    n = 8
    mesh = jax.make_mesh((n,), ("sp",))
    B, H, Hkv, D = 2, 4, 2, 8
    m = 4  # local slots: global capacity n*m = 32
    T = 12
    qs = jax.random.normal(jax.random.PRNGKey(5), (T, B, 1, H, D))
    ks = jax.random.normal(jax.random.PRNGKey(6), (T, B, 1, Hkv, D))
    vs = jax.random.normal(jax.random.PRNGKey(7), (T, B, 1, Hkv, D))

    def build(layout, window=None, vec_pos=False, prune=True):
        pos_spec = P(None) if vec_pos else P()

        def upd(kc, vc, kn, vn, pos):
            return sharded_cache_update(kc, vc, kn, vn, pos, "sp", n, layout=layout)

        def dec(q, kc, vc, pos):
            return sharded_cache_decode(
                q, kc, vc, pos, "sp", n, layout=layout, window=window, prune=prune
            )

        upd_f = jax.jit(shard_map(
            upd, mesh=mesh,
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, None), P(None, None), pos_spec),
            out_specs=(P(None, "sp"), P(None, "sp")),
            check_vma=False,
        ))
        dec_f = jax.jit(shard_map(
            dec, mesh=mesh,
            in_specs=(P(None, None), P(None, "sp"), P(None, "sp"), pos_spec),
            out_specs=P(None, None),
            check_vma=False,
        ))
        return upd_f, dec_f

    results = {}
    # 1+2+3: contiguous layout and striped+window, stepwise vs the dense
    # oracle.  Early steps (t < n under striping, t < m under contiguous)
    # leave most shards EMPTY — exercising the den == 0 psum guard.
    for name, layout, window in (
        ("contiguous", "contiguous", None),
        ("striped_window", "striped", 5),
        ("contiguous_window", "contiguous", 5),
    ):
        upd_f, dec_f = build(layout, window)
        k_cache = jnp.zeros((B, n * m, Hkv, D))
        v_cache = jnp.zeros((B, n * m, Hkv, D))
        max_err = 0.0
        for t in range(T):
            pos = jnp.int32(t)
            k_cache, v_cache = upd_f(k_cache, v_cache, ks[t], vs[t], pos)
            o = dec_f(qs[t], k_cache, v_cache, pos)
            assert not np.isnan(np.asarray(o)).any(), (name, t, "NaN")
            band = (t, 0, 0, (window - 1) if window else ref.BAND_INF)
            o_ref, _ = ref.attention_ref(
                qs[t],
                ks[: t + 1, :, 0].transpose(1, 0, 2, 3),
                vs[: t + 1, :, 0].transpose(1, 0, 2, 3),
                band=band,
            )
            max_err = max(max_err, float(jnp.max(jnp.abs(o - o_ref))))
        assert max_err < 2e-5, (name, max_err)
        results[name] = max_err

    # 4: per-slot position vector — rows at different depths in ONE call must
    # equal each row decoded alone at its own scalar depth
    for layout in ("striped", "contiguous"):
        upd_s, dec_s = build(layout)
        upd_v, dec_v = build(layout, vec_pos=True)
        depths = (3, 9)  # row 0 shallow, row 1 deep
        caches = []
        for b, depth in enumerate(depths):
            kc = jnp.zeros((1, n * m, Hkv, D))
            vc = jnp.zeros((1, n * m, Hkv, D))
            for t in range(depth):
                kc, vc = upd_s(kc, vc, ks[t, b : b + 1], vs[t, b : b + 1], jnp.int32(t))
            caches.append((kc, vc))
        kc = jnp.concatenate([c[0] for c in caches], axis=0)
        vc = jnp.concatenate([c[1] for c in caches], axis=0)
        pos_vec = jnp.asarray(depths, jnp.int32)
        # vector update writes each row at its own position...
        t = max(depths)  # any step index for fresh K/V
        kc2, vc2 = upd_v(kc, vc, ks[t], vs[t], pos_vec)
        o_vec = dec_v(qs[t], kc2, vc2, pos_vec)
        # ...and must match the per-row scalar path exactly
        max_err = 0.0
        for b, depth in enumerate(depths):
            kb, vb = upd_s(
                caches[b][0], caches[b][1],
                ks[t, b : b + 1], vs[t, b : b + 1], jnp.int32(depth),
            )
            o_b = dec_s(qs[t, b : b + 1], kb, vb, jnp.int32(depth))
            max_err = max(max_err, float(jnp.max(jnp.abs(o_vec[b : b + 1] - o_b))))
        assert max_err == 0.0, (layout, "vector pos != scalar pos", max_err)
        results[f"vec_pos_{layout}"] = max_err

    # 5: mask-pruned decode — the lax.cond shard skip under a sliding window
    # (shard-uniform window-start round-down) must be EXACT: bitwise equal to
    # the always-run-the-kernel program at every depth, scalar and vector pos.
    # window=3 < n=8 leaves most shards provably empty under both layouts.
    for layout in ("striped", "contiguous"):
        upd_f, dec_p = build(layout, window=3, prune=True)
        _, dec_u = build(layout, window=3, prune=False)
        k_cache = jnp.zeros((B, n * m, Hkv, D))
        v_cache = jnp.zeros((B, n * m, Hkv, D))
        for t in range(T):
            pos = jnp.int32(t)
            k_cache, v_cache = upd_f(k_cache, v_cache, ks[t], vs[t], pos)
            o_p = dec_p(qs[t], k_cache, v_cache, pos)
            o_u = dec_u(qs[t], k_cache, v_cache, pos)
            assert (np.asarray(o_p) == np.asarray(o_u)).all(), (layout, t)
        upd_v, dec_pv = build(layout, window=3, vec_pos=True, prune=True)
        _, dec_uv = build(layout, window=3, vec_pos=True, prune=False)
        pos_vec = jnp.asarray((3, 9), jnp.int32)  # mixed depths
        o_pv = dec_pv(qs[0], k_cache, v_cache, pos_vec)
        o_uv = dec_uv(qs[0], k_cache, v_cache, pos_vec)
        assert (np.asarray(o_pv) == np.asarray(o_uv)).all(), (layout, "vec")
        results[f"prune_exact_{layout}"] = 0.0
    return results


def check_serve_stream():
    """Continuous batching on a (2,4) mesh: a mixed-length arrival trace is
    served with slots at different depths decoding in one jitted step per
    tick; every request's tokens equal sequential single-request generation,
    and jit retraces are bounded by the bucket set."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    trace = [(16, 0), (32, 1), (64, 2), (16, 4)]
    prompts = [
        rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln, _ in trace
    ]
    new_tokens = 6

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)
    eng = ServeEngine(cfg, params, ctx=ctx, max_seq=128, num_slots=3)
    rids = [
        eng.submit(p, max_new_tokens=new_tokens, arrival_tick=tick)
        for p, (_, tick) in zip(prompts, trace)
    ]
    finished = eng.run()
    assert sum(eng.prefill_trace_counts.values()) == len({16, 32, 64})
    assert eng.decode_trace_count == 1, eng.decode_trace_count

    # sequential single-request oracle on a single device
    seq_eng = ServeEngine(cfg, params, max_seq=128, num_slots=1)
    for rid, p in zip(rids, prompts):
        ref_out = seq_eng.generate(p[None, :], max_new_tokens=new_tokens)
        got = finished[rid].generated
        assert got == ref_out[0].tolist(), (rid, got, ref_out[0].tolist())
    return {
        "tokens": {rid: finished[rid].generated for rid in rids},
        "prefill_traces": {str(k): v for k, v in eng.prefill_trace_counts.items()},
    }


def check_dispatch_seam():
    """The unified dispatch entry (registry + autotuned plan cache) ==
    single-device oracle for every backend it can route on this mesh."""
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.core.dispatch import (
        AttentionPlanConfig,
        distributed_attention,
        plan_from_ctx,
        plan_schedules,
    )
    from repro.core.am import CommModel
    from repro.core.tiling import stripe_permutation, unstripe_permutation
    from repro.kernels import ref
    from repro.parallel.context import ParallelCtx

    n = 8
    mesh = jax.make_mesh((n,), ("sp",))
    B, S, H, Hkv, D = 2, n * 16, 4, 2, 16
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(21), 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    results = {}

    with tempfile.TemporaryDirectory() as cache_dir:
        base = ParallelCtx(mesh=mesh, sp_axis="sp", block_q=16, block_kv=16,
                           plan_cache_dir=cache_dir)
        cases = [
            ("mesh", dict(attn_impl="mesh"), True, "striped"),
            ("mesh_autotuned", dict(attn_impl="mesh", attn_autotune=True), True, "striped"),
            ("ring", dict(attn_impl="ring"), True, "striped"),
            # ulysses runs below on its own 2-device mesh (n=8 > Hkv=2 here)
        ]
        import dataclasses

        for name, over, causal, layout in cases:
            ctx = dataclasses.replace(base, **over)
            cfg = plan_from_ctx(ctx, causal=causal, layout=layout)
            f = jax.jit(lambda q, k, v, cfg=cfg, ctx=ctx: distributed_attention(
                q, k, v, cfg=cfg, ctx=ctx))
            if causal and layout == "striped":
                perm = stripe_permutation(S, n)
                inv = unstripe_permutation(S, n)
                o = f(q[:, perm], k[:, perm], v[:, perm])[:, inv]
                band = ref.causal_band()
            else:
                o, band = f(q, k, v), None
            o_ref, _ = ref.attention_ref(q, k, v, band=band)
            err = float(jnp.max(jnp.abs(o - o_ref)))
            results[name] = err
            assert err < 2e-5, (name, err)

        # ulysses routes when the head cap allows (2 devices over Hkv=2)
        mesh2 = jax.make_mesh((2,), ("sp",))
        ctx2 = ParallelCtx(mesh=mesh2, sp_axis="sp", attn_impl="ulysses",
                           block_q=16, block_kv=16)
        cfg2 = plan_from_ctx(ctx2, causal=False, layout="contiguous")
        o = jax.jit(lambda q, k, v: distributed_attention(q, k, v, cfg=cfg2, ctx=ctx2))(q, k, v)
        o_ref, _ = ref.attention_ref(q, k, v)
        err = float(jnp.max(jnp.abs(o - o_ref)))
        results["ulysses"] = err
        assert err < 2e-5, ("ulysses", err)

        # the autotuned case must have persisted its plan; a fresh in-memory
        # state must round-trip it from disk
        import os

        from repro.core import dispatch as dsp

        plans = [fn for fn in os.listdir(cache_dir) if fn.endswith(".json")]
        assert plans, "autotuned run left no on-disk plan"
        dsp._MEM_CACHE.clear()
        cfg_at = plan_from_ctx(
            dataclasses.replace(base, attn_impl="mesh", attn_autotune=True),
            causal=True, layout="striped",
        )
        comm = CommModel(seq=S, hidden=H * D, n=n, kv_hidden=Hkv * D,
                         bytes_per_elem=4, batch=B)
        a, fwd, bwd = plan_schedules(cfg_at, comm)
        assert fwd.n == n and (bwd is None or bwd.n == n)
        results["plan_cache_files"] = len(plans)

    # unknown backend must fail loudly
    try:
        distributed_attention(q, k, v, cfg=AttentionPlanConfig(backend="nope", n=n))
        raise AssertionError("expected ValueError for unknown backend")
    except ValueError:
        pass
    return results


def check_pipeline_parallel():
    """GPipe pipeline over a 'pipe' axis == sequential layer application,
    forward AND gradients (autodiff through the ppermute schedule)."""
    import jax
    import jax.numpy as jnp

    from repro.parallel.pipeline import pipeline_apply, pipeline_stages

    L, D, M, mb = 8, 16, 6, 4
    n_stages = 4
    mesh = jax.make_mesh((n_stages,), ("pipe",))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {
        "w": jax.random.normal(ks[0], (L, D, D)) / D**0.5,
        "b": jax.random.normal(ks[1], (L, D)) * 0.1,
    }
    x = jax.random.normal(ks[2], (M, mb, D))

    def layer_fn(lp, h):
        return jnp.tanh(h @ lp["w"] + lp["b"])

    def run_pipe(params, x):
        staged = pipeline_stages(params, n_stages)
        return pipeline_apply(layer_fn, staged, x, mesh=mesh, n_stages=n_stages)

    def run_seq(params, x):
        def body(h, lp):
            return layer_fn(lp, h), None

        out, _ = jax.lax.scan(lambda h, lp: body(h, lp), x.reshape(M * mb, D), params)
        return out.reshape(M, mb, D)

    y_pipe = jax.jit(run_pipe)(params, x)
    y_seq = jax.jit(run_seq)(params, x)
    err_fwd = float(jnp.max(jnp.abs(y_pipe - y_seq)))
    assert err_fwd < 1e-5, err_fwd

    g_pipe = jax.jit(jax.grad(lambda p: jnp.sum(jnp.sin(run_pipe(p, x)))))(params)
    g_seq = jax.jit(jax.grad(lambda p: jnp.sum(jnp.sin(run_seq(p, x)))))(params)
    err_bwd = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_seq))
    )
    assert err_bwd < 1e-5, err_bwd
    return {"fwd_err": err_fwd, "bwd_err": err_bwd}


def check_collective_mode():
    """Algorithm-1 collective mode (2-D attention axes, native all-gathers)
    == single-device oracle AND == the ring-decomposed implementation."""
    import jax
    import jax.numpy as jnp
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
    from repro.core.mesh_attention_collective import mesh_attention_collective
    from repro.core.tiling import stripe_permutation, unstripe_permutation
    from repro.kernels import ref

    a, b = 2, 4
    n = a * b
    mesh2d = jax.make_mesh((a, b), ("aq", "akv"))
    mesh1d = jax.make_mesh((n,), ("sp",))
    B, S, H, Hkv, D = 2, n * 16, 4, 2, 16
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    results = {}
    for causal in (False, True):
        fcol = jax.jit(
            shard_map(
                lambda q, k, v, c=causal: mesh_attention_collective(
                    q, k, v, "aq", "akv", causal=c, block_q=16, block_kv=16
                ),
                mesh=mesh2d,
                in_specs=(P(None, ("aq", "akv")),) * 3,
                out_specs=P(None, ("aq", "akv")),
                check_vma=False,
            )
        )
        cfg = MeshAttentionConfig(axis_name="sp", n=n, a=a, causal=causal,
                                  block_q=16, block_kv=16)
        fring = jax.jit(
            shard_map(
                lambda q, k, v: mesh_attention(q, k, v, cfg),
                mesh=mesh1d,
                in_specs=(P(None, "sp"),) * 3,
                out_specs=P(None, "sp"),
                check_vma=False,
            )
        )
        if causal:
            perm = stripe_permutation(S, n)
            inv = unstripe_permutation(S, n)
            o_col = fcol(q[:, perm], k[:, perm], v[:, perm])[:, inv]
            o_ring = fring(q[:, perm], k[:, perm], v[:, perm])[:, inv]
            band = ref.causal_band()
        else:
            o_col, o_ring, band = fcol(q, k, v), fring(q, k, v), None
        o_ref, _ = ref.attention_ref(q, k, v, band=band)
        err_ref = float(jnp.max(jnp.abs(o_col - o_ref)))
        err_ring = float(jnp.max(jnp.abs(o_col - o_ring)))
        results[f"causal{causal}"] = {"vs_ref": err_ref, "vs_ring": err_ring}
        assert err_ref < 2e-5 and err_ring < 2e-5, results
    return results


def check_mla_latent_wire():
    """MLA latent-wire Mesh-Attention == the decompressed-KV standard path."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import make_batch
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx

    cfg = get_config("minicpm3-4b").reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                       block_q=8, block_kv=8)
    wire = dataclasses.replace(base, mla_latent_wire=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 32, 2, ctx=base)
    l1, _ = jax.jit(lambda p: tfm.forward(p, cfg, base, batch))(params)
    l2, _ = jax.jit(lambda p: tfm.forward(p, cfg, wire, batch))(params)
    err = float(jnp.max(jnp.abs(l1 - l2)))
    assert err < 2e-5, err
    return {"err": err}


def check_moe_ep_manual():
    """Manual-EP MoE (all_to_all dispatch inside shard_map) == single-device
    (capacity pinned high so per-shard vs global capacity cannot drop)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import make_batch
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0, mode="ep"))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), ctx=ctx)
    batch = make_batch(cfg, 32, 2, ctx=ctx)
    l_dist, _ = jax.jit(lambda p: tfm.forward(p, cfg, ctx, batch))(params)

    single = ParallelCtx()
    batch1 = make_batch(cfg, 32, 2, ctx=single)
    l_one, _ = jax.jit(lambda p: tfm.forward(p, cfg, single, batch1))(params)
    # undo the stripe permutation for comparison
    from repro.core.tiling import unstripe_permutation

    inv = unstripe_permutation(32, 4)
    err = float(jnp.max(jnp.abs(l_dist[:, inv] - l_one)))
    assert err < 3e-5, err
    return {"err": err}


def check_train_distributed():
    """End-to-end: FSDP+CP train on a (pod,data,model) fake mesh with int8
    cross-pod gradient compression, crash, elastic resume on a DIFFERENT
    mesh shape (resharding at restore), loss finite and decreasing."""
    import tempfile

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.parallel.compression import CompressionConfig
    from repro.parallel.context import ParallelCtx
    from repro.train import checkpoint as ckpt
    from repro.train.loop import TrainConfig, fit

    cfg = get_config("granite-8b").reduced()

    def ctx_pods():
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        return ParallelCtx(mesh=mesh, batch_axes=("pod", "data"), sp_axis="model",
                           block_q=8, block_kv=8)

    def ctx_flat():
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        return ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                           block_q=8, block_kv=8)

    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(steps=4, seq=32, batch=4, ckpt_dir=d, ckpt_every=2,
                           compression=CompressionConfig(kind="int8"))
        try:
            fit(cfg, ctx_pods(), tcfg, hooks={"fail_at": 2})
            raise AssertionError("expected injected failure")
        except RuntimeError:
            pass
        assert ckpt.latest_step(d) == 2
        # elastic resume on a different mesh (no pod axis -> no compression)
        tcfg2 = TrainConfig(steps=4, seq=32, batch=4, ckpt_dir=d, ckpt_every=2)
        out = fit(cfg, ctx_flat(), tcfg2)
        assert out["step"] == 4 and not out["interrupted"]
        hist = out["history"]
        assert all(np.isfinite(hist))
        # single-device reference: loss magnitudes line up (same data stream)
        ref = fit(cfg, ParallelCtx(), TrainConfig(steps=4, seq=32, batch=4))
        assert abs(hist[-1] - ref["history"][-1]) / ref["history"][-1] < 0.2
        return {"hist": hist, "ref": ref["history"]}


def check_serve_distributed():
    """Engine generation on a sequence-parallel mesh == single-device."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    prompts = (np.arange(16, dtype=np.int32).reshape(1, 16) * 7) % cfg.vocab_size

    single = ServeEngine(cfg, params, max_seq=64).generate(prompts, max_new_tokens=6)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)
    dist = ServeEngine(cfg, params, ctx=ctx, max_seq=64).generate(prompts, max_new_tokens=6)
    assert (single == dist).all(), (single, dist)
    return {"tokens": single.tolist()}


def check_mask_prune():
    """Mask-aware schedule pruning on an 8-fake-device (2, 4) mesh: a packed
    two-document workload (contiguous layout) prunes whole schedule blocks
    AND the comm steps that only fed them; the pruned schedule's forward and
    gradients are BITWISE identical to the unpruned schedule and match the
    dense masked oracle."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.masking import MaskSpec
    from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
    from repro.kernels import ref

    n = 4  # sequence-parallel width of the (2, 4) mesh's model axis
    mesh = jax.make_mesh((2, 4), ("data", "sp"))
    B, S, H, Hkv, D = 2, 64, 4, 2, 8
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(31), 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    doc_lens = (32, 32)
    spec = MaskSpec.document(doc_lens)
    seg = jnp.asarray(spec.segment_array(S))

    empty = spec.empty_blocks(2, 2, layout="contiguous", n=n, seq=S)
    assert empty, "expected prunable blocks for the aligned two-document mask"

    def build(cfg):
        f = shard_map(
            lambda q, k, v, s: mesh_attention(q, k, v, cfg, seg=s),
            mesh=mesh,
            in_specs=(P("data", "sp"),) * 3 + (P("sp"),),
            out_specs=P("data", "sp"),
            check_vma=False,
        )
        return f

    cfg_pruned = MeshAttentionConfig(
        axis_name="sp", n=n, a=2, mask=spec, layout="contiguous", block_q=8, block_kv=8
    )
    cfg_unpruned = dataclasses_replace_schedules(cfg_pruned)
    fwd_p, bwd_p = cfg_pruned.schedules(S)
    fwd_u, bwd_u = cfg_unpruned.schedules(S)
    assert len(fwd_p.comm_ops()) < len(fwd_u.comm_ops()), (
        fwd_p.comm_ops(), fwd_u.comm_ops(),
    )
    assert len(bwd_p.comm_ops()) < len(bwd_u.comm_ops())
    assert set(fwd_p.skip) == set(empty)

    f_p, f_u = build(cfg_pruned), build(cfg_unpruned)
    o_p = jax.jit(f_p)(q, k, v, seg)
    o_u = jax.jit(f_u)(q, k, v, seg)
    assert (np.asarray(o_p) == np.asarray(o_u)).all(), "pruned fwd != unpruned bitwise"

    o_ref, _ = ref.attention_ref(q, k, v, band=ref.causal_band(), seg_q=seg, seg_kv=seg)
    err = float(jnp.max(jnp.abs(o_p - o_ref)))
    assert err < 2e-5, err

    def loss(f):
        return lambda q, k, v: jnp.sum(jnp.sin(f(q, k, v, seg)))

    g_p = jax.jit(jax.grad(loss(f_p), argnums=(0, 1, 2)))(q, k, v)
    g_u = jax.jit(jax.grad(loss(f_u), argnums=(0, 1, 2)))(q, k, v)
    for a_, b_ in zip(g_p, g_u):
        assert (np.asarray(a_) == np.asarray(b_)).all(), "pruned grad != unpruned bitwise"
    return {
        "fwd_err": err,
        "pruned_blocks": sorted(list(map(list, empty))),
        "fwd_comms_pruned": fwd_p.comm_ops(),
        "fwd_comms_unpruned": fwd_u.comm_ops(),
        "bwd_comms_pruned": bwd_p.comm_ops(),
        "bwd_comms_unpruned": bwd_u.comm_ops(),
    }


def dataclasses_replace_schedules(cfg):
    """The same config forced to run UNPRUNED (explicit full schedules)."""
    import dataclasses

    from repro.core import schedule as Sch

    return dataclasses.replace(
        cfg,
        fwd_schedule=Sch.greedy_forward_schedule(cfg.a, cfg.b),
        bwd_schedule=Sch.greedy_backward_schedule(cfg.a, cfg.b),
    )


def check_overlap_exact():
    """comm_overlap modes are BITWISE-equal transports: on the 8-fake-device
    (2, 4) mesh, serial vs overlap vs bidir produce identical forward outputs
    AND identical gradients — for the plain causal striped ring, for a
    mask-PRUNED contiguous document schedule (seg tuples on the wire,
    paper-wire odoq backward), and for the Algorithm-1 collective mode."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import schedule as Sch
    from repro.core.masking import MaskSpec
    from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention
    from repro.core.mesh_attention_collective import mesh_attention_collective

    n = 4
    mesh = jax.make_mesh((2, 4), ("data", "sp"))
    B, S, H, Hkv, D = 2, 64, 4, 2, 8
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(57), 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    spec = MaskSpec.document((32, 32))
    seg = jnp.asarray(spec.segment_array(S))

    cases = {
        "causal_striped": (
            MeshAttentionConfig(axis_name="sp", n=n, a=2, causal=True,
                                layout="striped", block_q=8, block_kv=8),
            None,
        ),
        "doc_pruned_odoq": (
            MeshAttentionConfig(axis_name="sp", n=n, a=2, mask=spec,
                                layout="contiguous", bwd_wire="odoq",
                                block_q=8, block_kv=8),
            seg,
        ),
    }
    # the pruned case must actually exercise a pruned schedule
    fwd_sched, _ = cases["doc_pruned_odoq"][0].schedules(S)
    assert fwd_sched.skip, "document mask should prune blocks"

    detail = {}
    for name, (cfg, seg_in) in cases.items():
        outs, grads = {}, {}
        for mode in Sch.COMM_OVERLAP_MODES:
            c = dataclasses.replace(cfg, comm_overlap=mode)
            if seg_in is None:
                f = shard_map(
                    lambda q, k, v, c=c: mesh_attention(q, k, v, c),
                    mesh=mesh, in_specs=(P("data", "sp"),) * 3,
                    out_specs=P("data", "sp"), check_vma=False,
                )
                outs[mode] = jax.jit(f)(q, k, v)
                loss = lambda q, k, v, f=f: jnp.sum(jnp.sin(f(q, k, v)))
                grads[mode] = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
            else:
                f = shard_map(
                    lambda q, k, v, s, c=c: mesh_attention(q, k, v, c, seg=s),
                    mesh=mesh, in_specs=(P("data", "sp"),) * 3 + (P("sp"),),
                    out_specs=P("data", "sp"), check_vma=False,
                )
                outs[mode] = jax.jit(f)(q, k, v, seg_in)
                loss = lambda q, k, v, f=f: jnp.sum(jnp.sin(f(q, k, v, seg_in)))
                grads[mode] = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
        for mode in ("overlap", "bidir"):
            assert (np.asarray(outs[mode]) == np.asarray(outs["serial"])).all(), (
                f"{name}: {mode} fwd != serial bitwise"
            )
            for g_m, g_s in zip(grads[mode], grads["serial"]):
                assert (np.asarray(g_m) == np.asarray(g_s)).all(), (
                    f"{name}: {mode} grad != serial bitwise"
                )
        detail[name] = {"modes": list(Sch.COMM_OVERLAP_MODES), "bitwise": True}

    # Algorithm-1 collective mode: the knob maps onto the group all-gathers
    mesh2d = jax.make_mesh((2, 4), ("aq", "akv"))
    col_outs = {}
    for mode in Sch.COMM_OVERLAP_MODES:
        fcol = shard_map(
            lambda q, k, v, m=mode: mesh_attention_collective(
                q, k, v, "aq", "akv", causal=True, block_q=8, block_kv=8,
                comm_overlap=m,
            ),
            mesh=mesh2d, in_specs=(P(None, ("aq", "akv")),) * 3,
            out_specs=P(None, ("aq", "akv")), check_vma=False,
        )
        col_outs[mode] = jax.jit(fcol)(q, k, v)
    for mode in ("overlap", "bidir"):
        assert (np.asarray(col_outs[mode]) == np.asarray(col_outs["serial"])).all(), (
            f"collective: {mode} != serial bitwise"
        )
    detail["collective"] = {"modes": list(Sch.COMM_OVERLAP_MODES), "bitwise": True}
    return detail


def check_packed_prefill():
    """Packed serve prefill on a (2, 4) mesh: several same-tick prompts share
    ONE prefill row under a document mask, each document's K/V scattered into
    its own slot — and every request's tokens equal sequential per-request
    generation exactly."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(9)
    prompts = [
        rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln in (16, 8, 8)
    ]

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)
    eng = ServeEngine(cfg, params, ctx=ctx, max_seq=128, num_slots=3)
    rids = [eng.submit(p, max_new_tokens=5, arrival_tick=0) for p in prompts]
    finished = eng.run()
    # all three prompts went through a single packed (bucket=32, k=3) trace
    assert eng.prefill_trace_counts == {(32, 3): 1}, eng.prefill_trace_counts

    seq_eng = ServeEngine(cfg, params, max_seq=128, num_slots=1)
    tokens = {}
    for rid, p in zip(rids, prompts):
        ref_out = seq_eng.generate(p[None, :], max_new_tokens=5)
        got = finished[rid].generated
        assert got == ref_out[0].tolist(), (rid, got, ref_out[0].tolist())
        tokens[rid] = got
    return {"tokens": tokens}


def check_paged_serve():
    """Paged KV cache on a (2, 4) mesh: the paged engine (page pool + block
    tables + refcounted allocator) must be token-for-token identical to the
    dense engine on the mixed-length streaming trace, and a pair of requests
    sharing a 32-token prefix must allocate strictly fewer pages than an
    unshared pair while still matching the dense engine exactly.

    A second paged run forces ``decode_kernel="native"`` — the split-K kernel
    (kernels/paged_decode.py: block table read in-kernel, no gather
    intermediate; interpret-mode Pallas on these CPU devices) — and must
    produce the same tokens, so native == gather == dense on the live serve
    trace.  The device block-table upload count must stay version-gated."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    trace = [(16, 0), (32, 1), (64, 2), (16, 4)]
    prompts = [
        rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln, _ in trace
    ]
    new_tokens = 6

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)

    def run_engine(prompt_list, arrivals, **kw):
        eng = ServeEngine(cfg, params, ctx=ctx, max_seq=128, num_slots=3, **kw)
        rids = [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=t)
            for p, t in zip(prompt_list, arrivals)
        ]
        fin = eng.run()
        return [fin[r].generated for r in rids], eng

    arrivals = [t for _, t in trace]
    dense_toks, _ = run_engine(prompts, arrivals)
    # n=4, page_size=4 -> 16-token chunks; 8 logical pages cover max_seq=128
    # ("auto" resolves to the gather oracle on CPU: Pallas is off-policy here)
    paged_toks, paged_eng = run_engine(prompts, arrivals, paged=True, page_size=4)
    assert paged_toks == dense_toks, (paged_toks, dense_toks)
    assert paged_eng.decode_trace_count == 1, paged_eng.decode_trace_count
    assert paged_eng.allocator.pages_in_use == 0  # every retirement freed
    # the NATIVE split-K kernel (forced; interpret-mode Pallas on CPU) must
    # reproduce the trace token-for-token on the (2, 4) mesh
    native_toks, _ = run_engine(
        prompts, arrivals, paged=True, page_size=4, decode_kernel="native"
    )
    assert native_toks == dense_toks, (native_toks, dense_toks)
    # block-table uploads are version-gated (bounded by allocator mutations,
    # not by sync calls; tests/test_paged_decode.py pins the strict in-page
    # property with a controlled page size)
    assert 0 < paged_eng.bt_uploads <= paged_eng.allocator.version, (
        paged_eng.bt_uploads, paged_eng.allocator.version,
    )

    # prefix sharing: two 48-token prompts with a common 32-token prefix
    # (= 2 shared chunks) vs two unrelated 48-token prompts
    prefix = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    shared_pair = [
        np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)])
        for _ in range(2)
    ]
    unshared_pair = [
        rng.integers(0, cfg.vocab_size, (48,), dtype=np.int32) for _ in range(2)
    ]
    dense_sh, _ = run_engine(shared_pair, [0, 0])
    paged_sh, eng_sh = run_engine(shared_pair, [0, 0], paged=True, page_size=4)
    _, eng_un = run_engine(unshared_pair, [0, 0], paged=True, page_size=4)
    assert paged_sh == dense_sh, (paged_sh, dense_sh)
    st_sh, st_un = eng_sh.allocator.stats(), eng_un.allocator.stats()
    assert st_sh["shared_hits"] == 2, st_sh
    assert st_sh["fresh_allocs"] < st_un["fresh_allocs"], (st_sh, st_un)
    return {
        "tokens": {i: t for i, t in enumerate(paged_toks)},
        "native_equals_gather_equals_dense": True,
        "bt_uploads": paged_eng.bt_uploads,
        "ticks": paged_eng._tick,
        "shared_stats": st_sh,
        "unshared_stats": st_un,
    }


def check_continuous_prefill():
    """Continuous (chunked, budgeted) prefill on a (2, 4) mesh: an engine
    ingesting prompts in 16-token chunks under a 24-token/tick budget must be
    token-for-token identical to the one-shot engine AND to sequential
    single-device generation — dense and paged (prefix-shared pages
    included) — while tracing exactly one [slots, chunk] chunk step and one
    decode step.  This is the acceptance gate for the chunked-prefill cache
    scatter, the banded multi-row chunk attention, and the budget scheduler
    composing with the striped sequence-parallel decode stack."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    trace = [(16, 0), (32, 1), (64, 2), (16, 4)]
    prompts = [
        rng.integers(0, cfg.vocab_size, (ln,), dtype=np.int32) for ln, _ in trace
    ]
    arrivals = [t for _, t in trace]
    new_tokens = 6

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)

    def run_engine(prompt_list, arrive, **kw):
        serve = ServeConfig(max_seq=128, num_slots=3, **kw)
        eng = ServeEngine(cfg, params, ctx=ctx, serve=serve)
        rids = [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=t)
            for p, t in zip(prompt_list, arrive)
        ]
        fin = eng.run()
        return [fin[r].generated for r in rids], eng

    dense_toks, _ = run_engine(prompts, arrivals)
    chunk_toks, chunk_eng = run_engine(
        prompts, arrivals, prefill_chunk=16, tick_token_budget=24
    )
    assert chunk_toks == dense_toks, (chunk_toks, dense_toks)
    assert chunk_eng.chunk_trace_count == 1, chunk_eng.chunk_trace_count
    assert chunk_eng.decode_trace_count == 1, chunk_eng.decode_trace_count
    stats = chunk_eng.tick_stats()
    assert sum(stats["prefill_tokens"]) == sum(ln for ln, _ in trace)
    assert max(stats["prefill_tokens"]) <= 24, stats["prefill_tokens"]

    # sequential single-device oracle
    oracle = ServeEngine(cfg, params, serve=ServeConfig(max_seq=128, num_slots=1))
    for toks, p in zip(chunk_toks, prompts):
        ref_out = oracle.generate(p[None, :], max_new_tokens=new_tokens)
        assert toks == ref_out[0].tolist(), (toks, ref_out[0].tolist())

    # paged + prefix sharing under chunked ingestion (same-tick admissions:
    # the sharer's credit is capped at the mid-prefill donor's watermark)
    prefix = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    shared_pair = [
        np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)])
        for _ in range(2)
    ]
    paged_toks, paged_eng = run_engine(
        prompts, arrivals, paged=True, page_size=4,
        prefill_chunk=16, tick_token_budget=24,
    )
    assert paged_toks == dense_toks, (paged_toks, dense_toks)
    assert paged_eng.allocator.pages_in_use == 0
    dense_sh, _ = run_engine(shared_pair, [0, 0])
    paged_sh, eng_sh = run_engine(
        shared_pair, [0, 0], paged=True, page_size=4,
        prefill_chunk=16, tick_token_budget=24,
    )
    assert paged_sh == dense_sh, (paged_sh, dense_sh)
    assert eng_sh.allocator.stats()["shared_hits"] == 2, eng_sh.allocator.stats()
    return {
        "tokens": {i: t for i, t in enumerate(chunk_toks)},
        "chunk_launches": chunk_eng.chunk_launches,
        "tick_prefill_tokens": stats["prefill_tokens"],
        "tick_decode_tokens": stats["decode_tokens"],
        "paged_equals_dense": True,
        "shared_stats": eng_sh.allocator.stats(),
    }


def check_spec_decode():
    """Speculative multi-token decode on a (2, 4) mesh: an engine verifying
    prompt-lookup drafts through the banded [slots, spec_k] chunk launch
    must be token-for-token identical to the vanilla one-token-per-tick
    engine AND to sequential single-device generation — dense and paged
    (page-level rollback included, pool draining to zero) — while tracing
    exactly one verify step.  This is the acceptance gate for the
    speculative verify/commit path composing with the striped
    sequence-parallel decode stack and the refcounted page pool."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    # repetitive prompts drive acceptance through the drafting path; the
    # random prompt keeps rejection + fallback ticks in the same run
    prompts = [
        np.tile(np.array([7, 11, 13, 7], np.int32), 6),
        rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32),
        np.full((16,), 5, np.int32),
    ]
    arrivals = [0, 1, 2]
    new_tokens = 12

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)

    def run_engine(**kw):
        serve = ServeConfig(max_seq=128, num_slots=3, **kw)
        eng = ServeEngine(cfg, params, ctx=ctx, serve=serve)
        rids = [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=t)
            for p, t in zip(prompts, arrivals)
        ]
        fin = eng.run()
        return [fin[r].generated for r in rids], eng

    vanilla_toks, _ = run_engine()
    spec_toks, spec_eng = run_engine(spec_k=4, spec_max_misses=None)
    assert spec_toks == vanilla_toks, (spec_toks, vanilla_toks)
    assert spec_eng.verify_trace_count == 1, spec_eng.verify_trace_count
    assert spec_eng.spec_accepted > 0, "repetitive trace drove no accepts"

    paged_toks, paged_eng = run_engine(
        spec_k=4, spec_max_misses=None, paged=True, page_size=4
    )
    assert paged_toks == vanilla_toks, (paged_toks, vanilla_toks)
    assert paged_eng.allocator.pages_in_use == 0
    stats = paged_eng.allocator.stats()

    # sequential single-device oracle
    oracle = ServeEngine(cfg, params, serve=ServeConfig(max_seq=128, num_slots=1))
    for toks, p in zip(spec_toks, prompts):
        ref_out = oracle.generate(p[None, :], max_new_tokens=new_tokens)
        assert toks == ref_out[0].tolist(), (toks, ref_out[0].tolist())

    return {
        "tokens": {i: t for i, t in enumerate(spec_toks)},
        "verify_launches": spec_eng.verify_launches,
        "spec_proposed": spec_eng.spec_proposed,
        "spec_accepted": spec_eng.spec_accepted,
        "paged_equals_dense": True,
        "spec_rolled_back_pages": stats["spec_rolled_back_pages"],
    }


def check_quant_kv():
    """Quantized (int8) paged KV pool on a (2, 4) mesh: an engine storing
    pages as int8 codes + per-(token, kv-head) f32 scales replays the mixed
    streaming trace — prefix sharing, continuous prefill (chunk=16,
    budget=24) and speculative verify (spec_k=4) all in one run — and must
    track the fp paged engine with every per-token logit inside the
    documented quantization error bound (greedy flips allowed only on
    near-ties the bound itself explains), while pages AND scale-table
    entries drain back to zero.  This is the
    acceptance gate for quantize-on-write across all cache update paths
    (chunked prefill scatter, decode append, verify/rollback) composing
    with in-kernel dequant and the refcounted scale side table."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    # repetitive prompts drive speculative accepts; the random prompt keeps
    # rejection/rollback ticks in the run; the shared prefix pair exercises
    # CoW scale copies under chunked ingestion
    prompts = [
        np.tile(np.array([7, 11, 13, 7], np.int32), 6),
        rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32),
    ]
    prefix = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
    prompts += [
        np.concatenate([prefix, np.full((8,), 5, np.int32)]),
        np.concatenate([prefix, np.full((8,), 9, np.int32)]),
    ]
    arrivals = [0, 1, 2, 2]
    new_tokens = 12
    # documented elementwise cache bound is amax/254 (int8); after one
    # attention layer + lm head on the reduced config the empirical logit
    # error is ~0.04, so 0.25 is a conservative end-to-end ceiling
    logit_bound = 0.25

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)

    def run_engine(kv_dtype):
        serve = ServeConfig(
            max_seq=128, num_slots=3, paged=True, page_size=4,
            prefill_chunk=16, tick_token_budget=24,
            spec_k=4, spec_max_misses=None, kv_dtype=kv_dtype,
        )
        eng = ServeEngine(cfg, params, ctx=ctx, serve=serve)
        eng.capture_logits = True
        rids = [
            eng.submit(p, max_new_tokens=new_tokens, arrival_tick=t)
            for p, t in zip(prompts, arrivals)
        ]
        fin = eng.run()
        return [fin[r].generated for r in rids], [
            eng.debug_logits[r] for r in rids
        ], eng

    fp_toks, fp_logits, fp_eng = run_engine("fp")
    q_toks, q_logits, q_eng = run_engine("int8")
    assert fp_eng.allocator.scale_entries_in_use == 0  # fp pool has no scales

    # per-token logit comparison is meaningful only while both engines have
    # generated the same context.  Greedy argmax may legitimately flip on a
    # quantization-scale near-tie; when it does, both engines must score the
    # two candidates within 2x the elementwise bound, and the streams are
    # incomparable (different contexts) from there on.
    max_err = 0.0
    matched = 0
    total = 0
    flips = 0
    for rid, (tf, tq) in enumerate(zip(fp_toks, q_toks)):
        rows_fp, rows_q = fp_logits[rid], q_logits[rid]
        assert len(rows_fp) == len(tf), (len(rows_fp), len(tf))
        assert len(rows_q) == len(tq), (len(rows_q), len(tq))
        total += len(tf)
        for i, (a, b) in enumerate(zip(tf, tq)):
            lf = rows_fp[i].astype(np.float64)
            lq = rows_q[i].astype(np.float64)
            err = float(np.max(np.abs(lf - lq)))
            max_err = max(max_err, err)
            assert err <= logit_bound, (rid, i, err, logit_bound)
            if a != b:
                flips += 1
                assert lf[a] - lf[b] <= 2 * logit_bound, (rid, i, a, b, lf[a] - lf[b])
                assert lq[b] - lq[a] <= 2 * logit_bound, (rid, i, a, b, lq[b] - lq[a])
                break
            matched += 1
    assert matched >= total // 2, (matched, total)

    # the quantized pool and its scale side table drain together
    assert q_eng.allocator.pages_in_use == 0, q_eng.allocator.pages_in_use
    assert q_eng.allocator.scale_entries_in_use == 0
    stats = q_eng.allocator.stats()
    assert q_eng.allocator.quantized and stats["peak_in_use"] >= 1, stats
    assert q_eng.spec_accepted > 0, "repetitive trace drove no accepts"
    assert stats["shared_hits"] >= 1, stats

    kv = q_eng.kv_cache_stats()
    # storage: int8 codes (1B) + 2 * Hkv f32 scales per token vs 2 * Hkv * D
    # fp entries — the modeled per-token HBM footprint must stay under 0.55x
    hd = cfg.hd
    fp_tok_bytes = 2 * hd * fp_eng._cache["k"].dtype.itemsize
    q_tok_bytes = 2 * hd * 1 + 2 * 4
    ratio = q_tok_bytes / fp_tok_bytes
    assert ratio <= 0.55, ratio

    return {
        "tokens": {i: t for i, t in enumerate(q_toks)},
        "tokens_matched": matched,
        "tokens_total": total,
        "near_tie_flips": flips,
        "max_logit_err": max_err,
        "logit_bound": logit_bound,
        "bytes_per_token_ratio": ratio,
        "peak_pages_in_use": stats["peak_in_use"],
        "shared_hits": stats["shared_hits"],
        "spec_accepted": q_eng.spec_accepted,
        "dequant_fallbacks": kv["dequant_fallbacks"],
    }


def check_chaos_serve():
    """Fault-tolerant serving on a (2, 4) mesh: an OVERSUBSCRIBED engine
    (oversubscribe=2.0 over a 7-page pool) under real mid-decode pool
    exhaustion must preempt-and-recompute and still produce token streams
    IDENTICAL to the conservative (oversubscribe=1.0, ample pool) engine —
    prefix sharers included, whose committed pages are refcount-protected
    through a donor's preemption.  A chaos-injected NaN tick must retire
    exactly one request (status numeric_error) while every other stream is
    bitwise-unchanged, and the full seeded chaos trace (squeeze + NaN +
    dropped grants) must replay deterministically with pages AND int8 scale
    entries draining to zero.  This is the acceptance gate for ISSUE 10's
    preempt/recompute, NaN guard, and chaos harness composing with the
    striped sequence-parallel decode stack."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.parallel.context import ParallelCtx
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine
    from repro.testing.chaos import ChaosConfig, ChaosInjector

    cfg = get_config("granite-8b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    # page_size=4 on 4 sp shards -> 16 tokens/page.  32-token prompts + 12
    # new tokens = 3 lifetime pages each; three requests need 9 pages but
    # the oversubscribed pool has 7 -> guaranteed mid-decode exhaustion.
    prefix = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    prompts = [
        rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32),
        np.concatenate([prefix[:16], rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)]),
        np.concatenate([prefix[:16], rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)]),
    ]
    new_tokens = 12

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                      block_q=8, block_kv=8)

    def run_engine(chaos=None, **kw):
        serve = ServeConfig(max_seq=128, num_slots=3, paged=True, page_size=4,
                            prefill_chunk=16, **kw)
        eng = ServeEngine(cfg, params, ctx=ctx, serve=serve, chaos=chaos)
        rids = [eng.submit(p, max_new_tokens=new_tokens) for p in prompts]
        fin = eng.run()
        return [fin[r] for r in rids], eng

    # 1. preempt-and-recompute == uninterrupted, prefix sharers intact
    ref, _ = run_engine(num_pages=12)
    got, eng = run_engine(num_pages=7, oversubscribe=2.0, health_every=1)
    for r, g in zip(ref, got):
        assert g.status == "ok", g.status
        assert g.generated == r.generated, (r.generated, g.generated)
    assert eng.preemptions > 0, "7-page pool drove no preemption"
    assert eng.allocator.pages_in_use == 0
    assert eng.allocator.stats()["shared_hits"] >= 1

    # 2. one injected NaN retires exactly one request; the other slots'
    # streams are bitwise-unchanged vs the fault-free int8 run
    clean, _ = run_engine(num_pages=12, kv_dtype="int8")
    nan_cfg = ChaosConfig(seed=11, ticks=10, squeezes=0, nan_ticks=1,
                          drop_ticks=0)
    hurt, nan_eng = run_engine(num_pages=12, kv_dtype="int8",
                               chaos=ChaosInjector(nan_cfg))
    statuses = [g.status for g in hurt]
    assert statuses.count("numeric_error") == 1, statuses
    assert nan_eng.numeric_errors == 1
    survivors = 0
    for c, h in zip(clean, hurt):
        if h.status == "ok":
            assert h.generated == c.generated, (c.generated, h.generated)
            survivors += 1
    assert survivors == len(prompts) - 1
    assert nan_eng.allocator.pages_in_use == 0
    assert nan_eng.allocator.scale_entries_in_use == 0

    # 3. the full fault trace replays deterministically, pool + scales drain
    full_cfg = ChaosConfig(seed=5, ticks=14, squeezes=2, squeeze_frac=0.5,
                           squeeze_hold=3, nan_ticks=1, drop_ticks=1)
    runs = []
    for _ in range(2):
        inj = ChaosInjector(full_cfg)
        res, e = run_engine(num_pages=7, oversubscribe=2.0, kv_dtype="int8",
                            health_every=2, chaos=inj)
        assert e.allocator.pages_in_use == 0
        assert e.allocator.scale_entries_in_use == 0
        e.health()
        runs.append((inj.events, [(g.status, g.generated) for g in res], e))
    assert runs[0][0] == runs[1][0], (runs[0][0], runs[1][0])
    assert runs[0][1] == runs[1][1], (runs[0][1], runs[1][1])
    chaos_eng = runs[0][2]
    # ok streams match the fault-free engine of the SAME kv_dtype (int8
    # near-ties make fp an invalid oracle here)
    for c, (status, gen) in zip(clean, runs[0][1]):
        if status == "ok":
            assert gen == c.generated, (c.generated, gen)

    return {
        "tokens": {i: g.generated for i, g in enumerate(got)},
        "preemptions": eng.preemptions,
        "recompute_tokens": eng.recompute_tokens,
        "nan_statuses": statuses,
        "chaos_events": runs[0][0],
        "chaos_statuses": [s for s, _ in runs[0][1]],
        "chaos_preemptions": chaos_eng.preemptions,
        "chaos_dropped_grants": chaos_eng.chaos_dropped_grants,
        "deterministic_replay": True,
    }


CHECKS = {
    "mesh_fwd": check_mesh_attention_forward,
    "mesh_bwd": check_mesh_attention_backward,
    "mesh_pallas": check_mesh_attention_pallas_interpret,
    "ring_eq": check_ring_equals_mesh_a1,
    "ulysses": check_ulysses,
    "decode": check_striped_decode,
    "decode_edge": check_decode_edge,
    "train_dist": check_train_distributed,
    "serve_dist": check_serve_distributed,
    "serve_stream": check_serve_stream,
    "mla_wire": check_mla_latent_wire,
    "moe_ep": check_moe_ep_manual,
    "collective_mode": check_collective_mode,
    "pipeline": check_pipeline_parallel,
    "dispatch": check_dispatch_seam,
    "mask_prune": check_mask_prune,
    "overlap_exact": check_overlap_exact,
    "packed_prefill": check_packed_prefill,
    "paged_serve": check_paged_serve,
    "continuous_prefill": check_continuous_prefill,
    "spec_decode": check_spec_decode,
    "quant_kv": check_quant_kv,
    "chaos_serve": check_chaos_serve,
}


def main(argv):
    names = argv or list(CHECKS)
    report = {}
    failed = False
    for name in names:
        try:
            report[name] = {"ok": True, "detail": CHECKS[name]()}
        except Exception as e:  # noqa: BLE001
            failed = True
            report[name] = {"ok": False, "error": f"{e}", "tb": traceback.format_exc()}
    print(json.dumps(report))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
