"""Strategy combinators for the hypothesis fallback shim.

Only the API surface the repo's tests use: integers, floats, booleans,
sampled_from, just, tuples, builds, lists, plus .map/.flatmap/.filter.
Every strategy carries a deterministic ``minimal()`` (lower-bound) example
alongside the seeded ``draw(rng)``.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

__all__ = [
    "SearchStrategy",
    "integers",
    "floats",
    "booleans",
    "sampled_from",
    "just",
    "tuples",
    "builds",
    "lists",
]


class SearchStrategy:
    def __init__(self, draw: Callable, minimal: Callable[[], Any]):
        self._draw = draw
        self._minimal = minimal

    def draw(self, rng):
        return self._draw(rng)

    def minimal(self):
        return self._minimal()

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)), lambda: f(self._minimal()))

    def flatmap(self, f):
        return SearchStrategy(
            lambda rng: f(self._draw(rng)).draw(rng),
            lambda: f(self._minimal()).minimal(),
        )

    def filter(self, pred):
        def draw(rng):
            for _ in range(10_000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise RuntimeError("hypothesis-shim: filter predicate too strict")

        def minimal():
            v = self._minimal()
            if pred(v):
                return v
            import random

            return draw(random.Random(0))

        return SearchStrategy(draw, minimal)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value), lambda: min_value)


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value), lambda: min_value)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)), lambda: False)


def sampled_from(elements: Sequence) -> SearchStrategy:
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: rng.choice(elems), lambda: elems[0])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, lambda: value)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.draw(rng) for s in strategies),
        lambda: tuple(s.minimal() for s in strategies),
    )


def _resolve(v, rng):
    return v.draw(rng) if isinstance(v, SearchStrategy) else v


def _resolve_min(v):
    return v.minimal() if isinstance(v, SearchStrategy) else v


def builds(target: Callable, *args, **kwargs) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: target(
            *(_resolve(a, rng) for a in args),
            **{k: _resolve(v, rng) for k, v in kwargs.items()},
        ),
        lambda: target(
            *(_resolve_min(a) for a in args),
            **{k: _resolve_min(v) for k, v in kwargs.items()},
        ),
    )


def lists(elements: SearchStrategy, *, min_size: int = 0, max_size: int = 10) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: [elements.draw(rng) for _ in range(rng.randint(min_size, max_size))],
        lambda: [elements.minimal() for _ in range(min_size)],
    )
