"""Dependency-free fallback for the slice of `hypothesis` this repo uses.

The property-based test modules import ``given``/``settings``/``strategies``.
When the real hypothesis package is installed (CI installs the pin from
requirements-dev.txt) it is always preferred; this shim exists so the tier-1
suite still collects and runs in hermetic containers where ``pip install``
is unavailable.  ``tests/conftest.py`` calls :func:`install` only when
``import hypothesis`` fails.

Semantics: each ``@given`` test runs a deterministic sweep — one "minimal"
example (every strategy at its lower bound, hypothesis-style boundary
probing) followed by pseudo-random examples from a seed derived from the
test name, up to ``settings(max_examples=...)``.  No shrinking; the failing
example is attached to the exception notes instead.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

from repro.testing.hypothesis_shim import strategies

__all__ = ["given", "settings", "strategies", "install", "__version__"]

__version__ = "0.0.0+repro-shim"

_DEFAULT_MAX_EXAMPLES = 50


def settings(**kw):
    """Decorator recording run options; composes with @given in either order."""

    def decorate(fn):
        fn._shim_settings = dict(kw)
        return fn

    return decorate


# make bare uses like ``settings.default`` not explode if they ever appear
settings.default = {"max_examples": _DEFAULT_MAX_EXAMPLES}


def _bind_names(fn, n_positional, kw_strategies):
    """Right-align positional @given strategies to fn's parameters, the way
    hypothesis does (leading params may be filled by pytest fixtures or
    parametrize)."""
    params = [
        p.name
        for p in inspect.signature(fn).parameters.values()
        if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)
    ]
    tail = [p for p in params if p not in kw_strategies]
    return tail[len(tail) - n_positional :]


def given(*pos_strategies, **kw_strategies):
    def decorate(fn):
        names = _bind_names(fn, len(pos_strategies), kw_strategies)
        all_strats = dict(zip(names, pos_strategies))
        all_strats.update(kw_strategies)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = (
                getattr(wrapper, "_shim_settings", None)
                or getattr(fn, "_shim_settings", None)
                or {}
            )
            max_examples = int(conf.get("max_examples", _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(max(1, max_examples)):
                if i == 0:
                    drawn = {k: s.minimal() for k, s in all_strats.items()}
                else:
                    drawn = {k: s.draw(rng) for k, s in all_strats.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    note = f"[hypothesis-shim] falsifying example #{i}: {drawn!r}"
                    if hasattr(e, "add_note"):
                        e.add_note(note)
                    raise
            return None

        # hide the drawn parameters from pytest's fixture resolution (real
        # hypothesis does the same signature rewrite)
        sig = inspect.signature(fn)
        remaining = [p for p in sig.parameters.values() if p.name not in all_strats]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        del wrapper.__wrapped__  # or inspect follows it back to the full sig
        # marker some tooling sniffs for (anyio's pytest plugin reads
        # ``obj.hypothesis.inner_test``)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


def install():
    """Register this package as the ``hypothesis`` module family."""
    me = sys.modules[__name__]
    sys.modules.setdefault("hypothesis", me)
    sys.modules.setdefault("hypothesis.strategies", strategies)
