"""Deterministic fault injection for the serve engine (ISSUE 10).

A ``ChaosInjector`` drives three failure modes through the engine's REAL
code paths — no mocking, no monkeypatching:

* **pool squeezes** — ``PageAllocator.seize_pages`` removes a fraction of
  the free list for a few ticks (a co-tenant, fragmentation, a shrunken
  pool), forcing mid-decode ``PoolExhausted`` and therefore the
  preempt-and-recompute path;
* **NaN ticks** — ``engine.poison_slot_cache`` writes NaN into one active
  slot's resident K (the f32 scale table on quantized pools), so the next
  attention pass produces non-finite logits and the in-graph NaN guard
  must retire exactly that slot;
* **dropped grants** — ``drop_grants(tick)`` makes the engine discard a
  tick's continuous-prefill chunk plan, exercising the
  progress-resumes-next-tick guarantee.

Everything is precomputed from ``np.random.default_rng(seed)`` at
construction: the same (seed, engine, workload) triple replays the same
fault trace event-for-event, which is what the CI ``chaos-smoke`` job and
``dist_check chaos_serve`` assert.  The injector keeps a human-readable
``events`` log; two runs are *deterministic* iff their logs and outputs
match exactly.

Usage::

    chaos = ChaosInjector(ChaosConfig(seed=7, ticks=64, squeezes=2))
    eng = ServeEngine(cfg, params, serve=serve_cfg, chaos=chaos)
    ...submit / run...
    assert chaos.events == replay.events  # determinism gate
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["ChaosConfig", "ChaosInjector"]


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault-trace shape.  Event ticks are drawn without replacement
    from ``range(1, ticks)`` (tick 0 is left clean so at least one admission
    happens before the first fault)."""

    seed: int = 0
    ticks: int = 64  # horizon the event schedule is drawn over
    squeezes: int = 2  # free-list squeeze events
    squeeze_frac: float = 0.5  # fraction of currently-free pages seized
    squeeze_hold: int = 4  # ticks a squeeze holds before pages restore
    nan_ticks: int = 1  # ticks that poison one active slot's cache
    drop_ticks: int = 1  # ticks whose chunk grants are discarded

    def __post_init__(self):
        if self.ticks < 2:
            raise ValueError(f"ticks must be >= 2, got {self.ticks}")
        if not (0.0 <= self.squeeze_frac <= 1.0):
            raise ValueError(
                f"squeeze_frac must be in [0, 1], got {self.squeeze_frac}"
            )
        if self.squeeze_hold < 1:
            raise ValueError(
                f"squeeze_hold must be >= 1, got {self.squeeze_hold}"
            )
        for name in ("squeezes", "nan_ticks", "drop_ticks"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")


class ChaosInjector:
    """Replays the seeded fault schedule against a live engine.

    The engine calls ``on_tick(engine)`` at the top of every ``step()`` and
    ``drop_grants(tick)`` before launching a chunk plan.  One injector
    belongs to ONE engine run; construct a fresh one (same config) to
    replay the identical trace."""

    def __init__(self, config: ChaosConfig):
        self.config = config
        rng = np.random.default_rng(config.seed)
        horizon = np.arange(1, config.ticks)
        n_events = config.squeezes + config.nan_ticks + config.drop_ticks
        if n_events > len(horizon):
            raise ValueError(
                f"{n_events} events do not fit in {len(horizon)} ticks"
            )
        # one draw without replacement, then split: event kinds never collide
        # on a tick, so the event ordering within a tick is never ambiguous
        picks = rng.choice(horizon, size=n_events, replace=False)
        self.squeeze_ticks = set(
            int(t) for t in picks[: config.squeezes]
        )
        self.nan_ticks = set(
            int(t)
            for t in picks[config.squeezes : config.squeezes + config.nan_ticks]
        )
        self.drop_ticks = set(
            int(t) for t in picks[config.squeezes + config.nan_ticks :]
        )
        # live state
        self._held: List[Tuple[int, List[int]]] = []  # (restore_tick, pids)
        self._nan_pending = 0  # scheduled poisons waiting for a victim
        # counters + replay log
        self.injected_squeezes = 0
        self.injected_nans = 0
        self.restored_squeezes = 0
        self.events: List[str] = []

    # -- engine hooks --------------------------------------------------------

    def on_tick(self, engine) -> None:
        """Apply this tick's faults.  Called at the top of ``step()``,
        before admission, so a squeeze constrains this tick's decisions."""
        tick = engine._tick
        # 1. restore squeezes whose hold expired (before any new seizure so
        # a restore and a squeeze on the same tick compose deterministically)
        still = []
        for restore_tick, pids in self._held:
            if tick >= restore_tick and engine.allocator is not None:
                engine.allocator.restore_pages(pids)
                self.restored_squeezes += 1
                self.events.append(f"t{tick}:restore:{len(pids)}")
            else:
                still.append((restore_tick, pids))
        self._held = still
        # 2. new squeeze: seize a fraction of whatever is free RIGHT NOW
        if tick in self.squeeze_ticks and engine.allocator is not None:
            free_now = len(engine.allocator._free)
            k = max(1, int(free_now * self.config.squeeze_frac)) if free_now else 0
            pids = engine.allocator.seize_pages(k)
            if pids:
                self._held.append((tick + self.config.squeeze_hold, pids))
                self.injected_squeezes += 1
                self.events.append(f"t{tick}:squeeze:{len(pids)}")
        # 3. NaN poison: deferred until a victim is actually decoding, so a
        # scheduled tick that lands mid-prefill still injects (next tick)
        if tick in self.nan_ticks:
            self._nan_pending += 1
        if self._nan_pending:
            victim = self._pick_nan_victim(engine)
            if victim is not None:
                engine.poison_slot_cache(victim)
                self._nan_pending -= 1
                self.injected_nans += 1
                self.events.append(f"t{tick}:nan:slot{victim}")

    def drop_grants(self, tick: int) -> bool:
        """True when this tick's chunk plan must be discarded (the engine
        counts the dropped grants)."""
        if tick in self.drop_ticks:
            self.events.append(f"t{tick}:drop_grants")
            return True
        return False

    # -- internals -----------------------------------------------------------

    @staticmethod
    def _pick_nan_victim(engine):
        """Smallest active slot that finished ingest and generated at least
        one token: it is mid-decode, so the poison provably hits a launch
        whose other rows must commit bitwise-unchanged."""
        for slot, req in enumerate(engine.scheduler.slots):
            if (
                req is not None
                and req.prefill_pos >= req.ingest_len
                and req.generated
            ):
                return slot
        return None

    def summary(self) -> dict:
        return {
            "seed": self.config.seed,
            "injected_squeezes": self.injected_squeezes,
            "restored_squeezes": self.restored_squeezes,
            "injected_nans": self.injected_nans,
            "events": list(self.events),
        }
