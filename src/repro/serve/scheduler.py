"""Continuous-batching scheduler: request queue over a fixed slot pool.

Pure-python bookkeeping (no jax): the engine owns the device arrays, this
module owns WHO occupies WHICH slot WHEN.  Lifecycle of a request:

    submit() -> queued -> admit() assigns a free slot (FIFO among arrived
    requests) -> prefill fills the slot row -> the slot decodes every tick ->
    retire() on EOS / max_new_tokens -> slot returns to the free pool.

Prompts are right-padded to a **bucket** length for prefill so the number of
jit traces is bounded by ``len(buckets)``, not by the mix of prompt lengths
(``exact=True`` disables padding for SSM/hybrid archs, whose recurrent state
has no pad-correction — there the trace count is bounded by the number of
distinct prompt lengths instead).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Request", "Scheduler", "default_buckets"]


def default_buckets(max_seq: int, n: int = 1, lo: int = 16) -> Tuple[int, ...]:
    """Power-of-two bucket ladder up to the cache capacity; every bucket is a
    multiple of the sequence-parallel size n (striping requirement)."""
    lo = max(lo, n)
    out = []
    b = 1
    while b < lo:
        b *= 2
    while b < max_seq:
        if b % max(n, 1) == 0:
            out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(dict.fromkeys(out))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S0] int32
    max_new_tokens: int
    arrival_tick: int = 0
    # filled in by the engine as the request progresses:
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    admit_tick: Optional[int] = None
    first_token_tick: Optional[int] = None
    finish_tick: Optional[int] = None

    @property
    def done(self) -> bool:
        return self.finish_tick is not None


class Scheduler:
    """Admission + slot assignment + retirement over ``num_slots`` slots."""

    def __init__(
        self,
        num_slots: int,
        buckets: Sequence[int],
        max_seq: int,
        *,
        exact: bool = False,
        multiple: int = 1,
        chunk: Optional[int] = None,
    ):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = num_slots
        self.multiple = max(1, multiple)  # sequence-parallel divisibility
        self.chunk = chunk  # SSD scan chunk (exact mode only)
        self.buckets = tuple(sorted(set(buckets)))
        if not self.buckets or self.buckets[-1] > max_seq:
            raise ValueError(f"buckets {buckets} must be non-empty and <= max_seq={max_seq}")
        self.max_seq = max_seq
        self.exact = exact
        self.slots: List[Optional[Request]] = [None] * num_slots
        self._queue: List[Request] = []
        self._next_rid = 0

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int, arrival_tick: int = 0) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) exceeds "
                f"cache capacity {self.max_seq}"
            )
        self.bucket_for(len(prompt))  # raise early on un-bucketable prompts
        req = Request(self._next_rid, prompt, max_new_tokens, arrival_tick)
        self._next_rid += 1
        self._queue.append(req)
        return req

    def bucket_for(self, length: int) -> int:
        """Smallest bucket >= length (or the exact length in exact mode)."""
        if length < 1 or length > self.max_seq:
            raise ValueError(f"prompt length {length} outside (0, {self.max_seq}]")
        if self.exact:
            # no padding available, so the prompt itself must satisfy the
            # sequence-parallel divisibility (hybrid archs still shard
            # attention prefill over the model axis)
            if length % self.multiple:
                raise ValueError(
                    f"exact prefill (SSM/hybrid archs) needs the prompt length to be "
                    f"a multiple of the sequence-parallel size {self.multiple}; got {length}"
                )
            local = length // self.multiple
            if self.chunk is not None and local > self.chunk and local % self.chunk:
                raise ValueError(
                    f"the SSD chunked scan needs the per-device prompt length "
                    f"({local}) to be <= or a multiple of the chunk ({self.chunk})"
                )
            return length
        for b in self.buckets:
            if b >= length:
                return b
        raise ValueError(f"prompt length {length} exceeds largest bucket {self.buckets[-1]}")
    def pack_groups(
        self, assigned: List[Tuple[int, "Request"]], *, pack_max: int = 4
    ) -> List[List[Tuple[int, "Request"]]]:
        """Group same-tick admissions into packed prefill rows.

        Greedy in admission order: a group closes when it reaches ``pack_max``
        documents or its summed prompt length would overflow the largest
        bucket.  Exact mode (SSM/hybrid) never packs — the recurrent state
        has no per-document reset.
        """
        if self.exact or pack_max <= 1:
            return [[x] for x in assigned]
        cap = self.buckets[-1]
        groups: List[List[Tuple[int, Request]]] = []
        cur: List[Tuple[int, Request]] = []
        cur_len = 0
        for slot, req in assigned:
            length = len(req.prompt)
            if cur and (len(cur) >= pack_max or cur_len + length > cap):
                groups.append(cur)
                cur, cur_len = [], 0
            cur.append((slot, req))
            cur_len += length
        if cur:
            groups.append(cur)
        return groups

    # -- per-tick operations ------------------------------------------------

    def admit(self, tick: int) -> List[Tuple[int, Request]]:
        """Assign arrived queued requests to free slots, FIFO.  Returns
        [(slot, request)] for the engine to prefill."""
        assigned = []
        for slot in range(self.num_slots):
            if self.slots[slot] is not None:
                continue
            req = next(
                (r for r in self._queue if r.arrival_tick <= tick), None
            )
            if req is None:
                break
            self._queue.remove(req)
            req.slot, req.admit_tick = slot, tick
            self.slots[slot] = req
            assigned.append((slot, req))
        return assigned

    def retire(self, slot: int, tick: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is already free")
        req.finish_tick = tick
        self.slots[slot] = None
        return req

    # -- introspection ------------------------------------------------------

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self.slots)
