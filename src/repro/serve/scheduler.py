"""Continuous-batching scheduler: request queue over a fixed slot pool.

Pure-python bookkeeping (no jax): the engine owns the device arrays, this
module owns WHO occupies WHICH slot WHEN.  Lifecycle of a request:

    submit() -> queued -> admit() assigns a free slot (FIFO among arrived
    requests) -> prefill fills the slot row -> the slot decodes every tick ->
    retire() on EOS / max_new_tokens -> slot returns to the free pool.

Prompts are right-padded to a **bucket** length for prefill so the number of
jit traces is bounded by ``len(buckets)``, not by the mix of prompt lengths
(``exact=True`` disables padding for SSM/hybrid archs, whose recurrent state
has no pad-correction — there the trace count is bounded by the number of
distinct prompt lengths instead).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Request", "RequestResult", "Scheduler", "default_buckets"]


def default_buckets(max_seq: int, n: int = 1, lo: int = 16) -> Tuple[int, ...]:
    """Power-of-two bucket ladder up to the cache capacity; every bucket is a
    multiple of the sequence-parallel size n (striping requirement)."""
    lo = max(lo, n)
    out = []
    b = 1
    while b < lo:
        b *= 2
    while b < max_seq:
        if b % max(n, 1) == 0:
            out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(dict.fromkeys(out))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S0] int32
    max_new_tokens: int
    arrival_tick: int = 0
    # lifecycle: finish by arrival + deadline_ticks or retire with partial
    # output (status "deadline"); higher priority admits first (FIFO ties)
    deadline_ticks: Optional[int] = None
    priority: int = 0
    # filled in by the engine as the request progresses:
    generated: List[int] = dataclasses.field(default_factory=list)
    token_ticks: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    admit_tick: Optional[int] = None
    first_token_tick: Optional[int] = None
    finish_tick: Optional[int] = None
    # terminal state: ok | cancelled | deadline | numeric_error | rejected
    status: str = "ok"
    # oversubscription: times this request was preempted mid-decode, and
    # tokens re-ingested through continuous prefill to restore its cache
    preemptions: int = 0
    recompute_tokens: int = 0
    # continuous prefill: how far into the CONTEXT the cache is, and how many
    # chunk launches it took (a one-shot prefill counts as one chunk).
    # ``ingest_len`` is the ingest TARGET, frozen at admission — it equals
    # ``context_len`` at that instant, but unlike ``context_len`` it does NOT
    # grow as decode appends tokens, so ``prefill_pos >= ingest_len`` stays
    # the "done prefilling, decodable" test for the slot's whole residency
    ingest_len: int = 0
    prefill_pos: int = 0
    chunks: int = 0
    first_chunk_tick: Optional[int] = None
    # speculative decode: draft tokens sent to verify / accepted for this
    # request (acceptance rate = accepted / proposed)
    spec_proposed: int = 0
    spec_accepted: int = 0

    @property
    def done(self) -> bool:
        return self.finish_tick is not None

    @property
    def context(self) -> np.ndarray:
        """What the cache must hold for this request to keep decoding:
        prompt + everything generated so far.  A preempted request re-queues
        and prefills its CONTEXT, so the resumed stream continues exactly
        where the uninterrupted one would."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)]
        )

    @property
    def context_len(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def remaining_new_tokens(self) -> int:
        return max(self.max_new_tokens - len(self.generated), 0)


@dataclasses.dataclass(frozen=True)
class RequestResult:
    """What the engine hands back for a finished request.

    The streaming surface (``submit()``/``run()``/``step()``) returns these
    instead of bare token arrays so callers stop recomputing latency from
    trace side-channels: per-token tick stamps, TTFT and the chunk count
    ride along.  ``generated`` (list view of ``tokens``) and the tick fields
    keep the pre-redesign ``Request`` attribute names, so existing callers
    keep working unchanged."""

    rid: int
    prompt: np.ndarray  # [S0] int32
    tokens: np.ndarray  # [T] int32 generated tokens
    token_ticks: Tuple[int, ...]  # engine tick each token landed on
    arrival_tick: int
    admit_tick: int
    first_token_tick: int
    finish_tick: int
    max_new_tokens: int
    slot: int
    chunks: int  # prefill launches (1 = one-shot)
    first_chunk_tick: int  # tick the first prompt chunk landed
    spec_proposed: int = 0  # draft tokens verified for this request
    spec_accepted: int = 0  # ... of which matched greedy decode
    status: str = "ok"  # ok | cancelled | deadline | numeric_error | rejected
    preemptions: int = 0  # mid-decode evictions this request survived
    recompute_tokens: int = 0  # tokens re-ingested after preemption

    @property
    def generated(self) -> List[int]:
        """Legacy list view of ``tokens``."""
        return self.tokens.tolist()

    @property
    def ttft_ticks(self) -> int:
        """Ticks from arrival to the first generated token (inclusive)."""
        return self.first_token_tick - self.arrival_tick + 1

    @property
    def done(self) -> bool:
        return True

    @classmethod
    def from_request(cls, req: Request) -> "RequestResult":
        return cls(
            rid=req.rid,
            prompt=req.prompt,
            tokens=np.asarray(req.generated, np.int32),
            token_ticks=tuple(req.token_ticks),
            arrival_tick=req.arrival_tick,
            admit_tick=req.admit_tick,
            first_token_tick=req.first_token_tick,
            finish_tick=req.finish_tick,
            max_new_tokens=req.max_new_tokens,
            slot=req.slot,
            chunks=req.chunks,
            first_chunk_tick=(
                req.first_chunk_tick if req.first_chunk_tick is not None else req.admit_tick
            ),
            spec_proposed=req.spec_proposed,
            spec_accepted=req.spec_accepted,
            status=req.status,
            preemptions=req.preemptions,
            recompute_tokens=req.recompute_tokens,
        )


class Scheduler:
    """Admission + slot assignment + retirement over ``num_slots`` slots."""

    def __init__(
        self,
        num_slots: int,
        buckets: Sequence[int],
        max_seq: int,
        *,
        exact: bool = False,
        multiple: int = 1,
        chunk: Optional[int] = None,
        allocator=None,
        prefill_chunk: Optional[int] = None,
        tick_token_budget: Optional[int] = None,
    ):
        if num_slots < 1:
            raise ValueError("need at least one slot")
        self.num_slots = num_slots
        self.multiple = max(1, multiple)  # sequence-parallel divisibility
        self.chunk = chunk  # SSD scan chunk (exact mode only)
        # continuous prefill: prompts stream into their slot prefill_chunk
        # tokens per launch; tick_token_budget caps decode + chunk tokens per
        # tick (None = unbudgeted: every pending chunk runs every tick)
        self.prefill_chunk = prefill_chunk
        self.tick_token_budget = tick_token_budget
        # paged KV pool: admission accounts PAGES, not slot rows — a request
        # is only admitted when its whole lifetime (prompt + token budget)
        # fits the unreserved pool, so decode can never exhaust mid-flight
        self.allocator = allocator
        self.buckets = tuple(sorted(set(buckets)))
        if not self.buckets or self.buckets[-1] > max_seq:
            raise ValueError(f"buckets {buckets} must be non-empty and <= max_seq={max_seq}")
        self.max_seq = max_seq
        self.exact = exact
        self.slots: List[Optional[Request]] = [None] * num_slots
        self._queue: List[Request] = []
        self._next_rid = 0
        # requests admission found can NEVER fit the pool (even empty):
        # popped from the queue with status "rejected" for the engine to
        # drain, instead of blocking the line head forever
        self.rejected: List[Request] = []

    # -- submission ---------------------------------------------------------

    def submit(
        self, prompt: np.ndarray, max_new_tokens: int, arrival_tick: int = 0,
        *, deadline_ticks: Optional[int] = None, priority: int = 0,
    ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if deadline_ticks is not None and deadline_ticks < 1:
            raise ValueError("deadline_ticks must be >= 1 or None")
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) exceeds "
                f"cache capacity {self.max_seq}"
            )
        if self.prefill_chunk is None:
            self.bucket_for(len(prompt))  # raise early on un-bucketable prompts
        req = Request(
            self._next_rid, prompt, max_new_tokens, arrival_tick,
            deadline_ticks=deadline_ticks, priority=priority,
        )
        self._next_rid += 1
        self._queue.append(req)
        return req

    def bucket_for(self, length: int) -> int:
        """Smallest bucket >= length (or the exact length in exact mode)."""
        if length < 1 or length > self.max_seq:
            raise ValueError(f"prompt length {length} outside (0, {self.max_seq}]")
        if self.exact:
            # no padding available, so the prompt itself must satisfy the
            # sequence-parallel divisibility (hybrid archs still shard
            # attention prefill over the model axis)
            if length % self.multiple:
                raise ValueError(
                    f"exact prefill (SSM/hybrid archs) needs the prompt length to be "
                    f"a multiple of the sequence-parallel size {self.multiple}; got {length}"
                )
            local = length // self.multiple
            if self.chunk is not None and local > self.chunk and local % self.chunk:
                raise ValueError(
                    f"the SSD chunked scan needs the per-device prompt length "
                    f"({local}) to be <= or a multiple of the chunk ({self.chunk})"
                )
            return length
        for b in self.buckets:
            if b >= length:
                return b
        raise ValueError(f"prompt length {length} exceeds largest bucket {self.buckets[-1]}")
    def pack_groups(
        self,
        assigned: List[Tuple[int, "Request"]],
        *,
        pack_max: int = 4,
        plan: str = "binpack",
    ) -> List[List[Tuple[int, "Request"]]]:
        """Group same-tick admissions into packed prefill rows.

        ``plan="binpack"`` (default) sorts by length (descending) and places
        each request where the total padded-bucket cost grows least —
        first-fit-decreasing toward bucket boundaries, so a 16+9+8 burst
        prefers an exactly-full 32 row + a padding-free 8 over one 64-bucket
        row.  The admission-order greedy plan is kept as a candidate and the
        cheaper of the two (total bucketed tokens, then fewer groups) wins,
        so binpack never prefills more padding than ``plan="greedy"`` — the
        old behavior, kept for the serve bench's TTFT comparison.  Groups
        close at ``pack_max`` documents or the largest bucket.  Exact mode
        (SSM/hybrid) never packs — the recurrent state has no per-document
        reset.
        """
        if self.exact or pack_max <= 1:
            return [[x] for x in assigned]
        if plan not in ("greedy", "binpack"):
            raise ValueError(f"unknown pack plan {plan!r} (greedy | binpack)")
        cap = self.buckets[-1]
        groups: List[List[Tuple[int, Request]]] = []
        cur: List[Tuple[int, Request]] = []
        cur_len = 0
        for slot, req in assigned:
            length = len(req.prompt)
            if cur and (len(cur) >= pack_max or cur_len + length > cap):
                groups.append(cur)
                cur, cur_len = [], 0
            cur.append((slot, req))
            cur_len += length
        if cur:
            groups.append(cur)
        if plan == "greedy":
            return groups

        # first-fit-decreasing by MARGINAL bucket cost: joining a group costs
        # bucket(total+len) - bucket(total) extra padded tokens, a fresh group
        # costs bucket(len); ties join (fewer prefill launches)
        bins: List[Tuple[int, List[Tuple[int, Request]]]] = []  # (sum, members)
        order = sorted(assigned, key=lambda sr: len(sr[1].prompt), reverse=True)
        for slot, req in order:
            length = len(req.prompt)
            best_i, best_c = None, self.bucket_for(length)  # fresh-group cost
            for i, (total, members) in enumerate(bins):
                if len(members) >= pack_max or total + length > cap:
                    continue
                c = self.bucket_for(total + length) - self.bucket_for(total)
                if c <= best_c:
                    best_i, best_c = i, c
            if best_i is None:
                bins.append((length, [(slot, req)]))
            else:
                total, members = bins[best_i]
                bins[best_i] = (total + length, members + [(slot, req)])
        packed = [members for _, members in bins]

        def cost(gs):
            return sum(self.bucket_for(sum(len(r.prompt) for _, r in g)) for g in gs)

        # the greedy plan stays a candidate: dense bursts that fit one bucket
        # row beat any split, and this guarantees cost(binpack) <= cost(greedy)
        return min((packed, groups), key=lambda gs: (cost(gs), len(gs)))

    # -- per-tick operations ------------------------------------------------

    def _next_candidate(self, tick: int) -> Optional[Request]:
        """Highest-priority arrived request (FIFO within a priority level);
        requests that could never fit even an EMPTY pool are moved to
        ``self.rejected`` on sight instead of blocking the line."""
        while True:
            cand = min(
                (r for r in self._queue if r.arrival_tick <= tick),
                key=lambda r: (-r.priority, r.arrival_tick, r.rid),
                default=None,
            )
            if cand is None:
                return None
            if self.allocator is not None and self.allocator.never_admittable(
                cand.context_len, cand.remaining_new_tokens
            ):
                self._queue.remove(cand)
                cand.status = "rejected"
                self.rejected.append(cand)
                continue
            return cand

    def admit(self, tick: int) -> List[Tuple[int, Request]]:
        """Assign arrived queued requests to free slots — highest priority
        first, FIFO within a level (default priority 0 keeps the original
        pure-FIFO behavior).  Returns [(slot, request)] for the engine to
        prefill.  A preempted request re-enters through here with its
        context (prompt + generated) as the ingest payload."""
        assigned = []
        pending_pages = 0  # pages promised to this tick's earlier admissions
        pending_prompt = 0  # ... of which must be physically free NOW
        for slot in range(self.num_slots):
            if self.slots[slot] is not None:
                continue
            req = self._next_candidate(tick)
            if req is None:
                break
            if self.allocator is not None:
                if not self.allocator.can_admit(
                    req.context_len, req.remaining_new_tokens,
                    pending=pending_pages, pending_prompt=pending_prompt,
                ):
                    break  # pool exhausted: FIFO holds the head until pages free
                pending_pages += self.allocator.reserve_for(
                    req.context_len, req.remaining_new_tokens
                )
                pending_prompt += self.allocator.layout.pages_for(req.context_len)
            self._queue.remove(req)
            req.slot, req.admit_tick = slot, tick
            # freeze the ingest target NOW: decode appends grow context_len,
            # but the chunk machinery must stop exactly here
            req.ingest_len = req.context_len
            self.slots[slot] = req
            assigned.append((slot, req))
        return assigned

    def take_rejected(self) -> List[Request]:
        """Drain requests admission rejected as never-fitting."""
        out, self.rejected = self.rejected, []
        return out

    def preempt(self, slot: int) -> Request:
        """Evict a mid-flight request back to the queue: its slot frees, its
        prefill position resets so admission re-ingests the full context
        (prompt + generated) through continuous prefill.  The caller (the
        engine) frees the allocator pages and counts the preemption."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is already free")
        self.slots[slot] = None
        req.slot = None
        req.prefill_pos = 0
        self._queue.append(req)
        return req

    def find(self, rid: int) -> Optional[Request]:
        """Look a live request up by rid (queued or active); None if it is
        not in flight (finished, rejected, or never submitted)."""
        for r in self._queue:
            if r.rid == rid:
                return r
        for r in self.slots:
            if r is not None and r.rid == rid:
                return r
        return None

    def cancel_queued(self, rid: int) -> Optional[Request]:
        """Remove a QUEUED request; returns it (status set) or None if the
        rid is not queued (active requests cancel through the engine, which
        must also free the slot's pages)."""
        for r in self._queue:
            if r.rid == rid:
                self._queue.remove(r)
                r.status = "cancelled"
                return r
        return None

    def take_expired(self, tick: int) -> List[Request]:
        """Remove QUEUED requests whose deadline passed before admission."""
        out = [
            r for r in self._queue
            if r.deadline_ticks is not None
            and tick - r.arrival_tick >= r.deadline_ticks
        ]
        for r in out:
            self._queue.remove(r)
            r.status = "deadline"
        return out

    def plan_chunks(self, decode_slots: int) -> List[Tuple[int, Request, int, int]]:
        """Continuous prefill: pick this tick's chunk work under the token
        budget.  Returns ``[(slot, request, start, take)]`` — the engine
        launches exactly this plan and advances ``request.prefill_pos``.

        Chunks are served oldest-request-first (admission order), so the
        head of the line finishes prefilling — and starts decoding — as
        early as possible.  The budget charges one token per decodable slot
        (``decode_slots``) first, then grants whole chunks until it runs
        out.  The head-of-line chunk is ALWAYS granted, budget or not:
        prefill makes progress every tick, it can only be throttled."""
        if self.prefill_chunk is None:
            return []
        work = sorted(
            (r.admit_tick, r.rid, slot, r)
            for slot, r in enumerate(self.slots)
            if r is not None and r.prefill_pos < r.ingest_len
        )
        budget = None
        if self.tick_token_budget is not None:
            budget = max(self.tick_token_budget - decode_slots, 0)
        plan: List[Tuple[int, Request, int, int]] = []
        spent = 0
        for _, _, slot, r in work:
            take = min(self.prefill_chunk, r.ingest_len - r.prefill_pos)
            if plan and budget is not None and spent + take > budget:
                break
            plan.append((slot, r, r.prefill_pos, take))
            spent += take
        return plan

    def plan_spec(
        self, drafts: Dict[int, List[int]], decode_slots: int, chunk_tokens: int
    ) -> Dict[int, List[int]]:
        """Grant speculative draft tokens under the tick token budget.

        Draft tokens are EXTRA decode-side work on top of what this tick
        already spent: one token per decodable slot plus the prefill-chunk
        tokens ``plan_chunks`` granted (``chunk_tokens``).  Only the LEFTOVER
        budget is handed to drafts, oldest request first (admission order,
        like chunks), so speculation can never displace a prefill chunk or a
        decodable slot's guaranteed token — the PR 6 TTFT / inter-token
        bound is unchanged.  A draft may be granted partially (truncated to
        the remaining budget).  No budget configured = grant everything."""
        if not drafts:
            return {}
        if self.tick_token_budget is None:
            return dict(drafts)
        left = max(self.tick_token_budget - decode_slots - chunk_tokens, 0)
        granted: Dict[int, List[int]] = {}
        order = sorted((self.slots[s].admit_tick, self.slots[s].rid, s) for s in drafts)
        for _, _, slot in order:
            if left <= 0:
                break
            take = drafts[slot][:left]
            granted[slot] = take
            left -= len(take)
        return granted

    def retire(self, slot: int, tick: int, status: str = "ok") -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is already free")
        req.finish_tick = tick
        req.status = status
        self.slots[slot] = None
        return req

    # -- introspection ------------------------------------------------------

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self.slots)
