"""ServeConfig: one frozen object for every serving knob.

``ServeEngine`` grew its knobs one PR at a time — bucketed prefill, packing,
the paged pool, the native decode kernel — until the constructor carried a
dozen loose kwargs that ``launch/serve.py``, ``serve_bench`` and every test
had to thread through individually.  This module is the redesigned surface:

    eng = ServeEngine(cfg, params, ctx=ctx, serve=ServeConfig(
        max_seq=256, num_slots=4, paged=True, prefill_chunk=64,
        tick_token_budget=128,
    ))

All validation lives in ``ServeConfig.__post_init__`` so a bad combination
fails at construction, not three layers down at trace time.  The legacy
``ServeEngine(cfg, params, ctx, max_seq=..., paged=...)`` kwarg form still
works through a deprecation shim (one ``DeprecationWarning``, pinned by
test) that maps the old names 1:1 onto this dataclass.

The two fields new in this PR drive continuous prefill:

* ``prefill_chunk`` — split every admitted prompt into chunks of this many
  tokens and append them through the live-cache chunk path, interleaved
  with decode ticks.  ``None`` (default) keeps the one-shot bucketed
  prefill.  Unlike ``prefill_buckets``, the chunk size has NO divisibility
  constraint with the mesh: chunks scatter by absolute position.
* ``tick_token_budget`` — cap on (decode tokens + prefill-chunk tokens) per
  tick.  Each tick spends one token per decodable slot first, then grants
  prefill chunks (oldest request first) until the budget is exhausted; the
  head-of-line chunk is always granted so prefill cannot starve.  This is
  the TTFT / inter-token-latency bound: no tick's launch size scales with
  the longest pending prompt, only with the budget.

Speculative decode (this PR) adds three more:

* ``spec_k`` — verify up to ``spec_k`` tokens per slot per tick in ONE
  banded chunk launch (the current token + up to ``spec_k - 1`` drafted
  tokens).  ``0`` (default) keeps plain one-token decode; ``>= 2`` enables
  speculation.  Greedy accept/reject commits the longest accepted prefix,
  so the generated tokens are IDENTICAL to vanilla greedy decode — only
  how many land per tick changes.
* ``spec_draft`` — the draft proposer.  ``"ngram"`` (default) is
  self-speculative prompt-lookup: the longest suffix n-gram of the
  request's own prompt + generated history is matched against its earlier
  occurrences and the continuation is the draft — no second model.
  ``"off"`` disables proposing (every tick degenerates to plain decode).
* ``spec_max_misses`` — after this many CONSECUTIVE missed verify ticks
  (any drafted token rejected) a slot suspends drafting for a cooldown of
  ``16 * spec_max_misses`` ticks, then re-probes with one draft — so
  low-acceptance traffic degrades to ~baseline cost instead of paying a
  batch-wide verify launch forever, while a workload that turns repetitive
  later is re-detected.  Cooldown wake-ups align to a global tick phase so
  concurrent suspended slots probe in ONE shared launch.  ``None`` never
  suspends.  The counter resets on a fully-accepted verify tick and at
  admission.

Quantized KV (this PR) adds one:

* ``kv_dtype`` — ``"fp"`` (default) stores pages in ``cache_dtype``;
  ``"int8"`` / ``"fp8"`` store the paged pool quantized with
  per-(token, kv-head) scales in a side table that shares the block
  table's physical indexing, dequantized inside the decode kernel right
  after each page's DMA.  Requires ``paged=True``; ``"fp8"`` additionally
  requires runtime float8_e4m3fn support.

Robustness (this PR) adds three:

* ``oversubscribe`` — admission accounting capacity as a multiple of the
  physical page pool.  ``1.0`` (default) keeps the conservative lifetime
  reservation: ``prompt + max_new_tokens`` pages are booked for a
  request's whole life, so mid-decode exhaustion is impossible — and the
  pool idles whenever requests finish early.  ``> 1.0`` books lifetime
  reservations against ``floor(oversubscribe * num_pages)`` virtual pages
  and only requires the PROMPT pages (+ one page of margin) to fit
  physically at admission; when a decode append then finds the free list
  empty, the engine preempts a victim slot (youngest first, prefix-shared
  donors last), frees its pages, and re-queues it with its generated
  tokens appended to the prompt so continuous prefill recomputes it —
  the resumed stream is token-identical to an uninterrupted run.
  Requires ``paged=True`` and ``prefill_chunk`` (recompute rides the
  chunk machinery).
* ``nan_guard`` — per-tick NaN/Inf logit guard (default on): every decode
  / verify / final-chunk launch also returns an in-graph per-slot
  finiteness bit; a non-finite slot is retired with
  ``RequestResult.status == "numeric_error"`` while every other slot's
  tokens commit bitwise-unchanged (decode is batch-row-independent).
* ``health_every`` — run ``engine.health()`` (allocator refcount/free-list
  /scale-lockstep invariant sweep + engine slot cross-checks) every N
  ticks, raising on any violation.  ``0`` (default) = only on demand.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.core import kv_quant

__all__ = ["ServeConfig"]

_DECODE_KERNELS = ("auto", "native", "gather", "band")
_PACK_PLANS = ("greedy", "binpack")
_SPEC_DRAFTS = ("ngram", "off")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Every serving knob in one validated, hashable place."""

    max_seq: int = 256  # per-request cap: len(prompt) + max_new_tokens
    num_slots: int = 4  # concurrent requests (cache batch rows)
    cache_dtype: Any = jnp.float32  # KV cache dtype
    prefill_buckets: Optional[Tuple[int, ...]] = None  # one-shot prefill sizes
    eos_id: Optional[int] = None  # early-stop token
    pack_prefill: bool = True  # pack same-tick prompts into one row
    pack_max: int = 4  # max prompts per packed row
    pack_plan: str = "binpack"  # greedy | binpack (FFD by marginal cost)
    paged: bool = False  # paged KV pool + prefix sharing
    page_size: Optional[int] = None  # per-shard tokens per page (paged)
    num_pages: Optional[int] = None  # physical pool size (paged)
    decode_kernel: str = "auto"  # auto | native | gather | band
    kv_dtype: str = "fp"  # fp | int8 | fp8: paged-pool storage precision
    prefill_chunk: Optional[int] = None  # continuous prefill: chunk size
    tick_token_budget: Optional[int] = None  # cap decode+chunk tokens per tick
    spec_k: int = 0  # speculative decode: tokens verified per slot per tick
    spec_draft: str = "ngram"  # ngram (prompt-lookup) | off
    spec_max_misses: Optional[int] = 4  # consecutive missed verify ticks
    # before a slot's drafting suspends for a cooldown (None = never)
    oversubscribe: float = 1.0  # admission capacity multiple (paged); > 1.0
    # trades lifetime reservation for preempt-and-recompute under pressure
    nan_guard: bool = True  # retire (not propagate) non-finite-logit slots
    health_every: int = 0  # invariant sweep every N ticks (0 = on demand)

    def __post_init__(self):
        if self.max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {self.max_seq}")
        if self.num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {self.num_slots}")
        if self.pack_max < 1:
            raise ValueError(f"pack_max must be >= 1, got {self.pack_max}")
        if self.pack_plan not in _PACK_PLANS:
            raise ValueError(
                f"pack_plan must be one of {_PACK_PLANS}, got {self.pack_plan!r}"
            )
        if self.decode_kernel not in _DECODE_KERNELS:
            raise ValueError(
                f"decode_kernel must be one of {_DECODE_KERNELS}, "
                f"got {self.decode_kernel!r}"
            )
        if self.prefill_buckets is not None:
            buckets = tuple(int(b) for b in self.prefill_buckets)
            if not buckets or any(b < 1 for b in buckets):
                raise ValueError(f"prefill_buckets must be positive, got {buckets}")
            object.__setattr__(self, "prefill_buckets", buckets)
        if not self.paged and (self.page_size is not None or self.num_pages is not None):
            raise ValueError("page_size/num_pages require paged=True")
        if self.page_size is not None and self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages is not None and self.num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {self.num_pages}")
        if self.kv_dtype not in kv_quant.KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {kv_quant.KV_DTYPES}, "
                f"got {self.kv_dtype!r}"
            )
        if self.kv_dtype != "fp":
            if not self.paged:
                raise ValueError("kv_dtype requires paged=True (pool storage)")
            if self.kv_dtype == "fp8" and not kv_quant.fp8_supported():
                raise ValueError(
                    "kv_dtype='fp8' requires runtime float8_e4m3fn support; "
                    "use 'int8'"
                )
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.tick_token_budget is not None:
            if self.prefill_chunk is None:
                raise ValueError(
                    "tick_token_budget only budgets continuous prefill; "
                    "set prefill_chunk as well"
                )
            if self.tick_token_budget < 1:
                raise ValueError(
                    f"tick_token_budget must be >= 1, got {self.tick_token_budget}"
                )
        if self.spec_k < 0 or self.spec_k == 1:
            raise ValueError(
                f"spec_k must be 0 (off) or >= 2 (current token + drafts), "
                f"got {self.spec_k}"
            )
        if self.spec_draft not in _SPEC_DRAFTS:
            raise ValueError(
                f"spec_draft must be one of {_SPEC_DRAFTS}, got {self.spec_draft!r}"
            )
        if self.spec_max_misses is not None and self.spec_max_misses < 1:
            raise ValueError(
                f"spec_max_misses must be >= 1 or None, got {self.spec_max_misses}"
            )
        if self.oversubscribe < 1.0:
            raise ValueError(
                f"oversubscribe must be >= 1.0, got {self.oversubscribe}"
            )
        if self.oversubscribe > 1.0:
            if not self.paged:
                raise ValueError(
                    "oversubscribe > 1.0 requires paged=True (preemption "
                    "frees pages, not slot rows)"
                )
            if self.prefill_chunk is None:
                raise ValueError(
                    "oversubscribe > 1.0 requires prefill_chunk: preempted "
                    "requests recompute through continuous prefill"
                )
        if self.health_every < 0:
            raise ValueError(
                f"health_every must be >= 0, got {self.health_every}"
            )

    @classmethod
    def from_legacy_kwargs(cls, kwargs: dict) -> "ServeConfig":
        """Map the pre-redesign ``ServeEngine(**kwargs)`` names (identical
        1:1) onto a validated config; unknown names raise ``TypeError`` like
        the old constructor did."""
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(kwargs) - names)
        if unknown:
            raise TypeError(f"unknown ServeEngine kwargs: {unknown}")
        return cls(**kwargs)
