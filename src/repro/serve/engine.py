"""Batched serving engine over the distributed striped KV cache.

Request lifecycle: right-pad prompts to a common length, one jitted prefill
(Mesh-Attention over the model axis, writing the striped cache in place),
then jitted greedy decode steps.  The cache is allocated once at engine
construction and donated through the step, so decode is allocation-free.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dispatch
from repro.core.am import CommModel
from repro.data.pipeline import make_batch
from repro.models import transformer as tfm
from repro.parallel.context import ParallelCtx

__all__ = ["ServeEngine"]


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        ctx: Optional[ParallelCtx] = None,
        *,
        max_seq: int = 256,
        cache_dtype=jnp.float32,
    ):
        self.cfg = cfg
        self.ctx = ctx or ParallelCtx()
        self.params = params
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        # the declarative attention plan this engine serves under (the
        # prefill path resolves its backend/tile through this via dispatch)
        self.attn_plan = dispatch.plan_from_ctx(
            self.ctx, causal=True, layout=cfg.causal_layout
        )
        self._prefill = jax.jit(
            lambda p, b, c: tfm.prefill(p, cfg, self.ctx, b, c)
        )
        self._decode = jax.jit(
            lambda p, c, t: tfm.decode_step(p, c, t, cfg, self.ctx)
        )

    def _aux_inputs(self, batch_size: int) -> Dict:
        """Frontend stub inputs (audio frames / vision patches)."""
        extra = {}
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            extra["frames"] = jnp.zeros(
                (batch_size, cfg.encoder_seq, cfg.frontend_dim), jnp.float32
            )
        if cfg.frontend == "vision_stub":
            extra["patches"] = jnp.zeros(
                (batch_size, cfg.num_patches, cfg.frontend_dim), jnp.float32
            )
        return extra

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16) -> np.ndarray:
        """prompts: [B, S0] int32 (S0 must be divisible by the mesh's sp
        size).  Greedy decoding.  Striped-layout archs get their prompt
        striped here (the serving analogue of the data pipeline's §3.7
        permutation)."""
        B, S0 = prompts.shape
        if self.attn_plan.autotune and self.ctx.sp_size > 1:
            # resolve the (a, b) tile + schedules for this prefill geometry
            # through the on-disk plan cache BEFORE tracing, so repeated
            # serve launches skip the simulator entirely.  The key must match
            # what dispatch computes at trace time: activations inherit the
            # PARAM dtype (q flows from the embedding), not the cache dtype.
            # (with_backward stays at the plan default for the same reason —
            # a fwd-only tuning mode needs a serve-aware ParallelCtx first.)
            act_dtype = jax.tree.leaves(self.params)[0].dtype
            dispatch.plan_schedules(
                self.attn_plan,
                CommModel(
                    seq=S0,
                    hidden=self.cfg.num_heads * self.cfg.hd,
                    n=self.ctx.sp_size,
                    kv_hidden=self.cfg.num_kv_heads * self.cfg.hd,
                    bytes_per_elem=jnp.dtype(act_dtype).itemsize,
                    batch=B,
                ),
            )
        cache = tfm.init_cache(self.cfg, B, self.max_seq, dtype=self.cache_dtype, ctx=self.ctx)
        tokens = jnp.asarray(prompts, jnp.int32)
        n = self.ctx.sp_size
        if n > 1 and self.cfg.causal_layout == "striped":
            from repro.core.tiling import stripe_permutation

            perm = jnp.asarray(stripe_permutation(S0, n))
            tokens = tokens[:, perm]
            positions = perm.astype(jnp.int32)
        else:
            positions = jnp.arange(S0, dtype=jnp.int32)
        batch = {
            "tokens": tokens,
            "positions": positions,
            **self._aux_inputs(B),
        }
        logits, cache = self._prefill(self.params, batch, cache)
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [cur]
        for _ in range(max_new_tokens - 1):
            cur, cache, _ = self._decode(self.params, cache, cur)
            out.append(cur)
        return np.asarray(jnp.concatenate(out, axis=1))
