"""Continuous-batching serving engine over the distributed striped KV cache.

The engine owns a fixed pool of ``num_slots`` cache rows, allocated ONCE at
construction.  Requests flow through ``serve/scheduler.py``:

  * **prefill**: an admitted request is right-padded to a bucket length and
    prefilled alone (batch=1) through a per-bucket jitted function that
    scatters the resulting cache row into its assigned slot — jit retraces
    are bounded by the number of buckets, not by batch composition.
  * **decode**: ONE jitted step advances every slot per tick.  The cache
    carries a per-slot position vector ``pos: [B]`` (threaded through
    ``core/decode_attention.py``), so slots at arbitrary mixed depths decode
    together; per-token cross-device traffic stays O(B·H·D) (paper §3.7).
  * **retire**: per-slot EOS / max-token checks free the slot for the queue.

Because every decode op is batch-row-independent, a slot's tokens are exactly
what single-request generation would produce (MoE capacity is the one
documented exception: expert capacity couples rows by construction).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import dispatch
from repro.core.am import CommModel
from repro.models import transformer as tfm
from repro.parallel.context import ParallelCtx
from repro.serve.kv_pool import PageAllocator, PagedLayout
from repro.serve.scheduler import Request, Scheduler, default_buckets

__all__ = ["ServeEngine"]


class ServeEngine:
    """Slot-based continuous-batching engine.

    ``generate(prompts, max_new_tokens)`` keeps the legacy static-batch API
    (greedy, exactly max_new_tokens per row) on top of the streaming path:
    ``submit()`` requests, ``step()`` ticks, ``run()`` to drain.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        ctx: Optional[ParallelCtx] = None,
        *,
        max_seq: int = 256,
        cache_dtype=jnp.float32,
        num_slots: int = 4,
        prefill_buckets: Optional[Sequence[int]] = None,
        eos_id: Optional[int] = None,
        pack_prefill: bool = True,
        pack_max: int = 4,
        pack_plan: str = "binpack",
        paged: bool = False,
        page_size: Optional[int] = None,
        num_pages: Optional[int] = None,
        decode_kernel: str = "auto",
    ):
        self.cfg = cfg
        self.ctx = ctx or ParallelCtx()
        # flash-decode kernel variant: "auto" serves the paged cache with the
        # split-K native kernel (block table read in-kernel) wherever Pallas
        # runs, the gather/band reference elsewhere; "native"/"gather" force
        if decode_kernel != "auto":
            self.ctx = dataclasses.replace(self.ctx, decode_kernel=decode_kernel)
        self.params = params
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.num_slots = num_slots
        self.eos_id = eos_id
        self.pack_plan = pack_plan
        n = self.ctx.sp_size
        if max_seq % max(n, 1):
            raise ValueError(f"max_seq={max_seq} must be divisible by sp_size={n}")
        # paged KV: slot rows virtualize over a refcounted physical page pool
        # (serve/kv_pool.py) — memory follows allocated pages, and identical
        # prompt prefixes share pages across requests
        self.paged = paged
        self.allocator: Optional[PageAllocator] = None
        if paged:
            if cfg.ssm is not None or cfg.encoder_layers:
                raise ValueError(
                    "the paged KV cache serves attention-only decoder archs "
                    "(SSM state / encoder cross-K/V have no page structure)"
                )
            layout = PagedLayout.for_engine(
                max_seq, max(n, 1), num_slots, page_size=page_size, num_pages=num_pages
            )
            self.allocator = PageAllocator(layout)
        # SSD's recurrent state has no pad-correction: prefill exactly
        exact = cfg.ssm is not None
        buckets = tuple(prefill_buckets) if prefill_buckets else default_buckets(max_seq, n)
        if any(b % max(n, 1) for b in buckets) and not exact:
            raise ValueError(f"buckets {buckets} must be multiples of sp_size={n}")
        self.scheduler = Scheduler(
            num_slots, buckets, max_seq, exact=exact, multiple=n,
            chunk=cfg.ssm.chunk if exact else None, allocator=self.allocator,
        )
        # packed prefill: several same-tick admissions share one row under a
        # document mask (attention-only decoder archs; SSD state and per-row
        # frontend/encoder side inputs do not pack)
        self.pack_max = max(1, pack_max)
        self._can_pack = (
            pack_prefill
            and cfg.ssm is None
            and not cfg.encoder_layers
            and cfg.frontend is None
        )
        # the declarative attention plan this engine serves under (the
        # prefill path resolves its backend/tile through this via dispatch)
        self.attn_plan = dispatch.plan_from_ctx(
            self.ctx, causal=True, layout=cfg.causal_layout
        )
        # THE cache: allocated once here, threaded through prefill inserts
        # and decode steps for the engine's whole lifetime
        self._cache = tfm.init_cache(
            cfg, num_slots, max_seq, dtype=cache_dtype, ctx=self.ctx,
            paged=self.allocator.layout if self.allocator else None,
        )
        self._cur = np.zeros((num_slots, 1), np.int32)  # last token per slot
        self._depth = np.zeros((num_slots,), np.int64)  # host view of pos
        self._bt_version = -1  # device block table staleness marker
        self.bt_uploads = 0  # device block-table uploads (version-gated:
        # ticks whose appends stay inside a page re-upload nothing)
        self._tick = 0
        self._finished: Dict[int, Request] = {}
        # jit bookkeeping: trace counters tick at TRACE time only, so tests
        # can assert the retrace count is bounded by the bucket set
        self._prefill_fns: Dict[int, object] = {}
        self.prefill_trace_counts: Dict[int, int] = {}
        self.decode_trace_count = 0
        # launch accounting (every call, not just traces): the pack planner's
        # padded-prefill cost is launches x bucket tokens
        self.prefill_launches = 0
        self.prefill_launch_tokens = 0
        self._decode = jax.jit(self._decode_traced)
        self._copy_pages = jax.jit(self._copy_pages_traced)

    # -- jitted paths -------------------------------------------------------

    def _decode_traced(self, params, cache, tokens):
        self.decode_trace_count += 1  # python side effect: trace-time only
        return tfm.decode_step(params, cache, tokens, self.cfg, self.ctx)

    def _copy_pages_traced(self, cache, src, dst):
        """Copy-on-write: physical page src[i] -> dst[i] in every layer's
        pool.  Pad entries carry dst == num_pages, which the scatter drops;
        fixed [num_slots] operand shapes keep this a single trace."""
        out = dict(cache)
        for key in ("k", "v"):
            pool = cache[key]  # [L, num_pages, n*ps, Hkv, D]
            out[key] = pool.at[:, dst].set(pool[:, src], mode="drop")
        return out

    def _sync_block_table(self):
        """Upload the allocator's block table when it moved since last sync."""
        if self.allocator is None or self.allocator.version == self._bt_version:
            return
        self._cache = dict(self._cache)
        self._cache["bt"] = jnp.asarray(self.allocator.device_table(self.num_slots))
        self._bt_version = self.allocator.version
        self.bt_uploads += 1

    def _aux_inputs(self, batch_size: int) -> Dict:
        """Frontend stub inputs (audio frames / vision patches)."""
        extra = {}
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            extra["frames"] = jnp.zeros(
                (batch_size, cfg.encoder_seq, cfg.frontend_dim), jnp.float32
            )
        if cfg.frontend == "vision_stub":
            extra["patches"] = jnp.zeros(
                (batch_size, cfg.num_patches, cfg.frontend_dim), jnp.float32
            )
        return extra

    def _get_prefill(self, bucket: int):
        """Jitted (prefill into a fresh row + scatter into slot) per bucket."""
        if bucket in self._prefill_fns:
            return self._prefill_fns[bucket]
        cfg, ctx = self.cfg, self.ctx
        n = ctx.sp_size
        if self.attn_plan.autotune and n > 1:
            # resolve the (a, b) tile + schedules for this bucket geometry
            # through the on-disk plan cache BEFORE tracing, so repeated
            # serve launches skip the simulator entirely.  The key must match
            # what dispatch computes at trace time: activations inherit the
            # PARAM dtype (q flows from the embedding), not the cache dtype.
            act_dtype = jax.tree.leaves(self.params)[0].dtype
            dispatch.plan_schedules(
                self.attn_plan,
                CommModel(
                    seq=bucket,
                    hidden=cfg.num_heads * cfg.hd,
                    n=n,
                    kv_hidden=cfg.num_kv_heads * cfg.hd,
                    bytes_per_elem=jnp.dtype(act_dtype).itemsize,
                    batch=1,
                ),
            )
        if n > 1 and cfg.causal_layout == "striped":
            from repro.core.tiling import stripe_permutation

            perm = np.asarray(stripe_permutation(bucket, n))
        else:
            perm = np.arange(bucket)
        positions = jnp.asarray(perm, jnp.int32)
        self.prefill_trace_counts.setdefault(bucket, 0)

        def fn(params, cache, tokens, length, slot, shared_len):
            self.prefill_trace_counts[bucket] += 1  # trace-time only
            # striping is the serving analogue of the data pipeline's §3.7
            # permutation: token at index j carries true position perm[j]
            toks = tokens[:, perm]
            batch = {
                "tokens": toks,
                "positions": positions,
                "length": jnp.reshape(length, (1,)),
                **self._aux_inputs(1),
            }
            if self.paged:
                # the pool IS the cache: K/V scatter through slot's block-
                # table row; positions below shared_len stay with their owner
                batch["slot"] = slot
                batch["shared_len"] = shared_len
                logits, cache = tfm.prefill(params, cfg, ctx, batch, cache)
                first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [1,1]
                return cache, first
            row = tfm.init_cache(cfg, 1, self.max_seq, dtype=self.cache_dtype, ctx=ctx)
            logits, row = tfm.prefill(params, cfg, ctx, batch, row)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [1,1]

            def insert(big, small):
                ax = 1 if big.ndim > 1 else 0  # pos is [B]; all else [L,B,...]
                return lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=ax
                )

            return jax.tree.map(insert, cache, row), first

        jitted = jax.jit(fn)
        self._prefill_fns[bucket] = jitted
        return jitted

    def _get_prefill_packed(self, bucket: int, k: int):
        """Jitted packed prefill for ``k`` documents sharing one ``bucket``
        row — scatters each document's K/V into its own slot.  Trace count
        keys are (bucket, k): retraces stay bounded by buckets x pack sizes,
        independent of the actual prompt-length mix."""
        key = (bucket, k)
        if key in self._prefill_fns:
            return self._prefill_fns[key]
        cfg, ctx = self.cfg, self.ctx
        n = ctx.sp_size
        if self.attn_plan.autotune and n > 1:
            # pre-resolve the segment-masked plan for this bucket geometry
            # through the on-disk cache (mask signature is part of the key)
            from repro.core.masking import MaskSpec

            act_dtype = jax.tree.leaves(self.params)[0].dtype
            plan = dispatch.plan_from_ctx(
                ctx, mask=MaskSpec.segment(window=cfg.window), layout=cfg.causal_layout
            )
            dispatch.plan_schedules(
                plan,
                CommModel(
                    seq=bucket,
                    hidden=cfg.num_heads * cfg.hd,
                    n=n,
                    kv_hidden=cfg.num_kv_heads * cfg.hd,
                    bytes_per_elem=jnp.dtype(act_dtype).itemsize,
                    batch=1,
                ),
            )
        if n > 1 and cfg.causal_layout == "striped":
            from repro.core.tiling import stripe_permutation

            perm = np.asarray(stripe_permutation(bucket, n))
        else:
            perm = np.arange(bucket)
        perm_j = jnp.asarray(perm)
        self.prefill_trace_counts.setdefault(key, 0)

        def fn(params, cache, tokens, doc_lens, slots, shared_lens):
            self.prefill_trace_counts[key] += 1  # trace-time only
            j = jnp.arange(bucket, dtype=jnp.int32)
            cum = jnp.cumsum(doc_lens)
            starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), cum[:-1]])
            seg = jnp.sum(j[:, None] >= starts[None, :], axis=1).astype(jnp.int32) - 1
            pad = j >= cum[-1]
            seg = jnp.where(pad, jnp.int32(k), seg)  # pads match nothing real
            positions = j - starts[jnp.clip(seg, 0, k - 1)]
            batch = {
                "tokens": tokens[:, perm],  # §3.7 stripe, as in the data pipeline
                "positions": positions[perm_j],
                "segments": seg[perm_j],
                "doc_lens": doc_lens,
                "slots": slots,
            }
            if self.paged:
                batch["shared_lens"] = shared_lens
            logits, cache = tfm.prefill_packed(params, cfg, ctx, batch, cache)
            return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [k]

        jitted = jax.jit(fn)
        self._prefill_fns[key] = jitted
        return jitted

    # -- streaming API ------------------------------------------------------

    def submit(
        self, prompt: np.ndarray, max_new_tokens: int = 16, arrival_tick: int = 0
    ) -> int:
        """Queue one request; returns its rid.  ``arrival_tick`` defers
        admission until the engine clock reaches it (trace replay)."""
        req = self.scheduler.submit(prompt, max_new_tokens, arrival_tick)
        return req.rid

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def _finish(self, slot: int) -> Request:
        req = self.scheduler.retire(slot, self._tick)
        if self.allocator is not None:
            # drop the slot's page references; pages shared with live slots
            # survive until their last reader retires
            self.allocator.free_slot(slot)
        self._finished[req.rid] = req
        return req

    def _req_done(self, req: Request, tok: int) -> bool:
        if self.eos_id is not None and tok == self.eos_id:
            return True
        return len(req.generated) >= req.max_new_tokens

    def _alloc_pages(self, slot: int, req: Request) -> int:
        """Paged admission: claim (or prefix-share) the slot's pages and sync
        the device block table BEFORE the prefill trace reads it.  Returns
        the shared-prefix length the scatter must skip."""
        alloc = self.allocator.alloc_slot(slot, req.prompt, req.max_new_tokens)
        return alloc.shared_len

    def _prefill_single(self, slot: int, req: Request) -> int:
        """Legacy one-row-per-request prefill (exact/frontend archs)."""
        bucket = self.scheduler.bucket_for(len(req.prompt))
        self.prefill_launches += 1
        self.prefill_launch_tokens += bucket
        toks = np.zeros((1, bucket), np.int32)
        toks[0, : len(req.prompt)] = req.prompt
        shared = self._alloc_pages(slot, req) if self.paged else 0
        self._sync_block_table()
        fn = self._get_prefill(bucket)
        self._cache, first = fn(
            self.params,
            self._cache,
            jnp.asarray(toks),
            jnp.asarray(len(req.prompt), jnp.int32),
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(shared, jnp.int32),
        )
        self._depth[slot] = len(req.prompt)
        return int(np.asarray(first)[0, 0])

    def _prefill_group(self, group) -> List[int]:
        """Packed prefill: the group's prompts concatenate into one bucket
        row under a document mask; each document's K/V lands in its own
        slot.  Returns the first generated token per request."""
        lens = [len(req.prompt) for _, req in group]
        bucket = self.scheduler.bucket_for(sum(lens))
        self.prefill_launches += 1
        self.prefill_launch_tokens += bucket
        k = len(group)
        toks = np.zeros((1, bucket), np.int32)
        off = 0
        for (_, req), ln in zip(group, lens):
            toks[0, off : off + ln] = req.prompt
            off += ln
        shared = [
            self._alloc_pages(slot, req) if self.paged else 0 for slot, req in group
        ]
        self._sync_block_table()
        fn = self._get_prefill_packed(bucket, k)
        self._cache, firsts = fn(
            self.params,
            self._cache,
            jnp.asarray(toks),
            jnp.asarray(lens, jnp.int32),
            jnp.asarray([slot for slot, _ in group], jnp.int32),
            jnp.asarray(shared, jnp.int32),
        )
        for (slot, req), ln in zip(group, lens):
            self._depth[slot] = ln
        return [int(t) for t in np.asarray(firsts)]

    def step(self) -> List[Request]:
        """One engine tick: admit+prefill into free slots (same-tick
        admissions PACK into shared rows under a document mask), then one
        jitted decode over ALL slots.  Returns requests finished this tick."""
        finished: List[Request] = []
        # 1. admission: bucketed (packed) prefill straight into slot rows
        assigned = self.scheduler.admit(self._tick)
        if self._can_pack:
            groups = self.scheduler.pack_groups(
                assigned, pack_max=self.pack_max, plan=self.pack_plan
            )
        else:
            groups = [[x] for x in assigned]
        for group in groups:
            if self._can_pack:
                firsts = self._prefill_group(group)
            else:
                firsts = [self._prefill_single(slot, req) for slot, req in group]
            for tok, (slot, req) in zip(firsts, group):
                req.generated.append(tok)
                req.first_token_tick = self._tick
                self._cur[slot, 0] = tok
                if self._req_done(req, tok):
                    finished.append(self._finish(slot))
        # 2. one decode step over every slot (mixed depths via pos: [B])
        active = self.scheduler.active_slots()
        if active:
            if self.paged:
                # make every active slot's write position appendable: allocate
                # tail pages on chunk boundaries, copy-on-write shared tails
                copies = []
                for slot in active:
                    cp = self.allocator.ensure_append(slot, int(self._depth[slot]))
                    if cp is not None:
                        copies.append(cp)
                if copies:
                    npages = self.allocator.layout.num_pages
                    src = np.zeros((self.num_slots,), np.int32)
                    dst = np.full((self.num_slots,), npages, np.int32)  # dropped
                    for i, (s, d) in enumerate(copies):
                        src[i], dst[i] = s, d
                    self._cache = self._copy_pages(
                        self._cache, jnp.asarray(src), jnp.asarray(dst)
                    )
                self._sync_block_table()
            nxt, self._cache, _ = self._decode(
                self.params, self._cache, jnp.asarray(self._cur)
            )
            nxt_np = np.asarray(nxt)
            for slot in active:
                self._depth[slot] += 1
                req = self.scheduler.slots[slot]
                tok = int(nxt_np[slot, 0])
                req.generated.append(tok)
                self._cur[slot, 0] = tok
                if self._req_done(req, tok):
                    finished.append(self._finish(slot))
        self._tick += 1
        return finished

    def run(self) -> Dict[int, Request]:
        """Drain the queue; returns {rid: finished Request}."""
        while self.has_work:
            self.step()
        return dict(self._finished)

    def kv_cache_stats(self) -> Dict[str, float]:
        """Attention-cache memory accounting (bench / capacity planning).
        Dense: bytes are fixed at ``num_slots x max_seq``.  Paged: resident
        bytes follow the allocator's peak page usage, and the allocator's
        sharing/CoW counters ride along."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return {"cache_bytes": 0.0}
        L = cfg.num_layers
        itemsize = jnp.dtype(self.cache_dtype).itemsize
        hkv = self._cache["k"].shape[-2]
        elem = self._cache["k"].shape[-1] + self._cache["v"].shape[-1]  # dk + dv
        per_tok = L * hkv * elem * itemsize
        if self.allocator is None:
            return {
                "paged": 0,
                "cache_bytes": float(self.num_slots * self.max_seq * per_tok),
            }
        lay = self.allocator.layout
        stats = self.allocator.stats()
        return {
            "paged": 1,
            "page_size": lay.page_size,
            "chunk_tokens": lay.chunk,
            "num_pages": lay.num_pages,
            # pool reservation (what init_cache actually allocated) ...
            "cache_bytes": float(lay.num_pages * lay.chunk * per_tok),
            # ... vs what the workload actually touched
            "peak_page_bytes": float(stats["peak_in_use"] * lay.chunk * per_tok),
            "bt_uploads": float(self.bt_uploads),
            **{k: float(v) for k, v in stats.items()},
        }

    # -- legacy static-batch API --------------------------------------------

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16) -> np.ndarray:
        """prompts: [B, S0] int32.  Greedy decoding; returns [B,
        max_new_tokens].  A thin wrapper over the streaming path: B requests
        arrive at once and are served by the slot pool (in waves when B >
        num_slots).  The striped prompt permutation (§3.7) happens inside the
        bucketed prefill."""
        prompts = np.asarray(prompts, np.int32)
        rids = [self.submit(prompts[i], max_new_tokens, self._tick) for i in range(len(prompts))]
        self.run()
        out = []
        for rid in rids:
            row = self._finished.pop(rid).generated[:max_new_tokens]
            row = row + [self.eos_id or 0] * (max_new_tokens - len(row))
            out.append(row)
        return np.asarray(out, np.int32)
