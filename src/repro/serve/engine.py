"""Continuous-batching serving engine over the distributed striped KV cache.

The engine owns a fixed pool of ``num_slots`` cache rows, allocated ONCE at
construction.  Requests flow through ``serve/scheduler.py``:

  * **prefill**: an admitted request is right-padded to a bucket length and
    prefilled alone (batch=1) through a per-bucket jitted function that
    scatters the resulting cache row into its assigned slot — jit retraces
    are bounded by the number of buckets, not by batch composition.  With
    ``ServeConfig.prefill_chunk`` set, prompts instead stream into their
    slot in fixed-size chunks interleaved with decode (continuous prefill):
    one fixed-shape jitted chunk launch per tick, budgeted by
    ``ServeConfig.tick_token_budget``, so no tick scales with the longest
    pending prompt.
  * **decode**: ONE jitted step advances every slot per tick.  The cache
    carries a per-slot position vector ``pos: [B]`` (threaded through
    ``core/decode_attention.py``), so slots at arbitrary mixed depths decode
    together; per-token cross-device traffic stays O(B·H·D) (paper §3.7).
  * **retire**: per-slot EOS / max-token checks free the slot for the queue.

Because every decode op is batch-row-independent, a slot's tokens are exactly
what single-request generation would produce (MoE capacity is the one
documented exception: expert capacity couples rows by construction).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import dispatch
from repro.core.am import CommModel
from repro.models import transformer as tfm
from repro.parallel.context import ParallelCtx
from repro.serve.config import ServeConfig
from repro.serve.kv_pool import PageAllocator, PagedLayout, PoolExhausted
from repro.serve.scheduler import Request, RequestResult, Scheduler, default_buckets
from repro.serve.speculative import propose_ngram

__all__ = ["ServeEngine", "select_victim"]


def select_victim(slots, allocator, protect=()):
    """Preemption policy: pick the slot to evict when the page pool runs dry
    mid-decode.  Victims are ranked (1) slots whose pages nobody else maps
    first — evicting a prefix DONOR strands nothing (refcounts keep shared
    pages alive for the sharers) but frees fewer pages and forces the widest
    recompute blast radius, so donors go last; (2) youngest admission first
    (latest ``admit_tick``, then highest rid) — the oldest request always
    makes progress, which is what bounds recompute work and guarantees
    drain.  ``protect`` slots (the one being grown this tick) are exempt.
    Returns the slot index, or None when nothing is evictable."""
    cands = []
    for slot, req in enumerate(slots):
        if req is None or slot in protect:
            continue
        if allocator.slot_pages(slot) == 0:
            continue  # nothing to reclaim
        cands.append((
            allocator.slot_shares_pages(slot),  # donors last
            -(req.admit_tick if req.admit_tick is not None else -1),
            -req.rid,
            slot,
        ))
    if not cands:
        return None
    return min(cands)[3]

# mid-prefill slots park their cache position past any capacity: the shared
# decode step still ticks their row, but every write guard (pos < n*m) drops
# the append, so a half-ingested prompt can never be corrupted by decode
_PARKED = 2**30


class ServeEngine:
    """Slot-based continuous-batching engine.

    All knobs arrive as ONE validated object: ``ServeEngine(cfg, params,
    ctx=ctx, serve=ServeConfig(...))``.  The pre-redesign kwarg form
    (``ServeEngine(cfg, params, ctx, max_seq=..., paged=...)``) still works
    through a deprecation shim that maps the old names onto ``ServeConfig``.

    ``generate(prompts, max_new_tokens)`` keeps the legacy static-batch API
    (greedy, exactly max_new_tokens per row) on top of the streaming path:
    ``submit()`` requests, ``step()`` ticks, ``run()`` to drain — the
    streaming calls return ``RequestResult`` (tokens + per-token tick
    stamps + TTFT + chunk count).

    With ``serve.prefill_chunk`` set the engine runs CONTINUOUS PREFILL:
    admitted prompts stream into their slot ``prefill_chunk`` tokens per
    tick (budgeted by ``serve.tick_token_budget``), interleaved with the
    decode batch, instead of monopolizing a tick with one bucket-sized
    launch.  A request starts decoding on the same tick its last chunk
    lands, so chunked serving is token-for-token AND tick-for-tick
    identical to one-shot prefill — only launch sizes change.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        ctx: Optional[ParallelCtx] = None,
        *,
        serve: Optional[ServeConfig] = None,
        chaos=None,
        **legacy,
    ):
        if serve is not None and legacy:
            raise TypeError(
                f"pass serve=ServeConfig(...) or legacy kwargs, not both "
                f"(got both serve= and {sorted(legacy)})"
            )
        if serve is None:
            if legacy:
                warnings.warn(
                    "ServeEngine(cfg, params, ctx, max_seq=..., ...) is "
                    "deprecated; pass serve=ServeConfig(...) instead",
                    DeprecationWarning,
                    stacklevel=2,
                )
            serve = ServeConfig.from_legacy_kwargs(legacy)
        self.serve = serve
        self.cfg = cfg
        self.ctx = ctx or ParallelCtx()
        # flash-decode kernel variant: "auto" serves the paged cache with the
        # split-K native kernel (block table read in-kernel) wherever Pallas
        # runs, the gather/band reference elsewhere; "native"/"gather" force
        if serve.decode_kernel != "auto":
            self.ctx = dataclasses.replace(self.ctx, decode_kernel=serve.decode_kernel)
        self.params = params
        self.max_seq = serve.max_seq
        self.cache_dtype = serve.cache_dtype
        self.num_slots = serve.num_slots
        self.eos_id = serve.eos_id
        self.pack_plan = serve.pack_plan
        n = self.ctx.sp_size
        if serve.max_seq % max(n, 1):
            raise ValueError(
                f"max_seq={serve.max_seq} must be divisible by sp_size={n}"
            )
        # continuous prefill: chunk size + per-tick token budget (None/None =
        # legacy one-shot bucketed prefill).  Chunks scatter by absolute
        # position, so unlike buckets they need no divisibility with n.
        self.prefill_chunk = serve.prefill_chunk
        self.tick_token_budget = serve.tick_token_budget
        if self.prefill_chunk is not None and (
            cfg.ssm is not None or cfg.encoder_layers or cfg.frontend is not None
        ):
            raise ValueError(
                "continuous prefill serves attention-only decoder archs "
                "(SSM state / encoder / frontend inputs have no chunk-append)"
            )
        # speculative decode: verify spec_k tokens per slot per tick through
        # the chunk-attention machinery; greedy accept/reject keeps tokens
        # identical to vanilla decode, only the per-tick commit count changes
        self.spec_k = serve.spec_k
        self.spec_draft = serve.spec_draft
        self.spec_max_misses = serve.spec_max_misses
        self._spec_on = serve.spec_k >= 2 and serve.spec_draft != "off"
        if self._spec_on and (
            cfg.ssm is not None or cfg.encoder_layers or cfg.frontend is not None
        ):
            raise ValueError(
                "speculative decode rides the chunk-attention verify path: "
                "attention-only decoder archs (no SSM / encoder / frontend)"
            )
        # paged KV: slot rows virtualize over a refcounted physical page pool
        # (serve/kv_pool.py) — memory follows allocated pages, and identical
        # prompt prefixes share pages across requests
        self.paged = serve.paged
        # quantized pool storage: int8/fp8 pages + per-(token, kv-head) scale
        # side tables riding the block table's physical indexing
        self.kv_dtype = serve.kv_dtype
        self._quantized = serve.kv_dtype != "fp"
        self.dequant_fallbacks = 0  # quantized ticks served by the gather ref
        self._native_decode = (
            dispatch._resolve_decode_kernel(
                getattr(self.ctx, "decode_kernel", "auto"), paged=serve.paged
            ) == "native"
            if serve.paged else False
        )
        self.allocator: Optional[PageAllocator] = None
        if serve.paged:
            if cfg.ssm is not None or cfg.encoder_layers:
                raise ValueError(
                    "the paged KV cache serves attention-only decoder archs "
                    "(SSM state / encoder cross-K/V have no page structure)"
                )
            layout = PagedLayout.for_engine(
                serve.max_seq, max(n, 1), serve.num_slots,
                page_size=serve.page_size, num_pages=serve.num_pages,
            )
            self.allocator = PageAllocator(
                layout, quantized=self._quantized,
                oversubscribe=serve.oversubscribe,
            )
        # SSD's recurrent state has no pad-correction: prefill exactly
        exact = cfg.ssm is not None
        buckets = (
            tuple(serve.prefill_buckets)
            if serve.prefill_buckets
            else default_buckets(serve.max_seq, n)
        )
        if any(b % max(n, 1) for b in buckets) and not exact:
            raise ValueError(f"buckets {buckets} must be multiples of sp_size={n}")
        self.scheduler = Scheduler(
            self.num_slots, buckets, self.max_seq, exact=exact, multiple=n,
            chunk=cfg.ssm.chunk if exact else None, allocator=self.allocator,
            prefill_chunk=self.prefill_chunk,
            tick_token_budget=self.tick_token_budget,
        )
        # packed prefill: several same-tick admissions share one row under a
        # document mask (attention-only decoder archs; SSD state and per-row
        # frontend/encoder side inputs do not pack)
        self.pack_max = max(1, serve.pack_max)
        self._can_pack = (
            serve.pack_prefill
            and cfg.ssm is None
            and not cfg.encoder_layers
            and cfg.frontend is None
        )
        # the declarative attention plan this engine serves under (the
        # prefill path resolves its backend/tile through this via dispatch)
        self.attn_plan = dispatch.plan_from_ctx(
            self.ctx, causal=True, layout=cfg.causal_layout
        )
        # THE cache: allocated once here, threaded through prefill inserts
        # and decode steps for the engine's whole lifetime
        self._cache = tfm.init_cache(
            cfg, self.num_slots, self.max_seq, dtype=self.cache_dtype, ctx=self.ctx,
            paged=self.allocator.layout if self.allocator else None,
            kv_dtype=serve.kv_dtype,
        )
        self._cur = np.zeros((self.num_slots, 1), np.int32)  # last token per slot
        self._depth = np.zeros((self.num_slots,), np.int64)  # host view of pos
        # per-slot consecutive zero-accept verify ticks (speculative decode:
        # at spec_max_misses the slot stops drafting; reset on accept/admit)
        self._spec_misses = np.zeros((self.num_slots,), np.int64)
        self._shared_len = np.zeros((self.num_slots,), np.int64)  # paged prefix
        self._bt_version = -1  # device block table staleness marker
        self.bt_uploads = 0  # device block-table uploads (version-gated:
        # ticks whose appends stay inside a page re-upload nothing)
        self._tick = 0
        self._finished: Dict[int, RequestResult] = {}
        # jit bookkeeping: trace counters tick at TRACE time only, so tests
        # can assert the retrace count is bounded by the bucket set
        self._prefill_fns: Dict[int, object] = {}
        self.prefill_trace_counts: Dict[int, int] = {}
        self.decode_trace_count = 0
        self.chunk_trace_count = 0
        self.verify_trace_count = 0
        # launch accounting (every call, not just traces): the pack planner's
        # padded-prefill cost is launches x bucket tokens
        self.prefill_launches = 0
        self.prefill_launch_tokens = 0
        self.chunk_launches = 0
        self.chunk_launch_tokens = 0
        # speculative decode accounting (engine-wide; per-request twins live
        # on Request/RequestResult)
        self.verify_launches = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        # per-tick token series: PROMPT tokens ingested vs tokens GENERATED
        # (kept separate so a prefill-heavy tick cannot inflate decode
        # tokens/s — serve_bench reports both)
        self.tick_prefill_tokens: List[int] = []
        self.tick_decode_tokens: List[int] = []
        # debug logit capture (set BEFORE the first tick; read at trace time):
        # records every generated token's full logits row per rid so the
        # distributed quant check can bound per-token error vs an fp engine
        self.capture_logits = False
        self.debug_logits: Dict[int, List[np.ndarray]] = {}
        # robustness: oversubscribed preemption + lifecycle + fault guards
        self.nan_guard = serve.nan_guard
        self.health_every = serve.health_every
        self.chaos = chaos  # testing/chaos.py injector (None in production)
        self.preemptions = 0  # mid-decode evictions (pool pressure)
        self.recompute_tokens = 0  # tokens re-ingested for preempted requests
        self.cancelled = 0
        self.deadline_expired = 0
        self.numeric_errors = 0
        self.rejected_requests = 0
        self.health_sweeps = 0
        self.chaos_dropped_grants = 0
        self._decode = jax.jit(self._decode_traced)
        self._copy_pages = jax.jit(self._copy_pages_traced)
        self._chunk_step = jax.jit(self._chunk_traced)
        self._verify = jax.jit(self._verify_traced)

    # -- jitted paths -------------------------------------------------------

    def _decode_traced(self, params, cache, tokens):
        self.decode_trace_count += 1  # python side effect: trace-time only
        nxt, cache, logits = tfm.decode_step(
            params, cache, tokens, self.cfg, self.ctx
        )
        # per-slot finiteness bit for the NaN/Inf guard: reduced in-graph so
        # the host transfer is [B] bools, not the full logits
        ok = jnp.all(jnp.isfinite(logits), axis=(1, 2))
        return nxt, cache, logits, ok

    def _chunk_traced(self, params, cache, tokens, starts, lens, wstarts, pos_set):
        """Continuous prefill: append one [num_slots, prefill_chunk] chunk
        batch into the live cache — fixed operand shapes, so ONE trace serves
        every tick regardless of which slots have chunk work."""
        self.chunk_trace_count += 1  # python side effect: trace-time only
        batch = {
            "tokens": tokens,
            "starts": starts,
            "lens": lens,
            "write_starts": wstarts,
            "pos_set": pos_set,
        }
        logits, cache = tfm.prefill_chunk(params, self.cfg, self.ctx, batch, cache)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B]
        ok = jnp.all(jnp.isfinite(logits), axis=1)  # NaN guard (final chunks)
        if self.capture_logits:
            return cache, first, logits, ok
        return cache, first, ok

    def _verify_traced(self, params, cache, tokens, starts, lens):
        """Speculative verify: ONE fixed-shape [num_slots, spec_k] banded
        chunk launch scores every row's current token + draft, commits the
        longest accepted prefix in-graph (pos advances by the commit count),
        and returns the per-position greedy outputs.  lens=1 rows are
        exactly a vanilla one-token decode tick riding the same launch;
        lens=0 rows write nothing and keep their pos."""
        self.verify_trace_count += 1  # python side effect: trace-time only
        batch = {
            "tokens": tokens,
            "starts": starts,
            "lens": lens,
            # verify appends everything it scores: write start == band start
            "write_starts": starts,
        }
        y, commit, cache, logits = tfm.verify_step(
            params, self.cfg, self.ctx, batch, cache, return_logits=True,
        )
        # finiteness over the whole [K, V] block; only the reduced [B] bit
        # leaves the graph unless logits capture is on (XLA drops the rest)
        ok = jnp.all(jnp.isfinite(logits), axis=(1, 2))
        if self.capture_logits:
            return y, commit, cache, logits, ok
        return y, commit, cache, ok

    def _copy_pages_traced(self, cache, src, dst):
        """Copy-on-write: physical page src[i] -> dst[i] in every layer's
        pool.  Pad entries carry dst == num_pages, which the scatter drops;
        fixed [num_slots] operand shapes keep this a single trace."""
        out = dict(cache)
        # quantized pools copy the scale tables in lockstep with the pages:
        # a CoW'd page with stale scales would dequantize garbage
        for key in ("k", "v", "k_scale", "v_scale"):
            if key not in cache:
                continue
            pool = cache[key]  # [L, num_pages, n*ps, Hkv, D] (scales: no D)
            out[key] = pool.at[:, dst].set(pool[:, src], mode="drop")
        return out

    def _sync_block_table(self):
        """Upload the allocator's block table when it moved since last sync."""
        if self.allocator is None or self.allocator.version == self._bt_version:
            return
        self._cache = dict(self._cache)
        self._cache["bt"] = jnp.asarray(self.allocator.device_table(self.num_slots))
        self._bt_version = self.allocator.version
        self.bt_uploads += 1

    def _aux_inputs(self, batch_size: int) -> Dict:
        """Frontend stub inputs (audio frames / vision patches)."""
        extra = {}
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            extra["frames"] = jnp.zeros(
                (batch_size, cfg.encoder_seq, cfg.frontend_dim), jnp.float32
            )
        if cfg.frontend == "vision_stub":
            extra["patches"] = jnp.zeros(
                (batch_size, cfg.num_patches, cfg.frontend_dim), jnp.float32
            )
        return extra

    def _get_prefill(self, bucket: int):
        """Jitted (prefill into a fresh row + scatter into slot) per bucket."""
        if bucket in self._prefill_fns:
            return self._prefill_fns[bucket]
        cfg, ctx = self.cfg, self.ctx
        n = ctx.sp_size
        if self.attn_plan.autotune and n > 1:
            # resolve the (a, b) tile + schedules for this bucket geometry
            # through the on-disk plan cache BEFORE tracing, so repeated
            # serve launches skip the simulator entirely.  The key must match
            # what dispatch computes at trace time: activations inherit the
            # PARAM dtype (q flows from the embedding), not the cache dtype.
            act_dtype = jax.tree.leaves(self.params)[0].dtype
            dispatch.plan_schedules(
                self.attn_plan,
                CommModel(
                    seq=bucket,
                    hidden=cfg.num_heads * cfg.hd,
                    n=n,
                    kv_hidden=cfg.num_kv_heads * cfg.hd,
                    bytes_per_elem=jnp.dtype(act_dtype).itemsize,
                    batch=1,
                ),
            )
        if n > 1 and cfg.causal_layout == "striped":
            from repro.core.tiling import stripe_permutation

            perm = np.asarray(stripe_permutation(bucket, n))
        else:
            perm = np.arange(bucket)
        positions = jnp.asarray(perm, jnp.int32)
        self.prefill_trace_counts.setdefault(bucket, 0)

        def fn(params, cache, tokens, length, slot, shared_len):
            self.prefill_trace_counts[bucket] += 1  # trace-time only
            # striping is the serving analogue of the data pipeline's §3.7
            # permutation: token at index j carries true position perm[j]
            toks = tokens[:, perm]
            batch = {
                "tokens": toks,
                "positions": positions,
                "length": jnp.reshape(length, (1,)),
                **self._aux_inputs(1),
            }
            if self.paged:
                # the pool IS the cache: K/V scatter through slot's block-
                # table row; positions below shared_len stay with their owner
                batch["slot"] = slot
                batch["shared_len"] = shared_len
                logits, cache = tfm.prefill(params, cfg, ctx, batch, cache)
                first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [1,1]
                if self.capture_logits:
                    return cache, first, logits[0, 0]
                return cache, first
            row = tfm.init_cache(cfg, 1, self.max_seq, dtype=self.cache_dtype, ctx=ctx)
            logits, row = tfm.prefill(params, cfg, ctx, batch, row)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [1,1]

            def insert(big, small):
                ax = 1 if big.ndim > 1 else 0  # pos is [B]; all else [L,B,...]
                return lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=ax
                )

            merged = jax.tree.map(insert, cache, row)
            if self.capture_logits:
                return merged, first, logits[0, 0]
            return merged, first

        jitted = jax.jit(fn)
        self._prefill_fns[bucket] = jitted
        return jitted

    def _get_prefill_packed(self, bucket: int, k: int):
        """Jitted packed prefill for ``k`` documents sharing one ``bucket``
        row — scatters each document's K/V into its own slot.  Trace count
        keys are (bucket, k): retraces stay bounded by buckets x pack sizes,
        independent of the actual prompt-length mix."""
        key = (bucket, k)
        if key in self._prefill_fns:
            return self._prefill_fns[key]
        cfg, ctx = self.cfg, self.ctx
        n = ctx.sp_size
        if self.attn_plan.autotune and n > 1:
            # pre-resolve the segment-masked plan for this bucket geometry
            # through the on-disk cache (mask signature is part of the key)
            from repro.core.masking import MaskSpec

            act_dtype = jax.tree.leaves(self.params)[0].dtype
            plan = dispatch.plan_from_ctx(
                ctx, mask=MaskSpec.segment(window=cfg.window), layout=cfg.causal_layout
            )
            dispatch.plan_schedules(
                plan,
                CommModel(
                    seq=bucket,
                    hidden=cfg.num_heads * cfg.hd,
                    n=n,
                    kv_hidden=cfg.num_kv_heads * cfg.hd,
                    bytes_per_elem=jnp.dtype(act_dtype).itemsize,
                    batch=1,
                ),
            )
        if n > 1 and cfg.causal_layout == "striped":
            from repro.core.tiling import stripe_permutation

            perm = np.asarray(stripe_permutation(bucket, n))
        else:
            perm = np.arange(bucket)
        perm_j = jnp.asarray(perm)
        self.prefill_trace_counts.setdefault(key, 0)

        def fn(params, cache, tokens, doc_lens, slots, shared_lens):
            self.prefill_trace_counts[key] += 1  # trace-time only
            j = jnp.arange(bucket, dtype=jnp.int32)
            cum = jnp.cumsum(doc_lens)
            starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), cum[:-1]])
            seg = jnp.sum(j[:, None] >= starts[None, :], axis=1).astype(jnp.int32) - 1
            pad = j >= cum[-1]
            seg = jnp.where(pad, jnp.int32(k), seg)  # pads match nothing real
            positions = j - starts[jnp.clip(seg, 0, k - 1)]
            batch = {
                "tokens": tokens[:, perm],  # §3.7 stripe, as in the data pipeline
                "positions": positions[perm_j],
                "segments": seg[perm_j],
                "doc_lens": doc_lens,
                "slots": slots,
            }
            if self.paged:
                batch["shared_lens"] = shared_lens
            logits, cache = tfm.prefill_packed(params, cfg, ctx, batch, cache)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [k]
            if self.capture_logits:
                return cache, first, logits
            return cache, first

        jitted = jax.jit(fn)
        self._prefill_fns[key] = jitted
        return jitted

    # -- streaming API ------------------------------------------------------

    def submit(
        self, prompt: np.ndarray, max_new_tokens: int = 16, arrival_tick: int = 0,
        *, deadline_ticks: Optional[int] = None, priority: int = 0,
    ) -> int:
        """Queue one request; returns its rid.  ``arrival_tick`` defers
        admission until the engine clock reaches it (trace replay).
        ``deadline_ticks`` retires the request (status ``"deadline"``, partial
        tokens kept) once that many ticks pass from arrival; higher
        ``priority`` admits first (FIFO within a level)."""
        req = self.scheduler.submit(
            prompt, max_new_tokens, arrival_tick,
            deadline_ticks=deadline_ticks, priority=priority,
        )
        return req.rid

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def _finish(self, slot: int, status: str = "ok") -> RequestResult:
        req = self.scheduler.retire(slot, self._tick, status=status)
        freed: List[int] = []
        if self.allocator is not None:
            # drop the slot's page references; pages shared with live slots
            # survive until their last reader retires
            freed = self.allocator.free_slot(slot)
        if status == "numeric_error":
            self._scrub_numeric(slot, freed)
        result = RequestResult.from_request(req)
        self._finished[req.rid] = result
        return result

    def _scrub_numeric(self, slot: int, freed: List[int]) -> None:
        """Zero a numeric_error slot's K/V (quantized: also its scales)
        before the data can be re-read.  Stale FINITE garbage in freed pages
        is harmless — band-masked or overwritten before the band reaches it
        — but non-finite garbage is not: additive ``-inf`` mask bias keeps
        NaN NaN, so one retired slot's NaN could leak into other slots'
        scores through FREE-entry clamped page reads.  Shared pages (ref
        still > 0) are left alone: their content is live prefix data."""
        self._cache = dict(self._cache)
        keys = [k for k in ("k", "v", "k_scale", "v_scale") if k in self._cache]
        if self.allocator is not None:
            if not freed:
                return
            idx = jnp.asarray(freed, jnp.int32)
            for key in keys:
                self._cache[key] = self._cache[key].at[:, idx].set(0)
        else:
            for key in keys:
                self._cache[key] = self._cache[key].at[:, slot].set(0)

    def _finish_queued(self, req: Request) -> RequestResult:
        """Terminal path for a request that never held a slot this time
        around (cancelled / expired / rejected while queued).  A previously
        preempted request may still carry generated tokens — they ride along
        on the result."""
        req.finish_tick = self._tick
        result = RequestResult.from_request(req)
        self._finished[req.rid] = result
        return result

    def cancel(self, rid: int) -> Optional[RequestResult]:
        """Cancel a live request (queued or mid-flight).  Frees its slot and
        pages immediately; partial tokens are kept on the result (status
        ``"cancelled"``).  Returns the result, or None if the rid is not in
        flight (already finished or unknown)."""
        req = self.scheduler.cancel_queued(rid)
        if req is not None:
            self.cancelled += 1
            return self._finish_queued(req)
        req = self.scheduler.find(rid)
        if req is None or req.slot is None:
            return None
        self.cancelled += 1
        return self._finish(req.slot, status="cancelled")

    # -- robustness: preemption, fault guards, health -----------------------

    def _do_preempt(self, slot: int) -> List[int]:
        """Evict ``slot`` back to the queue under pool pressure: free its
        pages (refcounts keep prefix sharers' pages alive) and reset its
        ingest cursor so admission recomputes prompt + generated through
        continuous prefill.  Returns the physical pages whose refcount hit
        zero (the caller scrubs pending CoW copies against them)."""
        freed = self.allocator.free_slot(slot)
        req = self.scheduler.preempt(slot)
        req.preemptions += 1
        req.recompute_tokens += req.context_len
        self.preemptions += 1
        self.recompute_tokens += req.context_len
        self._shared_len[slot] = 0
        # park the stale row: paged writes already drop through the FREE
        # block-table row, parking additionally drops the pos-guard writes
        # and mirrors the mid-prefill convention
        self._cache = dict(self._cache)
        self._cache["pos"] = self._cache["pos"].at[slot].set(_PARKED)
        return freed

    def _preempt_for(self, protect) -> Optional[List[int]]:
        """Pick and evict one victim; None when nothing is evictable (the
        caller re-raises the pool exhaustion)."""
        if self.prefill_chunk is None:
            return None  # recompute rides continuous prefill only
        victim = select_victim(self.scheduler.slots, self.allocator, protect)
        if victim is None:
            return None
        return self._do_preempt(victim)

    def _ensure_append_robust(self, slot: int, pos: int, copies) -> None:
        """``ensure_append`` with preempt-and-retry: on pool exhaustion evict
        victims until the append fits (or nothing is left to evict).  Pending
        CoW copies whose destination page was freed by a preemption are
        scrubbed — the requester is gone, and the page may be re-issued
        within this same ensure phase."""
        while True:
            try:
                cp = self.allocator.ensure_append(slot, pos)
                if cp is not None:
                    copies.append(cp)
                return
            except PoolExhausted:
                freed = self._preempt_for(protect={slot})
                if freed is None:
                    raise
                drop = set(freed)
                copies[:] = [(s, d) for (s, d) in copies if d not in drop]

    def _ensure_span_robust(self, slot: int, start: int, count: int, copies) -> None:
        """``ensure_span`` with preempt-and-retry (speculative verify)."""
        chunk = self.allocator.layout.chunk
        if count <= 0:
            return
        for lp in range(start // chunk, (start + count - 1) // chunk + 1):
            if lp >= self.allocator.layout.max_pages:
                break
            self._ensure_append_robust(slot, max(start, lp * chunk), copies)

    def poison_slot_cache(self, slot: int) -> None:
        """Fault injection (testing/chaos.py): overwrite part of ``slot``'s
        resident K with NaN so its next attention pass produces non-finite
        logits — exercising the REAL in-graph guard path.  Batch rows are
        independent, so only this slot's stream is affected.  Quantized
        pools poison the f32 scale table (int8 codes cannot hold NaN)."""
        self._cache = dict(self._cache)
        if self.allocator is not None:
            held = self.allocator.slot_pages(slot)
            if held == 0:
                return
            pid = int(self.allocator.block_table[slot, 0])
            key = "k_scale" if "k_scale" in self._cache else "k"
            pool = self._cache[key]  # [L, num_pages, n*ps, ...]
            self._cache[key] = pool.at[:, pid, 0].set(jnp.nan)
        else:
            key = "k_scale" if "k_scale" in self._cache else "k"
            row = self._cache[key]  # [L, B, cap, ...]
            self._cache[key] = row.at[:, slot, 0].set(jnp.nan)

    def health(self) -> Dict[str, object]:
        """Invariant sweep: allocator refcounts/free list/scale lockstep plus
        engine-level slot cross-checks.  Raises on any violation; returns a
        summary dict when healthy.  Runs automatically every
        ``ServeConfig.health_every`` ticks."""
        self.health_sweeps += 1
        problems: List[str] = []
        if self.allocator is not None:
            problems += self.allocator.check_invariants()
            # every page-holding allocator slot must be a live scheduler slot
            for slot in self.allocator._slot_pages:
                if not (0 <= slot < self.num_slots):
                    problems.append(f"allocator holds pages for bad slot {slot}")
                elif self.scheduler.slots[slot] is None:
                    problems.append(
                        f"orphaned slot {slot}: holds "
                        f"{self.allocator.slot_pages(slot)} pages but no request"
                    )
            # ... and every ADMITTED paged request must hold pages (a request
            # still queued holds none; mid-prefill and decoding both do)
            for slot, req in enumerate(self.scheduler.slots):
                if req is not None and self.allocator.slot_pages(slot) == 0:
                    problems.append(
                        f"slot {slot} (rid {req.rid}) active without pages"
                    )
        if problems:
            raise RuntimeError(
                "engine.health() invariant sweep failed:\n  " + "\n  ".join(problems)
            )
        out = {
            "ok": True,
            "tick": self._tick,
            "active_slots": len(self.scheduler.active_slots()),
            "queued": self.scheduler.pending,
        }
        if self.allocator is not None:
            out.update(
                pages_in_use=self.allocator.pages_in_use,
                pages_reserved=self.allocator.pages_reserved,
                scale_entries_in_use=self.allocator.scale_entries_in_use,
            )
        return out

    def _req_done(self, req: Request, tok: int) -> bool:
        if self.eos_id is not None and tok == self.eos_id:
            return True
        return len(req.generated) >= req.max_new_tokens

    def _alloc_pages(self, slot: int, req: Request) -> int:
        """Paged admission: claim (or prefix-share) the slot's pages and sync
        the device block table BEFORE the prefill trace reads it.  A resumed
        (previously preempted) request allocates for its CONTEXT — prompt +
        generated — and only its REMAINING token budget.  Returns the
        shared-prefix length the scatter must skip."""
        alloc = self.allocator.alloc_slot(
            slot, req.context, req.remaining_new_tokens
        )
        return alloc.shared_len

    def _alloc_pages_robust(self, slot: int, req: Request) -> int:
        """Admission alloc with preempt-and-retry: under oversubscription the
        admission check only guaranteed PROMPT pages + margin, so a burst of
        same-tick admissions (or a chaos squeeze) can still find the free
        list short.  ``alloc_slot`` unwinds atomically on failure, so each
        retry starts from a clean slate."""
        while True:
            try:
                return self._alloc_pages(slot, req)
            except PoolExhausted:
                if self._preempt_for(protect={slot}) is None:
                    raise

    def _resident_shared_len(self, slot: int, shared: int) -> int:
        """Shared-prefix tokens whose CONTENT is already resident.

        Continuous prefill admits a sharer while its prefix donor may still
        be mid-chunk-ingestion: the shared pages are booked but their data
        hasn't been written, and a chunk that attended them would bake zeros
        into its deeper-layer KV writes.  Cap the credit at every
        mid-prefill donor's written watermark (page-aligned); the sharer
        recomputes and rewrites the rest of the prefix itself — identical
        values into the same physical pages, so the donor's own later
        writes are idempotent.  One-shot mode never needs this: a donor's
        full prefill launch always precedes a later sharer's admission."""
        lay = self.allocator.layout
        mine = {
            int(p) for p in self.allocator.block_table[slot, : lay.pages_for(shared)]
        }
        for s2, r2 in enumerate(self.scheduler.slots):
            if s2 == slot or r2 is None or r2.prefill_pos >= r2.ingest_len:
                continue
            if self.allocator.slot_pages(s2) == 0:
                continue  # admitted this tick, pages not allocated yet
            theirs = self.allocator.block_table[s2, : lay.pages_for(r2.ingest_len)]
            if mine & {int(p) for p in theirs}:
                shared = min(shared, (r2.prefill_pos // lay.chunk) * lay.chunk)
        return shared

    def _prefill_single(self, slot: int, req: Request) -> int:
        """Legacy one-row-per-request prefill (exact/frontend archs)."""
        bucket = self.scheduler.bucket_for(len(req.prompt))
        self.prefill_launches += 1
        self.prefill_launch_tokens += bucket
        toks = np.zeros((1, bucket), np.int32)
        toks[0, : len(req.prompt)] = req.prompt
        shared = self._alloc_pages(slot, req) if self.paged else 0
        self._sync_block_table()
        fn = self._get_prefill(bucket)
        out = fn(
            self.params,
            self._cache,
            jnp.asarray(toks),
            jnp.asarray(len(req.prompt), jnp.int32),
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(shared, jnp.int32),
        )
        if self.capture_logits:
            self._cache, first, row = out
            self.debug_logits.setdefault(req.rid, []).append(np.asarray(row))
        else:
            self._cache, first = out
        self._depth[slot] = len(req.prompt)
        return int(np.asarray(first)[0, 0])

    def _prefill_group(self, group) -> List[int]:
        """Packed prefill: the group's prompts concatenate into one bucket
        row under a document mask; each document's K/V lands in its own
        slot.  Returns the first generated token per request."""
        lens = [len(req.prompt) for _, req in group]
        bucket = self.scheduler.bucket_for(sum(lens))
        self.prefill_launches += 1
        self.prefill_launch_tokens += bucket
        k = len(group)
        toks = np.zeros((1, bucket), np.int32)
        off = 0
        for (_, req), ln in zip(group, lens):
            toks[0, off : off + ln] = req.prompt
            off += ln
        shared = [
            self._alloc_pages(slot, req) if self.paged else 0 for slot, req in group
        ]
        self._sync_block_table()
        fn = self._get_prefill_packed(bucket, k)
        out = fn(
            self.params,
            self._cache,
            jnp.asarray(toks),
            jnp.asarray(lens, jnp.int32),
            jnp.asarray([slot for slot, _ in group], jnp.int32),
            jnp.asarray(shared, jnp.int32),
        )
        if self.capture_logits:
            self._cache, firsts, rows = out
            rows_np = np.asarray(rows)
            for d, (_, req) in enumerate(group):
                self.debug_logits.setdefault(req.rid, []).append(rows_np[d])
        else:
            self._cache, firsts = out
        for (slot, req), ln in zip(group, lens):
            self._depth[slot] = ln
        return [int(t) for t in np.asarray(firsts)]

    def _record_first_token(self, slot: int, req: Request, tok: int, finished) -> None:
        """First generated token off prefill logits (one-shot or final
        chunk): same-tick bookkeeping shared by both ingestion modes.  For a
        RESUMED (preempted) request this is the first token past the
        recomputed context — TTFT keeps the original first-token tick."""
        req.generated.append(tok)
        req.token_ticks.append(self._tick)
        if req.first_token_tick is None:
            req.first_token_tick = self._tick
        self._cur[slot, 0] = tok
        if self._req_done(req, tok):
            finished.append(self._finish(slot))

    def _run_chunks(self, plan, finished) -> int:
        """Launch this tick's chunk plan as ONE fixed-shape [num_slots, C]
        jitted call; rows without work carry lens=0 (nothing written).  Rows
        whose LAST chunk this is get their cache position un-parked to the
        prompt length and sample their first token from the returned logits —
        the same tick a one-shot prefill would have.  Returns prompt tokens
        ingested."""
        C = self.prefill_chunk
        B = self.num_slots
        tokens = np.zeros((B, C), np.int32)
        starts = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        wstarts = np.zeros((B,), np.int32)
        pos_set = np.full((B,), -1, np.int32)
        finishing = []
        total = 0
        for slot, req, start, take in plan:
            ctx_toks = req.context  # prompt + generated (recompute on resume)
            tokens[slot, :take] = ctx_toks[start : start + take]
            starts[slot] = start
            lens[slot] = take
            wstarts[slot] = self._shared_len[slot]  # skip resident shared prefix
            if req.first_chunk_tick is None:
                req.first_chunk_tick = self._tick
            req.prefill_pos = start + take
            req.chunks += 1
            total += take
            if req.prefill_pos >= req.ingest_len:
                pos_set[slot] = req.ingest_len
                finishing.append((slot, req))
        self.chunk_launches += 1
        self.chunk_launch_tokens += B * C  # device tokens (incl. pad rows)
        self._sync_block_table()  # paged: admission allocated this plan's pages
        out = self._chunk_step(
            self.params, self._cache, jnp.asarray(tokens), jnp.asarray(starts),
            jnp.asarray(lens), jnp.asarray(wstarts), jnp.asarray(pos_set),
        )
        logits_np = None
        if self.capture_logits:
            self._cache, first, logits, ok = out
            logits_np = np.asarray(logits)
        else:
            self._cache, first, ok = out
        first_np = np.asarray(first)
        ok_np = np.asarray(ok)
        n_first = 0
        for slot, req in finishing:
            if self.nan_guard and not bool(ok_np[slot]):
                self.numeric_errors += 1
                finished.append(self._finish(slot, status="numeric_error"))
                continue
            self._depth[slot] = req.ingest_len
            if logits_np is not None:
                self.debug_logits.setdefault(req.rid, []).append(logits_np[slot])
            self._record_first_token(slot, req, int(first_np[slot]), finished)
            n_first += 1
        return total, n_first

    def _apply_copies(self, copies) -> None:
        """Run queued CoW page copies through the jitted scatter (fixed
        [num_slots] operand shape; pad rows carry dst == num_pages which the
        scatter drops).  Batches of more than num_slots copies launch in
        waves."""
        if not copies:
            return
        npages = self.allocator.layout.num_pages
        for off in range(0, len(copies), self.num_slots):
            wave = copies[off : off + self.num_slots]
            src = np.zeros((self.num_slots,), np.int32)
            dst = np.full((self.num_slots,), npages, np.int32)  # dropped
            for i, (s, d) in enumerate(wave):
                src[i], dst[i] = s, d
            self._cache = self._copy_pages(
                self._cache, jnp.asarray(src), jnp.asarray(dst)
            )

    def _vanilla_decode_tick(self, decodable, finished) -> int:
        """One plain decode launch over every decodable slot; returns tokens
        generated this tick."""
        if self.paged:
            # make every decodable slot's write position appendable:
            # allocate tail pages on chunk boundaries, CoW shared tails.
            # Under oversubscription (or a chaos squeeze) an allocation may
            # find the pool dry — preempt victims and retry; a preempted
            # slot drops out of this tick's decodable set
            copies = []
            for slot in decodable:
                if self.scheduler.slots[slot] is None:
                    continue  # preempted by an earlier slot's ensure
                self._ensure_append_robust(slot, int(self._depth[slot]), copies)
            decodable = [s for s in decodable if self.scheduler.slots[s] is not None]
            self._apply_copies(copies)
            self._sync_block_table()
            if not decodable:
                return 0
        if self._quantized and not self._native_decode:
            self.dequant_fallbacks += 1  # gather-path dequant served this tick
        nxt, self._cache, logits, ok = self._decode(
            self.params, self._cache, jnp.asarray(self._cur)
        )
        nxt_np = np.asarray(nxt)
        ok_np = np.asarray(ok)
        logits_np = np.asarray(logits) if self.capture_logits else None
        tokens = 0
        for slot in decodable:
            req = self.scheduler.slots[slot]
            if self.nan_guard and not bool(ok_np[slot]):
                # non-finite logits: retire ONLY this slot; every other row's
                # token came off the same launch and is bitwise what it would
                # have been (batch rows are independent)
                self.numeric_errors += 1
                finished.append(self._finish(slot, status="numeric_error"))
                continue
            self._depth[slot] += 1
            tok = int(nxt_np[slot, 0])
            if logits_np is not None:
                self.debug_logits.setdefault(req.rid, []).append(logits_np[slot, 0])
            req.generated.append(tok)
            req.token_ticks.append(self._tick)
            tokens += 1
            self._cur[slot, 0] = tok
            if self._req_done(req, tok):
                finished.append(self._finish(slot))
        return tokens

    def _spec_decode_tick(self, decodable, finished, prefill_tokens) -> int:
        """Speculative tick: draft per slot (prompt-lookup n-gram), verify
        every decodable row's current token + granted draft in ONE
        [num_slots, spec_k] banded launch, commit the longest accepted
        prefix.  Token stream is identical to vanilla greedy decode; only
        the commit count per tick changes.  Falls back to the plain decode
        launch when no slot has a granted draft (cold history, drafting
        suspended after ``spec_max_misses`` dry ticks, or no leftover tick
        budget) — so low-acceptance traffic degrades to baseline, not
        below it.  Returns tokens generated this tick."""
        drafts = {}
        for slot in decodable:
            if self.spec_max_misses is not None:
                m = self._spec_misses[slot]
                period = 16 * self.spec_max_misses
                if m >= self.spec_max_misses:
                    # tripped: suspend drafting until the next global probe
                    # boundary (negative counter counts the cooldown down).
                    # Aligning every slot's wake-up to tick % period == 0
                    # batches probes into ONE shared verify launch — a verify
                    # tick costs the whole batch, so staggered per-slot
                    # probes would each bill a full launch for one row.
                    self._spec_misses[slot] = -(period - self._tick % period)
                    continue
                if m < 0:
                    # cooldown lands on max_misses-1: ONE missed probe
                    # re-trips immediately, a fully-accepted probe
                    # re-enables drafting outright
                    self._spec_misses[slot] = (
                        self.spec_max_misses - 1 if m == -1 else m + 1
                    )
                    continue
            req = self.scheduler.slots[slot]
            # cap so the furthest write position stays inside the slot's
            # reserved capacity: at most max_new_tokens positions past prompt
            rem = req.max_new_tokens - len(req.generated)
            k_cap = min(self.spec_k, rem)
            if k_cap < 2:
                continue
            d = propose_ngram(req.prompt, req.generated, k_cap - 1)
            if d:
                drafts[slot] = d
        # draft tokens only spend LEFTOVER tick budget: decode rows and chunk
        # tokens were planned first, so the PR6 TTFT bound is untouched
        granted = self.scheduler.plan_spec(drafts, len(decodable), prefill_tokens)
        granted = {s: d for s, d in granted.items() if d}
        if not granted:
            return self._vanilla_decode_tick(decodable, finished)
        K = self.spec_k
        B = self.num_slots
        tokens = np.zeros((B, K), np.int32)
        starts = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        for slot in decodable:
            d = granted.get(slot, [])
            tokens[slot, 0] = self._cur[slot, 0]
            tokens[slot, 1 : 1 + len(d)] = d
            starts[slot] = self._depth[slot]
            lens[slot] = 1 + len(d)
        if self.paged:
            copies = []
            for slot in decodable:
                if self.scheduler.slots[slot] is None:
                    continue  # preempted by an earlier slot's ensure
                self._ensure_span_robust(
                    slot, int(self._depth[slot]), int(lens[slot]), copies
                )
            live = [s for s in decodable if self.scheduler.slots[s] is not None]
            if len(live) < len(decodable):
                for s in decodable:
                    if self.scheduler.slots[s] is None:
                        lens[s] = 0  # preempted rows write/commit nothing
                decodable = live
            self._apply_copies(copies)
            self._sync_block_table()
            if not decodable:
                return 0
        for slot in decodable:
            d = granted.get(slot, [])
            if d:
                req = self.scheduler.slots[slot]
                req.spec_proposed += len(d)
                self.spec_proposed += len(d)
        self.verify_launches += 1
        if self._quantized and not self._native_decode:
            self.dequant_fallbacks += 1  # gather-path dequant served this tick
        out = self._verify(
            self.params,
            self._cache,
            jnp.asarray(tokens),
            jnp.asarray(starts),
            jnp.asarray(lens),
        )
        logits_np = None
        if self.capture_logits:
            y, commit, self._cache, v_logits, ok = out
            logits_np = np.asarray(v_logits)
        else:
            y, commit, self._cache, ok = out
        y_np = np.asarray(y)
        commit_np = np.asarray(commit)
        ok_np = np.asarray(ok)
        generated = 0
        for slot in decodable:
            req = self.scheduler.slots[slot]
            if self.nan_guard and not bool(ok_np[slot]):
                # non-finite verify logits: commit nothing for this slot,
                # retire it alone (other rows commit bitwise-unchanged)
                self.numeric_errors += 1
                finished.append(self._finish(slot, status="numeric_error"))
                continue
            committed = int(commit_np[slot])
            drafted = int(lens[slot]) - 1
            if drafted:
                accepted = committed - 1  # draft tokens that matched greedy
                req.spec_accepted += accepted
                self.spec_accepted += accepted
                # a MISS is any verify tick with a rejection: the accept
                # distribution is bimodal (a live loop verifies fully, a
                # cold history verifies ~nothing), so full-accept cleanly
                # splits the regimes — and partial-accept ticks barely pay
                # for the batch-wide verify launch anyway
                if accepted == drafted:
                    self._spec_misses[slot] = 0
                else:
                    self._spec_misses[slot] += 1
            self._depth[slot] += committed
            done = False
            for i in range(committed):
                tok = int(y_np[slot, i])
                if logits_np is not None:
                    self.debug_logits.setdefault(req.rid, []).append(
                        logits_np[slot, i]
                    )
                req.generated.append(tok)
                req.token_ticks.append(self._tick)  # same tick: all one launch
                generated += 1
                self._cur[slot, 0] = tok
                if self._req_done(req, tok):
                    # EOS (or cap) mid-commit: later accepted tokens are
                    # discarded; their cache writes sit past the final depth
                    # and are band-invisible / freed by the rollback below
                    self._depth[slot] -= committed - (i + 1)
                    done = True
                    finished.append(self._finish(slot))
                    break
            if done:
                continue
            if self.paged and drafted:
                # free pages the verify wrote past the accepted prefix —
                # sharers never see them (append pages are never registered
                # for prefix sharing), but held rejected pages would leak
                # capacity until retirement.  No device sync here: every
                # launch site re-syncs the block table before launching.
                self.allocator.rollback(slot, int(self._depth[slot]))
        return generated

    def step(self) -> List[RequestResult]:
        """One engine tick: admission, prompt ingestion, then one jitted
        decode over every decodable slot.  Returns requests finished this
        tick (as ``RequestResult``).

        Legacy mode ingests each admission in ONE bucketed prefill launch
        (same-tick admissions PACK into shared rows under a document mask).
        Continuous mode (``serve.prefill_chunk``) parks newly admitted slots
        past cache capacity and streams their prompt in ``prefill_chunk``-
        token chunks under ``serve.tick_token_budget``; a slot joins the
        decode batch the same tick its last chunk lands."""
        finished: List[RequestResult] = []
        prefill_tokens = 0
        decode_tokens = 0
        # 0. fault injection (testing only) + lifecycle expiry
        if self.chaos is not None:
            self.chaos.on_tick(self)
        for req in self.scheduler.take_expired(self._tick):
            self.deadline_expired += 1
            finished.append(self._finish_queued(req))
        for slot, req in enumerate(self.scheduler.slots):
            if (
                req is not None
                and req.deadline_ticks is not None
                and self._tick - req.arrival_tick >= req.deadline_ticks
            ):
                self.deadline_expired += 1
                finished.append(self._finish(slot, status="deadline"))
        # 1. admission + prompt ingestion
        assigned = self.scheduler.admit(self._tick)
        for req in self.scheduler.take_rejected():
            self.rejected_requests += 1
            finished.append(self._finish_queued(req))
        for slot, _ in assigned:
            self._spec_misses[slot] = 0  # fresh request: drafting re-enabled
        if self.prefill_chunk is not None:
            for slot, req in assigned:
                shared = 0
                if self.paged:
                    try:
                        shared = self._alloc_pages_robust(slot, req)
                    except PoolExhausted:
                        # nothing evictable (fresh squeeze / lone giant):
                        # hand the slot back and retry on a later tick
                        self.scheduler.preempt(slot)
                        continue
                if shared:
                    shared = self._resident_shared_len(slot, shared)
                self._shared_len[slot] = shared
                # fully-shared chunks never launch, but the LAST context token
                # always runs forward — its logits seed the first decode
                req.prefill_pos = min(shared, req.ingest_len - 1)
            if assigned:
                # park mid-prefill rows so the shared decode's writes drop
                idx = jnp.asarray([slot for slot, _ in assigned], jnp.int32)
                self._cache = dict(self._cache)
                self._cache["pos"] = self._cache["pos"].at[idx].set(_PARKED)
            decodable = [
                s
                for s in self.scheduler.active_slots()
                if self.scheduler.slots[s].prefill_pos
                >= self.scheduler.slots[s].ingest_len
            ]
            plan = self.scheduler.plan_chunks(len(decodable))
            if plan and self.chaos is not None and self.chaos.drop_grants(self._tick):
                # injected scheduler fault: this tick's chunk grants vanish;
                # progress resumes next tick (the head-of-line guarantee is
                # per-plan, so a dropped plan only delays, never deadlocks)
                self.chaos_dropped_grants += len(plan)
                plan = []
            if plan:
                ingested, n_first = self._run_chunks(plan, finished)
                prefill_tokens += ingested
                decode_tokens += n_first  # first tokens off final-chunk logits
                # final chunks join the decode batch this same tick
                decodable = [
                    s
                    for s in self.scheduler.active_slots()
                    if self.scheduler.slots[s].prefill_pos
                    >= self.scheduler.slots[s].ingest_len
                ]
        else:
            if self._can_pack:
                groups = self.scheduler.pack_groups(
                    assigned, pack_max=self.pack_max, plan=self.pack_plan
                )
            else:
                groups = [[x] for x in assigned]
            for group in groups:
                if self._can_pack:
                    firsts = self._prefill_group(group)
                else:
                    firsts = [self._prefill_single(slot, req) for slot, req in group]
                for tok, (slot, req) in zip(firsts, group):
                    req.prefill_pos = len(req.prompt)
                    req.chunks = 1
                    req.first_chunk_tick = self._tick
                    prefill_tokens += len(req.prompt)
                    decode_tokens += 1  # first token off the prefill logits
                    self._record_first_token(slot, req, tok, finished)
            decodable = self.scheduler.active_slots()
        # 2. one decode step over every decodable slot (mixed depths via
        # pos: [B]; mid-prefill rows ride along parked, writes dropped).
        # Speculative mode turns the decode launch into a [slots, spec_k]
        # verify launch whenever any slot has a granted draft.
        if decodable:
            if self._spec_on:
                decode_tokens += self._spec_decode_tick(
                    decodable, finished, prefill_tokens
                )
            else:
                decode_tokens += self._vanilla_decode_tick(decodable, finished)
        self.tick_prefill_tokens.append(prefill_tokens)
        self.tick_decode_tokens.append(decode_tokens)
        self._tick += 1
        if self.health_every and self._tick % self.health_every == 0:
            self.health()  # raises on any invariant violation
        return finished

    def run(self) -> Dict[int, RequestResult]:
        """Drain the queue; returns {rid: RequestResult}."""
        while self.has_work:
            self.step()
        return dict(self._finished)

    def tick_stats(self) -> Dict[str, object]:
        """Per-tick token series: prompt tokens ingested (one-shot prefill or
        chunk launches) vs tokens generated, kept separate so prefill ticks
        cannot inflate decode tokens/s."""
        return {
            "ticks": self._tick,
            "prefill_tokens": list(self.tick_prefill_tokens),
            "decode_tokens": list(self.tick_decode_tokens),
        }

    def kv_cache_stats(self) -> Dict[str, float]:
        """Attention-cache memory accounting (bench / capacity planning).
        Dense: bytes are fixed at ``num_slots x max_seq``.  Paged: resident
        bytes follow the allocator's peak page usage, and the allocator's
        sharing/CoW counters ride along."""
        cfg = self.cfg
        spec = {
            "spec_proposed": float(self.spec_proposed),
            "spec_accepted": float(self.spec_accepted),
            "spec_accept_rate": (
                self.spec_accepted / self.spec_proposed if self.spec_proposed else 0.0
            ),
            "verify_launches": float(self.verify_launches),
            # robustness counters (ISSUE 10): ride along on every branch so
            # serve_bench / launch summaries need no allocator special-casing
            "preemptions": float(self.preemptions),
            "recompute_tokens": float(self.recompute_tokens),
            "cancelled": float(self.cancelled),
            "deadline_expired": float(self.deadline_expired),
            "numeric_errors": float(self.numeric_errors),
            "rejected_requests": float(self.rejected_requests),
            "health_sweeps": float(self.health_sweeps),
            "chaos_dropped_grants": float(self.chaos_dropped_grants),
        }
        if cfg.family == "ssm":
            return {"cache_bytes": 0.0, **spec}
        L = cfg.num_layers
        # the POOL's storage width, not cache_dtype: a quantized pool stores
        # int8/fp8 elements with f32 scales accounted separately below
        itemsize = jnp.dtype(self._cache["k"].dtype).itemsize
        hkv = self._cache["k"].shape[-2]
        elem = self._cache["k"].shape[-1] + self._cache["v"].shape[-1]  # dk + dv
        per_tok = L * hkv * elem * itemsize
        # per-(token, kv-head) scale entries: one f32 each for K and V
        scale_per_tok = (
            L * hkv * 2 * jnp.dtype(self._cache["k_scale"].dtype).itemsize
            if "k_scale" in self._cache else 0
        )
        if self.allocator is None:
            return {
                "paged": 0,
                "cache_bytes": float(self.num_slots * self.max_seq * per_tok),
                # dense rollback frees nothing: rejected positions are simply
                # band-invisible and get rewritten in place
                "spec_rolled_back_pages": 0.0,
                **spec,
            }
        lay = self.allocator.layout
        stats = self.allocator.stats()
        return {
            "paged": 1,
            "page_size": lay.page_size,
            "chunk_tokens": lay.chunk,
            "num_pages": lay.num_pages,
            # pool reservation (what init_cache actually allocated) ...
            "cache_bytes": float(lay.num_pages * lay.chunk * per_tok),
            # ... vs what the workload actually touched
            "peak_page_bytes": float(stats["peak_in_use"] * lay.chunk * per_tok),
            "bt_uploads": float(self.bt_uploads),
            # quantized pool: scale-table reservation + gather-ref fallbacks
            "scale_table_bytes": float(lay.num_pages * lay.chunk * scale_per_tok),
            "dequant_fallbacks": float(self.dequant_fallbacks),
            **{k: float(v) for k, v in stats.items()},
            **spec,
        }

    # -- legacy static-batch API --------------------------------------------

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16) -> np.ndarray:
        """prompts: [B, S0] int32.  Greedy decoding; returns [B,
        max_new_tokens].  A thin wrapper over the streaming path: B requests
        arrive at once and are served by the slot pool (in waves when B >
        num_slots).  The striped prompt permutation (§3.7) happens inside the
        bucketed prefill."""
        prompts = np.asarray(prompts, np.int32)
        rids = [self.submit(prompts[i], max_new_tokens, self._tick) for i in range(len(prompts))]
        self.run()
        out = []
        for rid in rids:
            row = self._finished.pop(rid).generated[:max_new_tokens]
            row = row + [self.eos_id or 0] * (max_new_tokens - len(row))
            out.append(row)
        return np.asarray(out, np.int32)
