"""Paged KV-cache pool: block tables, refcounted pages, prefix sharing.

The dense engine stores each slot's KV as a full ``[cap]`` row, so memory is
``num_slots x max_seq`` no matter how deep any request actually is, and two
requests sharing a system prompt materialize it twice.  This module virtualizes
the slot rows over a fixed **physical page pool**:

  * device side — per layer, ``[num_pages, n*page_size, Hkv, D]`` where the
    middle axis is sharded over the sequence-parallel axis exactly like the
    dense cap axis.  One *logical* page therefore covers ``n * page_size``
    consecutive global positions (``page_size`` local positions per shard),
    which keeps the striped owner math of ``core/decode_attention.py`` intact:
    owner shard -> (page, offset) instead of owner shard -> slot row.
  * host side — this module: an int32 block table ``[num_slots, max_pages]``
    mapping each slot's logical page to a physical page, a refcount per page,
    a free list, and a **prefix registry** (hash of the first ``c`` page-chunks
    of a prompt -> live physical pages) so identical prompt prefixes are
    admitted as shared, refcounted pages instead of fresh copies.

The allocator is pure bookkeeping (numpy, no jax): the engine threads the
block table through the jitted step as a device operand and applies the
allocator's page-copy instructions (copy-on-write) in a tiny jitted scatter.
All decisions are made *before* a step is traced/run, so jit signatures stay
static and retraces stay bounded exactly as in the dense engine.

Sharing granularity is one logical page (= ``n * page_size`` tokens): only
whole page-chunks of a prompt are registered/matched, and a slot's first
append position is at or past its prompt length, so under today's engine flow
an append NEVER lands inside a shared page.  Copy-on-write is nevertheless
part of the allocator contract — ``ensure_append`` returns a ``(src, dst)``
physical copy whenever the target page has refcount > 1, and the engine
applies it before writing — so finer-granularity sharing (partial-chunk
prefix match, suffix dedup) can land without a correctness cliff; the unit
tests exercise the CoW path directly.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PagedLayout", "PageAllocator", "gather_block_table"]


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of a paged KV pool.

    ``page_size`` counts LOCAL positions per shard per page; one logical page
    spans ``chunk = n * page_size`` consecutive global positions.  A slot's
    virtual capacity stays ``max_seq`` (= ``max_pages * chunk``), so all the
    band/owner math of the dense cache carries over unchanged.
    """

    num_pages: int  # physical pages in the pool (shared by all slots)
    page_size: int  # local positions per page (per device)
    max_pages: int  # logical pages per slot (virtual cap = max_pages * chunk)
    n: int = 1  # sequence-parallel size the pool is sharded over

    def __post_init__(self):
        if min(self.num_pages, self.page_size, self.max_pages, self.n) < 1:
            raise ValueError(f"invalid paged layout {self}")

    @property
    def chunk(self) -> int:
        """Global positions covered by one logical page."""
        return self.n * self.page_size

    @property
    def virtual_cap(self) -> int:
        return self.max_pages * self.chunk

    def pages_for(self, length: int) -> int:
        """Logical pages needed to hold ``length`` global positions."""
        return -(-max(int(length), 0) // self.chunk)

    @staticmethod
    def for_engine(
        max_seq: int, n: int, num_slots: int,
        page_size: Optional[int] = None, num_pages: Optional[int] = None,
    ) -> "PagedLayout":
        """Engine default: virtual cap == max_seq; pool sized to the dense
        cache (num_slots * max_pages) unless the caller asks for less."""
        if page_size is None:
            page_size = max(1, min(16, max_seq // max(n, 1)))
        if (max_seq % (n * page_size)) != 0:
            raise ValueError(
                f"max_seq={max_seq} must be divisible by n*page_size={n * page_size}"
            )
        max_pages = max_seq // (n * page_size)
        return PagedLayout(
            num_pages=num_pages if num_pages is not None else num_slots * max_pages,
            page_size=page_size,
            max_pages=max_pages,
            n=n,
        )


def _prefix_key(prompt: np.ndarray, upto: int) -> bytes:
    """Chain hash of the first ``upto`` tokens (position 0 anchored, so RoPE
    phases match by construction)."""
    return hashlib.sha1(np.ascontiguousarray(prompt[:upto], np.int32).tobytes()).digest()


@dataclasses.dataclass
class SlotAlloc:
    """What an admission got: which logical pages are shared (prefill must
    NOT overwrite them — the owner's K/V is already there, byte-identical by
    causality) and how many tokens they cover."""

    shared_pages: int
    shared_len: int  # = shared_pages * chunk


class PageAllocator:
    """Refcounted page allocator + prefix registry over a ``PagedLayout``.

    All methods mutate host state only; device mutations are communicated as
    return values (block-table rows, copy pairs) for the engine to apply.
    """

    FREE = -1

    def __init__(self, layout: PagedLayout, quantized: bool = False):
        self.layout = layout
        # quantized pools carry a scale tile per physical page (side table
        # indexed by the same block table); its liveness is counted
        # INDEPENDENTLY of the free list so "scales drain with pages" is a
        # real invariant, not a tautology
        self.quantized = bool(quantized)
        self.scale_entries_in_use = 0
        self.block_table = np.full((0, layout.max_pages), self.FREE, np.int32)
        self.ref = np.zeros((layout.num_pages,), np.int32)
        self.gen = np.zeros((layout.num_pages,), np.int64)  # bumped on free
        self._free: List[int] = list(range(layout.num_pages - 1, -1, -1))
        # slot -> logical page count currently allocated
        self._slot_pages: Dict[int, int] = {}
        # slot -> pages reserved for its full lifetime (admission guarantee)
        self._reserved: Dict[int, int] = {}
        # prefix registry: chain-hash -> (physical page, generation stamp)
        self._prefix: Dict[bytes, Tuple[int, int]] = {}
        # stats
        self.fresh_allocs = 0  # pages taken off the free list, ever
        self.shared_hits = 0  # pages admitted by prefix match instead
        self.cow_copies = 0
        self.spec_rolled_back = 0  # pages freed by speculative rollback
        self.peak_in_use = 0
        # bumped on every block-table mutation: the engine re-uploads the
        # device table only when this moved since the last sync
        self.version = 0

    # -- introspection ------------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        return self.layout.num_pages - len(self._free)

    @property
    def pages_reserved(self) -> int:
        return sum(self._reserved.values())

    def slot_pages(self, slot: int) -> int:
        return self._slot_pages.get(slot, 0)

    # -- admission ----------------------------------------------------------

    def reserve_for(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case lifetime pages for a request (sharing not discounted:
        a shared page may need a private copy at any time)."""
        return self.layout.pages_for(prompt_len + max_new_tokens)

    def can_admit(self, prompt_len: int, max_new_tokens: int, pending: int = 0) -> bool:
        """Page-accounted admission: every admitted request must be able to
        reach its token budget without mid-flight pool exhaustion.
        ``pending`` carries pages already promised to requests admitted
        earlier in the same tick (their ``alloc_slot`` hasn't run yet)."""
        need = self.reserve_for(prompt_len, max_new_tokens)
        return self.pages_reserved + pending + need <= self.layout.num_pages

    # -- lifecycle ----------------------------------------------------------

    def _ensure_rows(self, slot: int):
        if slot >= len(self.block_table):
            grow = np.full(
                (slot + 1 - len(self.block_table), self.layout.max_pages),
                self.FREE, np.int32,
            )
            self.block_table = np.concatenate([self.block_table, grow])

    def _take_page(self) -> int:
        if not self._free:
            raise RuntimeError(
                "page pool exhausted — admission accounting should have "
                "rejected this request (allocator bug or un-reserved caller)"
            )
        pid = self._free.pop()
        self.ref[pid] = 1
        self.fresh_allocs += 1
        if self.quantized:
            self.scale_entries_in_use += 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pid

    def _release_page(self, pid: int):
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self.gen[pid] += 1  # invalidate any prefix-registry entries
            self._free.append(pid)
            if self.quantized:
                self.scale_entries_in_use -= 1
        elif self.ref[pid] < 0:
            raise RuntimeError(f"double free of page {pid}")

    def alloc_slot(self, slot: int, prompt: np.ndarray, max_new_tokens: int) -> SlotAlloc:
        """Admit a prompt into ``slot``: match whole page-chunks of its prefix
        against the registry (share, +ref), allocate fresh pages for the rest
        of the prompt, register its own full chunks, and reserve its lifetime
        page budget.  Returns what prefill may skip writing."""
        if self._slot_pages.get(slot, 0):
            raise ValueError(f"slot {slot} still holds pages; free_slot first")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        need = self.reserve_for(len(prompt), max_new_tokens)
        if self.pages_reserved + need > self.layout.num_pages:
            raise RuntimeError(
                f"admission without capacity: need {need} pages, "
                f"{self.layout.num_pages - self.pages_reserved} unreserved"
            )
        self._ensure_rows(slot)
        chunk = self.layout.chunk
        n_pages = self.layout.pages_for(len(prompt))
        full = len(prompt) // chunk  # whole chunks eligible for sharing
        shared = 0
        for c in range(full):
            key = _prefix_key(prompt, (c + 1) * chunk)
            hit = self._prefix.get(key)
            if hit is None:
                break
            pid, stamp = hit
            if self.ref[pid] <= 0 or self.gen[pid] != stamp:
                del self._prefix[key]  # stale: owner retired since
                break
            self.block_table[slot, c] = pid
            self.ref[pid] += 1
            self.shared_hits += 1
            shared = c + 1
        for c in range(shared, n_pages):
            pid = self._take_page()
            self.block_table[slot, c] = pid
            if c < full:  # register this slot's own full chunks
                self._prefix[_prefix_key(prompt, (c + 1) * chunk)] = (
                    pid, int(self.gen[pid]),
                )
        self._slot_pages[slot] = n_pages
        self._reserved[slot] = need
        self.version += 1
        return SlotAlloc(shared_pages=shared, shared_len=shared * chunk)

    def ensure_append(self, slot: int, pos: int) -> Optional[Tuple[int, int]]:
        """Make position ``pos`` writable for ``slot`` before a decode tick:
        allocate the next logical page on a chunk boundary, and copy-on-write
        when the target page is shared.  Returns an optional ``(src, dst)``
        physical page copy the engine must apply to the device pool."""
        lp = pos // self.layout.chunk
        if lp >= self.layout.max_pages:
            return None  # past virtual capacity: the write masks off anyway
        held = self._slot_pages.get(slot, 0)
        if lp >= held:
            if lp != held:
                raise ValueError(f"non-contiguous append: slot {slot} pos {pos}")
            self.block_table[slot, lp] = self._take_page()
            self._slot_pages[slot] = held + 1
            self.version += 1
            return None
        pid = int(self.block_table[slot, lp])
        if self.ref[pid] > 1:  # shared tail: private copy before writing
            dst = self._take_page()
            self.ref[pid] -= 1
            self.block_table[slot, lp] = dst
            self.cow_copies += 1
            self.version += 1
            return (pid, dst)
        return None

    def ensure_span(self, slot: int, start: int, count: int) -> List[Tuple[int, int]]:
        """Make positions ``start .. start + count - 1`` writable for ``slot``
        — the multi-token (speculative verify) analogue of ``ensure_append``:
        walk the span's logical pages in order, allocating tail pages and
        CoW-ing shared ones.  Returns every ``(src, dst)`` physical copy the
        engine must apply before the write."""
        copies: List[Tuple[int, int]] = []
        if count <= 0:
            return copies
        chunk = self.layout.chunk
        for lp in range(start // chunk, (start + count - 1) // chunk + 1):
            if lp >= self.layout.max_pages:
                break  # past virtual capacity: those writes mask off anyway
            cp = self.ensure_append(slot, max(start, lp * chunk))
            if cp is not None:
                copies.append(cp)
        return copies

    def rollback(self, slot: int, keep_len: int) -> int:
        """Free every page of ``slot`` beyond what ``keep_len`` committed
        positions need — rejected speculative tokens become page frees, not
        cache rewrites.  Stale K/V inside the kept tail page is harmless:
        the band never reads past ``pos``, and every position is rewritten
        before ``pos`` reaches it.  Speculative pages are never in the
        prefix registry (only ``alloc_slot`` registers, and only full prompt
        chunks), so sharers can never have mapped what is freed here.
        Returns the number of pages freed."""
        held = self._slot_pages.get(slot, 0)
        target = self.layout.pages_for(keep_len)
        freed = 0
        for lp in range(held - 1, target - 1, -1):
            self._release_page(int(self.block_table[slot, lp]))
            self.block_table[slot, lp] = self.FREE
            freed += 1
        if freed:
            self._slot_pages[slot] = target
            self.spec_rolled_back += freed
            self.version += 1
        return freed

    def free_slot(self, slot: int):
        """Retire a slot: drop its references; pages survive while shared."""
        held = self._slot_pages.pop(slot, 0)
        for c in range(held):
            self._release_page(int(self.block_table[slot, c]))
        self.block_table[slot, :held] = self.FREE
        self._reserved.pop(slot, None)
        if held:
            self.version += 1

    # -- device view --------------------------------------------------------

    def device_table(self, num_slots: int) -> np.ndarray:
        """Block table padded/clipped to the engine's slot count.  FREE (-1)
        entries mean "unallocated"; device code clamps them to page 0, whose
        contents are hidden by the position band."""
        self._ensure_rows(num_slots - 1)
        return np.array(self.block_table[:num_slots], np.int32)

    def stats(self) -> Dict[str, int]:
        return {
            "pages_in_use": self.pages_in_use,
            "peak_in_use": self.peak_in_use,
            "fresh_allocs": self.fresh_allocs,
            "shared_hits": self.shared_hits,
            "cow_copies": self.cow_copies,
            "spec_rolled_back_pages": self.spec_rolled_back,
            "quantized_pages": self.pages_in_use if self.quantized else 0,
            "scale_entries_in_use": self.scale_entries_in_use,
        }


def gather_block_table(pool: np.ndarray, table: np.ndarray) -> np.ndarray:
    """Numpy oracle: materialize the dense per-slot view a block table
    describes.  ``pool``: [num_pages, n*page_size, ...]; ``table``: [slots,
    max_pages].  Returns [slots, max_pages * n*page_size, ...] with
    unallocated pages zero-filled (they are invisible behind the band)."""
    pool = np.asarray(pool)
    table = np.asarray(table)
    padded = np.concatenate([pool, np.zeros_like(pool[:1])])
    idx = np.where(table < 0, pool.shape[0], table)
    out = padded[idx]  # [slots, max_pages, n*ps, ...]
    return out.reshape((table.shape[0], -1) + pool.shape[2:])
