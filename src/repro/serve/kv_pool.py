"""Paged KV-cache pool: block tables, refcounted pages, prefix sharing.

The dense engine stores each slot's KV as a full ``[cap]`` row, so memory is
``num_slots x max_seq`` no matter how deep any request actually is, and two
requests sharing a system prompt materialize it twice.  This module virtualizes
the slot rows over a fixed **physical page pool**:

  * device side — per layer, ``[num_pages, n*page_size, Hkv, D]`` where the
    middle axis is sharded over the sequence-parallel axis exactly like the
    dense cap axis.  One *logical* page therefore covers ``n * page_size``
    consecutive global positions (``page_size`` local positions per shard),
    which keeps the striped owner math of ``core/decode_attention.py`` intact:
    owner shard -> (page, offset) instead of owner shard -> slot row.
  * host side — this module: an int32 block table ``[num_slots, max_pages]``
    mapping each slot's logical page to a physical page, a refcount per page,
    a free list, and a **prefix registry** (hash of the first ``c`` page-chunks
    of a prompt -> live physical pages) so identical prompt prefixes are
    admitted as shared, refcounted pages instead of fresh copies.

The allocator is pure bookkeeping (numpy, no jax): the engine threads the
block table through the jitted step as a device operand and applies the
allocator's page-copy instructions (copy-on-write) in a tiny jitted scatter.
All decisions are made *before* a step is traced/run, so jit signatures stay
static and retraces stay bounded exactly as in the dense engine.

Sharing granularity is one logical page (= ``n * page_size`` tokens): only
whole page-chunks of a prompt are registered/matched, and a slot's first
append position is at or past its prompt length, so under today's engine flow
an append NEVER lands inside a shared page.  Copy-on-write is nevertheless
part of the allocator contract — ``ensure_append`` returns a ``(src, dst)``
physical copy whenever the target page has refcount > 1, and the engine
applies it before writing — so finer-granularity sharing (partial-chunk
prefix match, suffix dedup) can land without a correctness cliff; the unit
tests exercise the CoW path directly.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PagedLayout", "PageAllocator", "PoolExhausted", "gather_block_table"]


class PoolExhausted(RuntimeError):
    """The free list cannot satisfy a page request RIGHT NOW.

    Under conservative admission (``oversubscribe == 1.0``) this is an
    allocator bug or an un-reserved caller; under oversubscription it is an
    expected runtime event the engine answers by preempting a victim slot
    and retrying.  Subclasses ``RuntimeError`` so pre-oversubscription
    callers (and tests) that caught ``RuntimeError`` keep working."""


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of a paged KV pool.

    ``page_size`` counts LOCAL positions per shard per page; one logical page
    spans ``chunk = n * page_size`` consecutive global positions.  A slot's
    virtual capacity stays ``max_seq`` (= ``max_pages * chunk``), so all the
    band/owner math of the dense cache carries over unchanged.
    """

    num_pages: int  # physical pages in the pool (shared by all slots)
    page_size: int  # local positions per page (per device)
    max_pages: int  # logical pages per slot (virtual cap = max_pages * chunk)
    n: int = 1  # sequence-parallel size the pool is sharded over

    def __post_init__(self):
        if min(self.num_pages, self.page_size, self.max_pages, self.n) < 1:
            raise ValueError(f"invalid paged layout {self}")

    @property
    def chunk(self) -> int:
        """Global positions covered by one logical page."""
        return self.n * self.page_size

    @property
    def virtual_cap(self) -> int:
        return self.max_pages * self.chunk

    def pages_for(self, length: int) -> int:
        """Logical pages needed to hold ``length`` global positions."""
        return -(-max(int(length), 0) // self.chunk)

    @staticmethod
    def for_engine(
        max_seq: int, n: int, num_slots: int,
        page_size: Optional[int] = None, num_pages: Optional[int] = None,
    ) -> "PagedLayout":
        """Engine default: virtual cap == max_seq; pool sized to the dense
        cache (num_slots * max_pages) unless the caller asks for less."""
        if page_size is None:
            page_size = max(1, min(16, max_seq // max(n, 1)))
        if (max_seq % (n * page_size)) != 0:
            raise ValueError(
                f"max_seq={max_seq} must be divisible by n*page_size={n * page_size}"
            )
        max_pages = max_seq // (n * page_size)
        return PagedLayout(
            num_pages=num_pages if num_pages is not None else num_slots * max_pages,
            page_size=page_size,
            max_pages=max_pages,
            n=n,
        )


def _prefix_key(prompt: np.ndarray, upto: int) -> bytes:
    """Chain hash of the first ``upto`` tokens (position 0 anchored, so RoPE
    phases match by construction)."""
    return hashlib.sha1(np.ascontiguousarray(prompt[:upto], np.int32).tobytes()).digest()


@dataclasses.dataclass
class SlotAlloc:
    """What an admission got: which logical pages are shared (prefill must
    NOT overwrite them — the owner's K/V is already there, byte-identical by
    causality) and how many tokens they cover."""

    shared_pages: int
    shared_len: int  # = shared_pages * chunk


class PageAllocator:
    """Refcounted page allocator + prefix registry over a ``PagedLayout``.

    All methods mutate host state only; device mutations are communicated as
    return values (block-table rows, copy pairs) for the engine to apply.
    """

    FREE = -1
    # extra physically-free pages required beyond the prompt at admission
    # under oversubscription: the first append after prefill has somewhere
    # to land without an immediate preemption
    ADMIT_MARGIN = 1

    def __init__(
        self, layout: PagedLayout, quantized: bool = False,
        oversubscribe: float = 1.0,
    ):
        if oversubscribe < 1.0:
            raise ValueError(f"oversubscribe must be >= 1.0, got {oversubscribe}")
        self.layout = layout
        # admission accounting capacity: lifetime reservations may overbook
        # the physical pool by this factor (1.0 = the conservative guarantee:
        # no admitted request can ever exhaust the pool mid-decode)
        self.oversubscribe = float(oversubscribe)
        self.virtual_pages = int(layout.num_pages * self.oversubscribe)
        # quantized pools carry a scale tile per physical page (side table
        # indexed by the same block table); its liveness is counted
        # INDEPENDENTLY of the free list so "scales drain with pages" is a
        # real invariant, not a tautology
        self.quantized = bool(quantized)
        self.scale_entries_in_use = 0
        self.block_table = np.full((0, layout.max_pages), self.FREE, np.int32)
        self.ref = np.zeros((layout.num_pages,), np.int32)
        self.gen = np.zeros((layout.num_pages,), np.int64)  # bumped on free
        self._free: List[int] = list(range(layout.num_pages - 1, -1, -1))
        # slot -> logical page count currently allocated
        self._slot_pages: Dict[int, int] = {}
        # slot -> pages reserved for its full lifetime (admission guarantee)
        self._reserved: Dict[int, int] = {}
        # prefix registry: chain-hash -> (physical page, generation stamp)
        self._prefix: Dict[bytes, Tuple[int, int]] = {}
        # stats
        self.fresh_allocs = 0  # pages taken off the free list, ever
        self.shared_hits = 0  # pages admitted by prefix match instead
        self.cow_copies = 0
        self.spec_rolled_back = 0  # pages freed by speculative rollback
        self.double_free_noops = 0  # idempotent free/rollback of a retired slot
        self.peak_in_use = 0
        # chaos harness: pages seized OUT of the free list (fault injection);
        # they count as in-use but carry no refs and no scale entries
        self._seized: List[int] = []
        # bumped on every block-table mutation: the engine re-uploads the
        # device table only when this moved since the last sync
        self.version = 0

    # -- introspection ------------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        return self.layout.num_pages - len(self._free)

    @property
    def pages_reserved(self) -> int:
        return sum(self._reserved.values())

    @property
    def pages_referenced(self) -> int:
        """Pages with at least one live block-table reference (excludes
        chaos-seized pages, which are in-use but own no data)."""
        return int(np.count_nonzero(self.ref > 0))

    def slot_pages(self, slot: int) -> int:
        return self._slot_pages.get(slot, 0)

    def slot_shares_pages(self, slot: int) -> bool:
        """True when any of ``slot``'s pages is mapped by another live slot
        (prefix donor / sharer) — preemption policy treats these as
        last-resort victims."""
        held = self._slot_pages.get(slot, 0)
        if not held:
            return False
        return any(self.ref[int(p)] > 1 for p in self.block_table[slot, :held])

    # -- admission ----------------------------------------------------------

    def reserve_for(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case lifetime pages for a request (sharing not discounted:
        a shared page may need a private copy at any time)."""
        return self.layout.pages_for(prompt_len + max_new_tokens)

    def can_admit(
        self, prompt_len: int, max_new_tokens: int, pending: int = 0,
        pending_prompt: int = 0,
    ) -> bool:
        """Page-accounted admission.  At ``oversubscribe == 1.0`` this is the
        conservative guarantee: every admitted request can reach its token
        budget without mid-flight pool exhaustion.  Above 1.0 lifetime
        reservations book against the VIRTUAL capacity
        (``floor(oversubscribe * num_pages)``) and only the prompt pages
        (plus a one-page margin) must fit physically right now — mid-decode
        exhaustion becomes an expected event the engine resolves by
        preempt-and-recompute.  ``pending`` / ``pending_prompt`` carry pages
        already promised to requests admitted earlier in the same tick
        (their ``alloc_slot`` hasn't run yet)."""
        need = self.reserve_for(prompt_len, max_new_tokens)
        if self.pages_reserved + pending + need > self.virtual_pages:
            return False
        if self.oversubscribe > 1.0:
            prompt_pages = self.layout.pages_for(prompt_len)
            now = self.pages_in_use + pending_prompt + prompt_pages
            if now + self.ADMIT_MARGIN > self.layout.num_pages:
                return False
        return True

    def never_admittable(self, prompt_len: int, max_new_tokens: int) -> bool:
        """True when the request could not be admitted even into an EMPTY
        pool — waiting can never help, so the scheduler rejects it instead
        of blocking the queue head forever."""
        need = self.reserve_for(prompt_len, max_new_tokens)
        if need > self.virtual_pages:
            return True
        return self.layout.pages_for(prompt_len) > self.layout.num_pages

    # -- lifecycle ----------------------------------------------------------

    def _ensure_rows(self, slot: int):
        if slot >= len(self.block_table):
            grow = np.full(
                (slot + 1 - len(self.block_table), self.layout.max_pages),
                self.FREE, np.int32,
            )
            self.block_table = np.concatenate([self.block_table, grow])

    def _take_page(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"page pool exhausted: {self.pages_in_use}/{self.layout.num_pages} "
                f"pages in use ({self.pages_referenced} referenced, "
                f"{len(self._seized)} seized), {self.pages_reserved} reserved "
                f"against a virtual capacity of {self.virtual_pages} "
                f"(oversubscribe={self.oversubscribe}), free list empty"
            )
        pid = self._free.pop()
        self.ref[pid] = 1
        self.fresh_allocs += 1
        if self.quantized:
            self.scale_entries_in_use += 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pid

    def _release_page(self, pid: int):
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self.gen[pid] += 1  # invalidate any prefix-registry entries
            self._free.append(pid)
            if self.quantized:
                self.scale_entries_in_use -= 1
        elif self.ref[pid] < 0:
            raise RuntimeError(f"double free of page {pid}")

    def alloc_slot(self, slot: int, prompt: np.ndarray, max_new_tokens: int) -> SlotAlloc:
        """Admit a prompt into ``slot``: match whole page-chunks of its prefix
        against the registry (share, +ref), allocate fresh pages for the rest
        of the prompt, register its own full chunks, and reserve its lifetime
        page budget.  Returns what prefill may skip writing."""
        if self._slot_pages.get(slot, 0):
            raise ValueError(f"slot {slot} still holds pages; free_slot first")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        need = self.reserve_for(len(prompt), max_new_tokens)
        if self.pages_reserved + need > self.virtual_pages:
            raise RuntimeError(
                f"admission without capacity: need {need} pages but only "
                f"{self.virtual_pages - self.pages_reserved} of the virtual "
                f"capacity {self.virtual_pages} is unreserved "
                f"({self.pages_in_use}/{self.layout.num_pages} physical pages "
                f"in use, oversubscribe={self.oversubscribe})"
            )
        self._ensure_rows(slot)
        chunk = self.layout.chunk
        n_pages = self.layout.pages_for(len(prompt))
        full = len(prompt) // chunk  # whole chunks eligible for sharing
        shared = 0
        try:
            for c in range(full):
                key = _prefix_key(prompt, (c + 1) * chunk)
                hit = self._prefix.get(key)
                if hit is None:
                    break
                pid, stamp = hit
                if self.ref[pid] <= 0 or self.gen[pid] != stamp:
                    del self._prefix[key]  # stale: owner retired since
                    break
                self.block_table[slot, c] = pid
                self.ref[pid] += 1
                self.shared_hits += 1
                shared = c + 1
            for c in range(shared, n_pages):
                pid = self._take_page()
                self.block_table[slot, c] = pid
                if c < full:  # register this slot's own full chunks
                    self._prefix[_prefix_key(prompt, (c + 1) * chunk)] = (
                        pid, int(self.gen[pid]),
                    )
        except PoolExhausted:
            # atomic admission: a squeezed/oversubscribed pool may run dry
            # mid-prompt — unwind every page this call took or shared so the
            # engine can preempt (or defer) and retry cleanly
            done = int(np.count_nonzero(self.block_table[slot, :n_pages] >= 0))
            for c in range(done - 1, -1, -1):
                self._release_page(int(self.block_table[slot, c]))
                self.block_table[slot, c] = self.FREE
            self.version += 1
            raise
        self._slot_pages[slot] = n_pages
        self._reserved[slot] = need
        self.version += 1
        return SlotAlloc(shared_pages=shared, shared_len=shared * chunk)

    def ensure_append(self, slot: int, pos: int) -> Optional[Tuple[int, int]]:
        """Make position ``pos`` writable for ``slot`` before a decode tick:
        allocate the next logical page on a chunk boundary, and copy-on-write
        when the target page is shared.  Returns an optional ``(src, dst)``
        physical page copy the engine must apply to the device pool."""
        lp = pos // self.layout.chunk
        if lp >= self.layout.max_pages:
            return None  # past virtual capacity: the write masks off anyway
        held = self._slot_pages.get(slot, 0)
        if lp >= held:
            if lp != held:
                raise ValueError(f"non-contiguous append: slot {slot} pos {pos}")
            self.block_table[slot, lp] = self._take_page()
            self._slot_pages[slot] = held + 1
            self.version += 1
            return None
        pid = int(self.block_table[slot, lp])
        if self.ref[pid] > 1:  # shared tail: private copy before writing
            dst = self._take_page()
            self.ref[pid] -= 1
            self.block_table[slot, lp] = dst
            self.cow_copies += 1
            self.version += 1
            return (pid, dst)
        return None

    def ensure_span(self, slot: int, start: int, count: int) -> List[Tuple[int, int]]:
        """Make positions ``start .. start + count - 1`` writable for ``slot``
        — the multi-token (speculative verify) analogue of ``ensure_append``:
        walk the span's logical pages in order, allocating tail pages and
        CoW-ing shared ones.  Returns every ``(src, dst)`` physical copy the
        engine must apply before the write."""
        copies: List[Tuple[int, int]] = []
        if count <= 0:
            return copies
        chunk = self.layout.chunk
        for lp in range(start // chunk, (start + count - 1) // chunk + 1):
            if lp >= self.layout.max_pages:
                break  # past virtual capacity: those writes mask off anyway
            cp = self.ensure_append(slot, max(start, lp * chunk))
            if cp is not None:
                copies.append(cp)
        return copies

    def rollback(self, slot: int, keep_len: int) -> int:
        """Free every page of ``slot`` beyond what ``keep_len`` committed
        positions need — rejected speculative tokens become page frees, not
        cache rewrites.  Stale K/V inside the kept tail page is harmless:
        the band never reads past ``pos``, and every position is rewritten
        before ``pos`` reaches it.  Speculative pages are never in the
        prefix registry (only ``alloc_slot`` registers, and only full prompt
        chunks), so sharers can never have mapped what is freed here.
        Rolling back a slot that holds no pages (already retired/preempted)
        is an idempotent no-op counted in ``double_free_noops``.
        Returns the number of pages freed."""
        if slot not in self._slot_pages:
            self.double_free_noops += 1
            return 0
        held = self._slot_pages.get(slot, 0)
        target = self.layout.pages_for(keep_len)
        freed = 0
        for lp in range(held - 1, target - 1, -1):
            self._release_page(int(self.block_table[slot, lp]))
            self.block_table[slot, lp] = self.FREE
            freed += 1
        if freed:
            self._slot_pages[slot] = target
            self.spec_rolled_back += freed
            self.version += 1
        return freed

    def free_slot(self, slot: int) -> List[int]:
        """Retire a slot: drop its references; pages survive while shared.
        Freeing an already-free slot is an idempotent no-op (counted in
        ``double_free_noops``), NOT a refcount corruption.  Returns the
        physical pages whose refcount actually hit zero (the engine scrubs
        pending CoW copies against this after a preemption)."""
        if slot not in self._slot_pages:
            self.double_free_noops += 1
            self._reserved.pop(slot, None)
            return []
        held = self._slot_pages.pop(slot, 0)
        freed: List[int] = []
        for c in range(held):
            pid = int(self.block_table[slot, c])
            self._release_page(pid)
            if self.ref[pid] == 0:
                freed.append(pid)
        self.block_table[slot, :held] = self.FREE
        self._reserved.pop(slot, None)
        if held:
            self.version += 1
        return freed

    # -- fault injection (testing/chaos.py) ---------------------------------

    def seize_pages(self, k: int) -> List[int]:
        """Chaos hook: remove up to ``k`` pages from the free list, simulating
        an external squeeze (fragmentation, a co-tenant, a shrunken pool).
        Seized pages own no refs and no scale entries; ``restore_pages``
        returns them.  Returns the seized page ids."""
        taken: List[int] = []
        for _ in range(max(int(k), 0)):
            if not self._free:
                break
            taken.append(self._free.pop())
        self._seized.extend(taken)
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return taken

    def restore_pages(self, pids: List[int]) -> None:
        """Chaos hook: return previously seized pages to the free list."""
        for pid in pids:
            self._seized.remove(pid)
            self._free.append(pid)

    # -- invariants (engine.health()) ---------------------------------------

    def check_invariants(self) -> List[str]:
        """Cross-check every piece of allocator state; returns a list of
        violation descriptions (empty = healthy).  ``engine.health()`` runs
        this every ``ServeConfig.health_every`` ticks and raises on any."""
        lay = self.layout
        problems: List[str] = []
        free = list(self._free)
        if len(set(free)) != len(free):
            problems.append(f"free list has duplicates: {sorted(free)}")
        for pid in free:
            if not (0 <= pid < lay.num_pages):
                problems.append(f"free list page {pid} out of range")
            elif self.ref[pid] != 0:
                problems.append(f"free page {pid} has refcount {int(self.ref[pid])}")
        for pid in self._seized:
            if self.ref[pid] != 0:
                problems.append(f"seized page {pid} has refcount {int(self.ref[pid])}")
            if pid in free:
                problems.append(f"page {pid} both seized and free")
        # refcount per page == live block-table references over held rows
        counted = np.zeros((lay.num_pages,), np.int64)
        for slot, held in self._slot_pages.items():
            row = self.block_table[slot, :held]
            if np.any(row < 0):
                problems.append(f"slot {slot} holds {held} pages but row has FREE entries")
            for pid in row:
                if 0 <= int(pid) < lay.num_pages:
                    counted[int(pid)] += 1
            tail = self.block_table[slot, held:]
            if np.any(tail != self.FREE):
                problems.append(f"slot {slot}: block-table entries past held={held}")
        for slot in range(len(self.block_table)):
            if slot not in self._slot_pages and np.any(
                self.block_table[slot] != self.FREE
            ):
                problems.append(f"orphaned block-table row {slot} (slot holds no pages)")
        mism = np.nonzero(counted != self.ref)[0]
        for pid in mism[:8]:
            problems.append(
                f"page {int(pid)}: refcount {int(self.ref[pid])} != "
                f"{int(counted[pid])} block-table references"
            )
        if len(free) + self.pages_referenced + len(self._seized) != lay.num_pages:
            problems.append(
                f"page conservation: {len(free)} free + {self.pages_referenced} "
                f"referenced + {len(self._seized)} seized != {lay.num_pages}"
            )
        if self.quantized and self.scale_entries_in_use != self.pages_referenced:
            problems.append(
                f"scale entries ({self.scale_entries_in_use}) out of lockstep "
                f"with referenced pages ({self.pages_referenced})"
            )
        if self.pages_reserved > self.virtual_pages:
            problems.append(
                f"reserved {self.pages_reserved} exceeds virtual capacity "
                f"{self.virtual_pages}"
            )
        for slot in self._reserved:
            if slot not in self._slot_pages:
                problems.append(f"slot {slot} reserved but holds no pages")
        return problems

    # -- device view --------------------------------------------------------

    def device_table(self, num_slots: int) -> np.ndarray:
        """Block table padded/clipped to the engine's slot count.  FREE (-1)
        entries mean "unallocated"; device code clamps them to page 0, whose
        contents are hidden by the position band."""
        self._ensure_rows(num_slots - 1)
        return np.array(self.block_table[:num_slots], np.int32)

    def stats(self) -> Dict[str, int]:
        return {
            "pages_in_use": self.pages_in_use,
            "peak_in_use": self.peak_in_use,
            "fresh_allocs": self.fresh_allocs,
            "shared_hits": self.shared_hits,
            "cow_copies": self.cow_copies,
            "spec_rolled_back_pages": self.spec_rolled_back,
            "quantized_pages": self.pages_in_use if self.quantized else 0,
            "scale_entries_in_use": self.scale_entries_in_use,
            "pages_reserved": self.pages_reserved,
            "virtual_pages": self.virtual_pages,
            "seized_pages": len(self._seized),
            "double_free_noops": self.double_free_noops,
        }


def gather_block_table(pool: np.ndarray, table: np.ndarray) -> np.ndarray:
    """Numpy oracle: materialize the dense per-slot view a block table
    describes.  ``pool``: [num_pages, n*page_size, ...]; ``table``: [slots,
    max_pages].  Returns [slots, max_pages * n*page_size, ...] with
    unallocated pages zero-filled (they are invisible behind the band)."""
    pool = np.asarray(pool)
    table = np.asarray(table)
    padded = np.concatenate([pool, np.zeros_like(pool[:1])])
    idx = np.where(table < 0, pool.shape[0], table)
    out = padded[idx]  # [slots, max_pages, n*ps, ...]
    return out.reshape((table.shape[0], -1) + pool.shape[2:])
