"""Self-speculative draft proposal: prompt-lookup n-gram continuation.

Speculative decode needs candidate tokens to verify; the cheapest credible
source is the request's OWN token history (prompt + everything generated so
far).  ``propose_ngram`` matches the longest suffix n-gram of that history
against its earlier occurrences and proposes the continuation after the
most recent match — "prompt lookup" drafting: no second model, no extra
device work, pure host-side numpy per slot per tick.

Why it works: real serving traffic is full of exact repetition (quoted
context, code identifiers, boilerplate, lists), and greedy decode itself
falls into verbatim loops — both cases the lookup predicts perfectly.
When the history has no repeats the proposer returns an empty draft and
the slot costs exactly one vanilla decode row.

Correctness never depends on the draft: the verify step accepts a drafted
token only where it equals the model's own greedy output, so a bad draft
costs wasted verify FLOPs, never a wrong token.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = ["propose_ngram"]


def propose_ngram(
    prompt: Sequence[int],
    generated: Sequence[int],
    k: int,
    *,
    max_ngram: int = 3,
) -> List[int]:
    """Draft up to ``k`` tokens expected to FOLLOW the current history
    ``prompt + generated`` (whose last element is the token the engine is
    about to feed to decode).

    Longest-match-first: try suffix n-grams of size ``max_ngram`` down to 1;
    for the first size with an earlier occurrence, copy the continuation of
    the MOST RECENT occurrence (recency tracks the live repetition — a loop
    the model just entered beats a stale prompt match).  Returns ``[]`` when
    the history never repeats (the slot then runs a plain 1-token row)."""
    if k <= 0:
        return []
    hist = np.concatenate([
        np.asarray(prompt, np.int64).reshape(-1),
        np.asarray(generated, np.int64).reshape(-1),
    ])
    size = int(hist.size)
    for n in range(min(max_ngram, size - 1), 0, -1):
        suffix = hist[size - n:]
        # match every window start at once (n vectorized compares — a
        # per-candidate scan would go O(history) on repeat-free histories,
        # and this runs per slot per tick).  Window starts stop strictly
        # before the suffix's own start; overlap with the suffix is fine —
        # that is exactly how period-<n loops are predicted.
        mask = hist[: size - n] == suffix[0]
        for i in range(1, n):
            mask &= hist[i : size - n + i] == suffix[i]
        hits = np.flatnonzero(mask)
        if hits.size:
            j = int(hits[-1])  # most recent match tracks the live repetition
            cont = hist[j + n : j + n + k]
            return [int(t) for t in cont]
    return []
