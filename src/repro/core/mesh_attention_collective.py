"""Algorithm-1 collective mode: Mesh-Attention with XLA-native collectives.

The paper's Algorithm 1 states the functional flow as whole-group
collectives (all-gather Q in the Q group, all-gather KV in the KV group,
blockwise compute, reduce-scatter O with online-softmax as the reduce
operator) and §3.4 then *decomposes* them into ring P2P steps for
overlapping.  On meshes that expose the tile factors as REAL axes
(e.g. ``(data, aq, akv)``), this module implements Algorithm 1 directly with
``lax.all_gather`` / ``lax.psum_scatter`` — XLA's async collectives then do
their own overlapping.  It serves as:

  * a cross-check of the ring decomposition (same math, different comm),
  * an alternative production configuration for §Perf comparisons (XLA can
    sometimes schedule few large collectives better than many small ones),
  * the natural expression of the paper's "wrap-around mesh" on a physical
    2-D TPU slice.

Chunk layout: the sequence is sharded over the combined ("aq","akv") axes in
row-major order, so device (x, y) holds global chunk c = x·b + y.  Its
gathered Q set is the column-residue class {x'·b + y} and its KV set the row
band {x·b + y'} — each AM block is computed exactly once and the local Q-KV
property holds by construction (c is in both sets).  The lse-weighted
reduce-scatter over "aq" returns each device exactly its own chunk's output.

Differentiable by plain autodiff (XLA transposes the collectives); the
ring-mode custom_vjp remains the paper-faithful backward.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.core import schedule as S
from repro.kernels import ops
from repro.kernels.ref import BAND_INF, NEG_INF

__all__ = ["mesh_attention_collective"]


def mesh_attention_collective(
    q: jnp.ndarray,  # [B, m, H, D] local chunk
    k: jnp.ndarray,  # [B, m, Hkv, D]
    v: jnp.ndarray,
    q_axis: str,  # mesh axis carrying the tile height a
    kv_axis: str,  # mesh axis carrying the tile width b
    *,
    causal: bool = False,
    window: Optional[int] = None,
    layout: str = "striped",
    scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    mask=None,  # Optional[MaskSpec]; supersedes causal/window
    seg: Optional[jnp.ndarray] = None,  # [m] int32 local segment-id chunk
    comm_overlap: str = "overlap",  # schedule.COMM_OVERLAP_MODES; collective
    # mode has no step pipeline, so the knob maps onto the gathers: serial
    # barriers compute on every gather, bidir splits each all-gather into a
    # half-payload pair (both ring directions of the axis).  Reductions
    # (psum_scatter, the lse all-gather feeding one) are never split — only
    # pure transport is, which keeps all three modes bitwise-equal.
) -> jnp.ndarray:
    S.validate_comm_overlap(comm_overlap)
    a = lax.psum(1, q_axis)
    b = lax.psum(1, kv_axis)
    n = a * b
    x = lax.axis_index(q_axis)
    y = lax.axis_index(kv_axis)
    m = q.shape[1]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if mask is not None:
        causal = mask.is_causal
        window = mask.window
        if mask.needs_segments and seg is None:
            raise ValueError(f"mask kind {mask.kind!r} needs a segment-id operand")

    def gather(x, axis):
        if comm_overlap != "bidir" or x.ndim == 0 or x.shape[-1] < 2:
            return lax.all_gather(x, axis)
        h = x.shape[-1] // 2
        lo = lax.all_gather(x[..., :h], axis)
        hi_half = lax.all_gather(x[..., h:], axis)
        return jnp.concatenate([lo, hi_half], axis=-1)

    # Algorithm 1 lines 1-2: group all-gathers
    qs = gather(q, q_axis)  # [a, B, m, H, D]
    ks = gather(k, kv_axis)  # [b, B, m, Hkv, D]
    vs = gather(v, kv_axis)
    seg_qs = seg_ks = None
    if seg is not None:
        seg = jnp.asarray(seg, jnp.int32)
        seg_qs = gather(seg, q_axis)  # [a, m]
        seg_ks = gather(seg, kv_axis)  # [b, m]
    if comm_overlap == "serial":
        # pin the gathers ahead of the blockwise compute (identity on values)
        gathered = (qs, ks, vs) + ((seg_qs, seg_ks) if seg is not None else ())
        barr = lax.optimization_barrier(gathered)
        qs, ks, vs = barr[0], barr[1], barr[2]
        if seg is not None:
            seg_qs, seg_ks = barr[3], barr[4]

    hi = (window - 1) if (causal and window) else BAND_INF

    def band_for(u, w_):
        if not causal:
            return jnp.asarray([0, 0, -BAND_INF, BAND_INF], jnp.int32), 1, 1
        qc = u * b + y  # global chunk ids under the row-major layout
        kc = x * b + w_
        if layout == "striped":
            off_q, off_kv, s = qc, kc, n
        else:
            off_q, off_kv, s = qc * m, kc * m, 1
        return (
            jnp.stack([off_q.astype(jnp.int32), off_kv.astype(jnp.int32),
                       jnp.int32(0), jnp.int32(hi)]),
            s, s,
        )

    # Algorithm 1 line 3: blockwise compute with online-softmax accumulation
    o_rows = []
    lse_rows = []
    for u in range(a):
        acc_o = None
        acc_l = None
        for w_ in range(b):
            band, sq, skv = band_for(jnp.asarray(u), jnp.asarray(w_))
            o_b, l_b = ops.block_attention(
                qs[u], ks[w_], vs[w_], band,
                scale=scale, stride_q=sq, stride_kv=skv,
                block_q=block_q, block_kv=block_kv,
                seg_q=None if seg_qs is None else seg_qs[u],
                seg_kv=None if seg_ks is None else seg_ks[w_],
            )
            o_b = o_b.astype(jnp.float32)
            l_b = l_b.astype(jnp.float32)
            if acc_o is None:
                acc_o, acc_l = o_b, l_b
            else:
                mx = jnp.maximum(jnp.maximum(acc_l, l_b), NEG_INF)
                w1 = jnp.exp(acc_l - mx)
                w2 = jnp.exp(l_b - mx)
                tot = jnp.where(w1 + w2 > 0, w1 + w2, 1.0)
                acc_o = (acc_o * (w1 / tot).swapaxes(1, 2)[..., None]
                         + o_b * (w2 / tot).swapaxes(1, 2)[..., None])
                acc_l = jnp.where(w1 + w2 > 0, mx + jnp.log(tot), NEG_INF)
        o_rows.append(acc_o)
        lse_rows.append(acc_l)

    o_stack = jnp.stack(o_rows)  # [a, B, m, H, D] partials for my Q set
    lse_stack = jnp.stack(lse_rows)  # [a, B, H, m]

    # Algorithm 1 line 4: reduce-scatter with online softmax as the reducer.
    # Combine lse across the Q group first (tiny), then psum_scatter the
    # rescaled partials so device x receives exactly its own chunk (slot x).
    lse_all = lax.all_gather(lse_stack, q_axis)  # [a(dev), a(slot), B, H, m]
    mx = jnp.maximum(jnp.max(lse_all, axis=0), NEG_INF)  # [a, B, H, m]
    den = jnp.sum(jnp.exp(lse_all - mx[None]), axis=0)
    den = jnp.where(den > 0, den, 1.0)
    w = jnp.exp(lse_stack - mx) / den  # my weight for each slot
    o_weighted = o_stack * w.swapaxes(2, 3)[..., None]  # [a, B, m, H, D]
    # untiled: slot dim removed; device x receives the reduced slot x = its chunk
    o_mine = lax.psum_scatter(o_weighted, q_axis, scatter_dimension=0, tiled=False)
    return o_mine.astype(q.dtype)
