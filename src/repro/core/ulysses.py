"""DeepSpeed-Ulysses baseline (Jacobs et al. 2023) — head-parallel attention.

Two all-to-alls transpose between sequence- and head-sharding so each device
computes full attention for H/n complete heads locally; a final all-to-all
restores sequence sharding for O.  Communication is 4·(n-1)/n²·N·d per device
(paper Table 2) but parallelism is capped at the KV-head count — the
limitation Mesh-Attention removes (paper §2.3).

Runs inside shard_map over ``axis_name``; expects the *contiguous* sequence
layout (not striped): after the gather each device sees the full sequence, so
plain causal masking applies.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.kernels import ops

__all__ = ["ulysses_attention"]


def ulysses_attention(
    q: jnp.ndarray,  # [B, S/n, H, D]
    k: jnp.ndarray,  # [B, S/n, Hkv, D]
    v: jnp.ndarray,
    axis_name: str,
    n: int,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    seg: Optional[jnp.ndarray] = None,  # [S/n] int32 local segment-id chunk
) -> jnp.ndarray:
    H, Hkv = q.shape[2], k.shape[2]
    if n == 1:
        return ops.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale, seg_q=seg, seg_kv=seg
        )
    if Hkv % n:
        raise ValueError(
            f"DS-Ulysses parallelism is capped by the KV head count: "
            f"n={n} does not divide Hkv={Hkv} (the paper's §2.3 limitation)"
        )
    # seq-sharded -> head-sharded: split heads (axis 2) across devices,
    # concatenate sequence chunks (axis 1)
    qh = lax.all_to_all(q, axis_name, split_axis=2, concat_axis=1, tiled=True)
    kh = lax.all_to_all(k, axis_name, split_axis=2, concat_axis=1, tiled=True)
    vh = lax.all_to_all(v, axis_name, split_axis=2, concat_axis=1, tiled=True)
    seg_full = None
    if seg is not None:
        # after the transpose every device holds the FULL sequence; gather
        # the (tiny, int32) segment ids to match
        seg_full = lax.all_gather(seg, axis_name, tiled=True)
    oh = ops.flash_attention(
        qh, kh, vh, causal=causal, window=window, scale=scale,
        seg_q=seg_full, seg_kv=seg_full,
    )
    # head-sharded -> seq-sharded
    return lax.all_to_all(oh, axis_name, split_axis=1, concat_axis=2, tiled=True)
