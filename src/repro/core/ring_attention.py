"""Ring-Attention baseline (Liu et al. 2023).

The paper shows Ring-Attention is exactly the (a=1, b=n) row-wise special
case of the Mesh-Attention assignment matrix: each device keeps its Q chunk
and the KV chunks circulate through a single logical ring.  We therefore
implement the baseline *as* that special case — identical kernels, identical
ring machinery, only the tile shape differs — which makes the benchmark
comparison an apples-to-apples measurement of the tiling idea itself.
"""

from __future__ import annotations

from typing import Optional

from repro.core import schedule as S
from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention

__all__ = ["ring_attention", "ring_config"]


def ring_config(
    axis_name: str,
    n: int,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> MeshAttentionConfig:
    return MeshAttentionConfig(
        axis_name=axis_name,
        n=n,
        a=1,
        causal=causal,
        window=window,
        scale=scale,
        fwd_schedule=S.ring_forward_schedule(n) if n > 1 else None,
        block_q=block_q,
        block_kv=block_kv,
    )


def ring_attention(q, k, v, axis_name: str, n: int, **kw):
    """Drop-in distributed attention with the Ring schedule (inside shard_map)."""
    return mesh_attention(q, k, v, ring_config(axis_name, n, **kw))
