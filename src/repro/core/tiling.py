"""Tile-based workload distribution for Mesh-Attention (paper §3.2).

The assignment matrix (AM) is the n x n matrix whose entry AM[i][j] names the
device responsible for computing the attention block between Q chunk i and KV
chunk j.  Mesh-Attention partitions the AM into n tiles of shape (a, b) with
n = a * b, arranges devices row-first over the tiles, and rotates the KV chunk
indices so that every device retains the *local Q-KV property*: it computes
the block between its own Q and KV chunk without any communication.

Everything in this module is pure Python / integer arithmetic so that it can
be unit- and property-tested exhaustively and reused both by the scheduler
(`core/schedule.py`) and by the distributed implementation
(`core/mesh_attention.py`), which turns the same index maps into
``jax.lax.ppermute`` permutations.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "TileLayout",
    "factorizations",
    "best_square_a",
    "stripe_permutation",
    "unstripe_permutation",
    "striped_causal_offset",
]


def factorizations(n: int) -> List[Tuple[int, int]]:
    """All ordered factorizations n = a * b with a, b >= 1.

    ``a`` is the Q-group size (tile height); ``a == 1`` recovers
    Ring-Attention, ``a == n`` is the column-wise (communicate-Q) extreme.
    """
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    out = []
    for a in range(1, n + 1):
        if n % a == 0:
            out.append((a, n // a))
    return out


def best_square_a(n: int) -> int:
    """The divisor of n closest to sqrt(n) (paper §3.8: comm is minimized
    at a -> sqrt(n) by AM-GM)."""
    best, best_gap = 1, float("inf")
    root = math.sqrt(n)
    for a, _ in factorizations(n):
        gap = abs(math.log(a / root))
        if gap < best_gap:
            best, best_gap = a, gap
    return best


@dataclasses.dataclass(frozen=True)
class TileLayout:
    """The (a, b) tiling of the assignment matrix for n = a*b devices.

    Device naming follows the paper: device ``i`` sits at tile
    (row-band ``i // a``, column-residue ``i % a``).

    * Q group  g = i // a   : devices {a*g + x | x in [0, a)}  (b groups, size a)
    * KV group r = i % a    : devices {r + a*x | x in [0, b)}  (a groups, size b)
    """

    n: int
    a: int

    def __post_init__(self):
        if self.n % self.a != 0:
            raise ValueError(f"a={self.a} does not divide n={self.n}")
        if self.a < 1:
            raise ValueError(f"a must be >= 1, got {self.a}")

    @property
    def b(self) -> int:
        return self.n // self.a

    # ---- groups ------------------------------------------------------------
    def q_group(self, i: int) -> int:
        return i // self.a

    def kv_group(self, i: int) -> int:
        return i % self.a

    def q_group_members(self, g: int) -> List[int]:
        return [self.a * g + x for x in range(self.a)]

    def kv_group_members(self, r: int) -> List[int]:
        return [r + self.a * x for x in range(self.b)]

    # ---- ring neighbours ----------------------------------------------------
    def succ_q(self, i: int) -> int:
        """Successor of device i in its Q group ring."""
        return self.a * (i // self.a) + (i + 1) % self.a

    def pred_q(self, i: int) -> int:
        return self.a * (i // self.a) + (i - 1) % self.a

    def succ_kv(self, i: int) -> int:
        """Successor of device i in its KV group ring (stride a)."""
        return (i + self.a) % self.n

    def pred_kv(self, i: int) -> int:
        return (i - self.a) % self.n

    def q_ring_perm(self) -> List[Tuple[int, int]]:
        """(src, dst) pairs implementing one Recv-Q ring step for ALL devices.

        Data flows predecessor -> device, i.e. every device sends to its
        successor.  With a == 1 the Q ring is a self-loop and no permutation
        is needed (returns []).
        """
        if self.a == 1:
            return []
        return [(i, self.succ_q(i)) for i in range(self.n)]

    def kv_ring_perm(self) -> List[Tuple[int, int]]:
        if self.b == 1:
            return []
        return [(i, self.succ_kv(i)) for i in range(self.n)]

    # ---- canonical data-flow permutations used by the distributed op ----------
    #
    # Slot arithmetic (Table 1) fixes the flow direction: device i's slot u+1
    # is device (i+1 in group)'s slot u, so on every ring step each device
    # forwards its in-flight buffer to the *lower* neighbour and receives from
    # the *higher* one.  The same downward perm serves Recv Q (all-gather),
    # Send O and Send dQ (reduce-scatter) on the Q ring — and analogously for
    # the KV ring with stride a — so the whole algorithm uses exactly two
    # neighbour shifts, which map to uniform single-hop ICI moves on a torus.

    def q_shift_perm(self) -> List[Tuple[int, int]]:
        if self.a == 1:
            return []
        return [(i, self.pred_q(i)) for i in range(self.n)]

    def kv_shift_perm(self) -> List[Tuple[int, int]]:
        if self.b == 1:
            return []
        return [(i, self.pred_kv(i)) for i in range(self.n)]

    # ---- Table 1: local slot -> global chunk index ---------------------------
    def q_chunk(self, i: int, u: int) -> int:
        """Global index of Q#u on device i (paper Table 1)."""
        return self.a * (i // self.a) + (i + u) % self.a

    def o_chunk(self, i: int, u: int) -> int:
        return self.q_chunk(i, u)

    def kv_chunk(self, i: int, u: int) -> int:
        """Global index of KV#u on device i (paper Table 1)."""
        return (i + self.a * u) % self.n

    def q_slot_of(self, i: int, v: int) -> int:
        """Inverse of q_chunk: which local slot holds global Q chunk v."""
        g = i // self.a
        if v // self.a != g:
            raise ValueError(f"Q chunk {v} is not in device {i}'s Q group")
        return (v - i) % self.a

    def kv_slot_of(self, i: int, v: int) -> int:
        if v % self.a != i % self.a:
            raise ValueError(f"KV chunk {v} is not in device {i}'s KV group")
        return ((v - i) % self.n) // self.a

    # ---- assignment matrix ----------------------------------------------------
    def assignment_matrix(self) -> np.ndarray:
        """AM[q_chunk][kv_chunk] = responsible device.

        Derivation: device i covers Q rows of its band i//a and KV columns of
        its residue class i % a, therefore AM[qi][kj] = a*(qi//a) + kj % a.
        """
        qi = np.arange(self.n)[:, None]
        kj = np.arange(self.n)[None, :]
        return self.a * (qi // self.a) + kj % self.a

    def comm_chunks_per_device(self) -> dict:
        """Paper §3.2/§3.8: per-device chunk counts (Q recv, KV recv, O send)."""
        return {"q": self.a - 1, "kv": self.b - 1, "o": self.a - 1}


# ---- striped (causal) sequence layout -----------------------------------------


@lru_cache(maxsize=128)
def _stripe_perm_cached(seq_len: int, n: int) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    m = seq_len // n
    fwd = tuple(int((j // m) + n * (j % m)) for j in range(seq_len))
    inv = [0] * seq_len
    for j, src in enumerate(fwd):
        inv[src] = j
    return fwd, tuple(inv)


def stripe_permutation(seq_len: int, n: int) -> np.ndarray:
    """Gather indices that produce the striped layout (paper §3.7).

    ``striped[j] = original[perm[j]]``.  Chunk ``c`` (positions
    ``c*m .. (c+1)*m-1`` of the striped sequence, with ``m = seq_len // n``)
    holds the original tokens {c + n*x | x in [0, m)}: token t lives in chunk
    ``t mod n`` — Striped-Attention's round-robin assignment, which balances
    the causal mask across all (rotated) AM blocks.
    """
    if seq_len % n != 0:
        raise ValueError(f"seq_len={seq_len} not divisible by n={n}")
    return np.asarray(_stripe_perm_cached(seq_len, n)[0], dtype=np.int64)


def unstripe_permutation(seq_len: int, n: int) -> np.ndarray:
    """Inverse gather: ``original[j] = striped[inv[j]]``."""
    if seq_len % n != 0:
        raise ValueError(f"seq_len={seq_len} not divisible by n={n}")
    return np.asarray(_stripe_perm_cached(seq_len, n)[1], dtype=np.int64)


def striped_causal_offset(q_chunk: int, kv_chunk: int) -> int:
    """Mask offset for block (Q chunk, KV chunk) under the striped layout.

    Striped token indices: q_tok = q_chunk + n*t, kv_tok = kv_chunk + n*s.
    Causality q_tok >= kv_tok reduces to ``t >= s`` when q_chunk >= kv_chunk
    and ``t > s`` otherwise.  We encode this as an offset o such that position
    (t, s) is visible iff ``t - s + o >= 0``: o = 0 or -1.
    """
    return 0 if q_chunk >= kv_chunk else -1
