"""Quantized KV-pool storage: int8 / fp8-e4m3 pages with fp32 scale tables.

The paged pool stores K/V pages in a narrow dtype and keeps symmetric
scales in a side table that shares the pool's physical-page indexing
(``[L, num_pages, chunk, Hkv]`` next to pages ``[L, num_pages, chunk, Hkv,
D]``).  Scales are **per token per kv-head** within a page — amax over the
head dim only — so incremental appends (decode, chunked prefill,
speculative verify) never re-quantize previously written positions: each
position's ``(q, scale)`` pair is written exactly once and is final.  This
is the refinement of "per-page scales" that keeps the write paths
read-modify-write-free; the scale tile still rides the block table's page
indexing, so CoW/rollback/prefix-sharing move scales in lockstep with
pages.

Error model (documented bound, asserted in tests and dist_check
``quant_kv``):

- ``int8``: ``scale = amax / 127``, round-to-nearest →
  ``|x - deq(q)| <= scale/2 = amax/254`` per element, i.e. relative error
  ``<= 1/254`` of the per-(token, head) amax.
- ``fp8`` (e4m3fn, 3 mantissa bits): ``scale = amax / 448`` maps amax to
  the format's max normal; relative error ``<= 2**-4`` (half ulp).

``fp8`` is gated on the runtime exposing ``jnp.float8_e4m3fn``
(``fp8_supported()``); ``ServeConfig`` validation rejects it otherwise.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = [
    "KV_DTYPES",
    "QUANT_KV_DTYPES",
    "REL_ERROR_BOUND",
    "fp8_dtype",
    "fp8_supported",
    "storage_dtype",
    "storage_itemsize",
    "quantize",
    "dequantize",
]

KV_DTYPES = ("fp", "int8", "fp8")
QUANT_KV_DTYPES = ("int8", "fp8")

# Max representable magnitude the amax is mapped onto.
_QMAX = {"int8": 127.0, "fp8": 448.0}

# Elementwise |x - dequant(quant(x))| <= REL_ERROR_BOUND * amax(token, head).
REL_ERROR_BOUND = {"fp": 0.0, "int8": 1.0 / 254.0, "fp8": 2.0 ** -4}

SCALE_DTYPE = jnp.float32


def fp8_dtype():
    """The fp8-e4m3 storage dtype, or None when this jax doesn't have it."""
    return getattr(jnp, "float8_e4m3fn", None)


def fp8_supported() -> bool:
    return fp8_dtype() is not None


def storage_dtype(kv_dtype: str, fp_dtype=jnp.float32):
    """Pool element dtype for a ``kv_dtype`` knob value."""
    if kv_dtype == "fp":
        return jnp.dtype(fp_dtype)
    if kv_dtype == "int8":
        return jnp.dtype(jnp.int8)
    if kv_dtype == "fp8":
        dt = fp8_dtype()
        if dt is None:
            raise ValueError("kv_dtype='fp8' requires jnp.float8_e4m3fn")
        return jnp.dtype(dt)
    raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")


def storage_itemsize(kv_dtype: str, fp_dtype=jnp.float32) -> int:
    return storage_dtype(kv_dtype, fp_dtype).itemsize


def quantize(x: jnp.ndarray, kv_dtype: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric quantization over the last (head-dim) axis.

    Returns ``(q, scale)`` with ``q.shape == x.shape`` in the storage dtype
    and ``scale.shape == x.shape[:-1]`` in fp32.  ``dequantize(q, scale)``
    reconstructs within ``REL_ERROR_BOUND[kv_dtype] * amax``.  Zero rows
    get scale 0 (and quantize to 0), so zero-initialized pool positions and
    their zero-initialized scale entries agree by construction.
    """
    if kv_dtype not in QUANT_KV_DTYPES:
        raise ValueError(f"quantize expects one of {QUANT_KV_DTYPES}, got {kv_dtype!r}")
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = (amax / _QMAX[kv_dtype]).astype(SCALE_DTYPE)
    safe = jnp.where(scale > 0, scale, 1.0)[..., None]
    if kv_dtype == "int8":
        q = jnp.clip(jnp.round(xf / safe), -127.0, 127.0).astype(jnp.int8)
    else:
        q = (xf / safe).astype(fp8_dtype())
    return q, scale


def dequantize(q: jnp.ndarray, scale: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Inverse of :func:`quantize`; ``scale`` broadcasts over the head dim.

    ``scale=None`` is the fp passthrough (cast to f32 only), so callers can
    route both modes through one expression.
    """
    if scale is None:
        return q.astype(jnp.float32)
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
