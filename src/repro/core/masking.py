"""First-class attention masks: one hashable spec for every layer of the stack.

``MaskSpec`` replaces the scattered ``causal``/``window`` booleans that used
to be re-interpreted at every layer (kernel band arithmetic, schedule
generation, cost model, plan-cache key).  A spec is a *static* description of
the mask — hashable, so it rides on ``MeshAttentionConfig`` /
``AttentionPlanConfig`` as a nondiff/jit-static field — and every layer asks
it the question it cares about:

  * kernels:   ``band()`` (+ optional runtime segment-id operands),
  * scheduler: ``block_visibility(a, b, ...)`` — classify each (u, v) slot
    block of the tile as FULL / PARTIAL / EMPTY so the greedy schedules can
    *prune* EMPTY blocks and the communication that only feeds them,
  * simulator: ``visible_fraction(seq)`` — mask-aware per-block FLOP scaling,
  * plan cache: ``signature()`` — enters the autotuner cache key.

Kinds
-----
  full         no mask
  causal       token i attends j iff 0 <= i - j (<= window-1 when windowed)
  document     causal within *statically known* packed documents: position
               lengths ``doc_lens`` partition the sequence into contiguous
               documents (serve prefill packing, synthetic packed batches).
               Static boundaries are what makes schedule pruning possible.
  segment      causal within *runtime* segment ids (an int32 [S] operand
               rides along with q/k/v).  Block structure is unknown at trace
               time, so no pruning — only kernel-level masking.
  block_sparse an explicit n x n chunk-level visibility bitmap.

Lock-step pruning rule: the distributed schedule is identical on every
device, so a slot block (u, v) may be dropped only when the global (Q chunk,
KV chunk) pair it maps to is fully masked on EVERY device of the tile.
``block_visibility`` applies exactly that quantifier.
"""

from __future__ import annotations

import dataclasses
from bisect import bisect_right
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.tiling import TileLayout

__all__ = [
    "MaskSpec",
    "FULL",
    "PARTIAL",
    "EMPTY",
    "BAND_INF",
    "segment_ids_from_doc_lens",
    "positions_from_doc_lens",
    "prefix_chunk_visibility",
]

# classification of one attention block under a mask
FULL = "full"  # every (q, kv) pair visible
PARTIAL = "partial"  # some visible, some masked
EMPTY = "empty"  # fully masked -> prunable (when true on every device)

BAND_INF = 2**30  # matches kernels/ref.py

Block = Tuple[int, int]

# dense-evaluation budget for striped document blocks (m*m pairs per block);
# beyond it we conservatively return PARTIAL (never prunes, always correct)
_DENSE_CAP = 1 << 16


def segment_ids_from_doc_lens(doc_lens, seq: int) -> np.ndarray:
    """[S] int32 document id per position (contiguous original order)."""
    if sum(doc_lens) != seq:
        raise ValueError(f"doc_lens {tuple(doc_lens)} do not sum to seq={seq}")
    return np.repeat(np.arange(len(doc_lens), dtype=np.int32), np.asarray(doc_lens))


def positions_from_doc_lens(doc_lens) -> np.ndarray:
    """[S] int32 per-document positions (restart at each document start)."""
    return np.concatenate([np.arange(l, dtype=np.int32) for l in doc_lens])


def _ceil_div(a: int, b: int) -> int:
    return -((-a) // b)


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Hashable static description of an attention mask (see module doc)."""

    kind: str = "full"
    window: Optional[int] = None  # causal kinds only; width inclusive of self
    doc_lens: Optional[Tuple[int, ...]] = None  # kind == "document"
    bitmap: Optional[Tuple[Tuple[bool, ...], ...]] = None  # kind == "block_sparse"

    def __post_init__(self):
        if self.kind not in ("full", "causal", "document", "segment", "block_sparse"):
            raise ValueError(f"unknown mask kind {self.kind!r}")
        if self.window is not None:
            if not self.is_causal:
                raise ValueError(f"window requires a causal mask kind, got {self.kind!r}")
            if self.window < 1:
                raise ValueError(f"window must be >= 1, got {self.window}")
        if self.kind == "document":
            if not self.doc_lens or any(l < 1 for l in self.doc_lens):
                raise ValueError(f"document mask needs positive doc_lens, got {self.doc_lens}")
        elif self.doc_lens is not None:
            raise ValueError("doc_lens is only valid for kind='document'")
        if self.kind == "block_sparse":
            if not self.bitmap or any(len(r) != len(self.bitmap) for r in self.bitmap):
                raise ValueError("block_sparse needs a square non-empty bitmap")
        elif self.bitmap is not None:
            raise ValueError("bitmap is only valid for kind='block_sparse'")

    # ---- constructors ------------------------------------------------------

    @staticmethod
    def full() -> "MaskSpec":
        return MaskSpec(kind="full")

    @staticmethod
    def causal(window: Optional[int] = None) -> "MaskSpec":
        return MaskSpec(kind="causal", window=window)

    @staticmethod
    def document(doc_lens, window: Optional[int] = None) -> "MaskSpec":
        return MaskSpec(kind="document", window=window,
                        doc_lens=tuple(int(l) for l in doc_lens))

    @staticmethod
    def segment(window: Optional[int] = None) -> "MaskSpec":
        return MaskSpec(kind="segment", window=window)

    @staticmethod
    def block_sparse(bitmap) -> "MaskSpec":
        return MaskSpec(kind="block_sparse",
                        bitmap=tuple(tuple(bool(x) for x in row) for row in bitmap))

    @staticmethod
    def from_flags(causal: bool, window: Optional[int] = None) -> "MaskSpec":
        """The legacy (causal, window) boolean pair as a spec."""
        if causal:
            return MaskSpec.causal(window)
        if window:
            raise ValueError("sliding window requires causal=True")
        return MaskSpec.full()

    # ---- basic properties --------------------------------------------------

    @property
    def is_causal(self) -> bool:
        return self.kind in ("causal", "document", "segment")

    @property
    def needs_segments(self) -> bool:
        """Kernel-level masking needs an int32 segment-id operand."""
        return self.kind in ("document", "segment")

    def band(self) -> Tuple[int, int]:
        """(lo, hi) of the position-difference band q_pos - kv_pos."""
        if self.kind == "block_sparse" or not self.is_causal:
            return (-BAND_INF, BAND_INF)
        return (0, (self.window - 1) if self.window else BAND_INF)

    def signature(self) -> str:
        """Stable short string for plan-cache keys and reports."""
        if self.kind == "full":
            return "full"
        w = f"w{self.window}" if self.window else ""
        if self.kind == "causal":
            return f"causal{w}"
        if self.kind == "segment":
            return f"segment{w}"
        if self.kind == "document":
            lens = ",".join(str(l) for l in self.doc_lens)
            return f"doc[{lens}]{w}"
        rows = "".join("".join("1" if x else "0" for x in r) for r in self.bitmap)
        return f"bs[{len(self.bitmap)}:{rows}]"

    # ---- static segment arrays (document kind) ------------------------------

    def segment_array(self, seq: int) -> np.ndarray:
        """[S] int32 segment ids in original contiguous order."""
        if self.kind != "document":
            raise ValueError(f"segment_array is only defined for 'document', not {self.kind!r}")
        return segment_ids_from_doc_lens(self.doc_lens, seq)

    def _doc_of(self, pos: int) -> int:
        # doc_starts[d] <= pos < doc_starts[d+1]
        starts = self._doc_starts()
        return bisect_right(starts, pos) - 1

    def _doc_starts(self) -> Tuple[int, ...]:
        starts, acc = [], 0
        for l in self.doc_lens:
            starts.append(acc)
            acc += l
        return tuple(starts)

    # ---- per-chunk-pair classification --------------------------------------

    def _band_visibility(self, qc: int, kc: int, *, n: int, m: int, layout: str) -> str:
        """Band-only classification of the (qc, kc) chunk pair."""
        lo, hi = self.band()
        if lo <= -BAND_INF and hi >= BAND_INF:
            return FULL
        if layout == "striped":
            d0, stride = qc - kc, n
        else:
            d0, stride = (qc - kc) * m, 1
        # diff takes values d0 + stride*j, j in [-(m-1), m-1]
        if d0 - stride * (m - 1) >= lo and d0 + stride * (m - 1) <= hi:
            return FULL
        j_lo = max(_ceil_div(lo - d0, stride), -(m - 1))
        j_hi = min((hi - d0) // stride, m - 1)
        return EMPTY if j_lo > j_hi else PARTIAL

    def _doc_visibility(self, qc: int, kc: int, *, m: int) -> str:
        """Document-membership classification (contiguous layout)."""
        dq0 = self._doc_of(qc * m)
        dq1 = self._doc_of(qc * m + m - 1)
        dk0 = self._doc_of(kc * m)
        dk1 = self._doc_of(kc * m + m - 1)
        if dq1 < dk0 or dk1 < dq0:
            return EMPTY
        if dq0 == dq1 == dk0 == dk1:
            return FULL
        return PARTIAL

    def _dense_visibility(self, qc: int, kc: int, *, n: int, m: int, layout: str) -> str:
        """Exact classification by evaluating the mask on the chunk pair."""
        if layout == "striped":
            qpos = qc + n * np.arange(m)
            kpos = kc + n * np.arange(m)
        else:
            qpos = qc * m + np.arange(m)
            kpos = kc * m + np.arange(m)
        lo, hi = self.band()
        diff = qpos[:, None] - kpos[None, :]
        vis = (diff >= lo) & (diff <= hi)
        if self.kind == "document":
            seg = self.segment_array(n * m)
            vis &= seg[qpos][:, None] == seg[kpos][None, :]
        if vis.all():
            return FULL
        if not vis.any():
            return EMPTY
        return PARTIAL

    def chunk_visibility(self, qc: int, kc: int, *, n: int, seq: int, layout: str = "striped") -> str:
        """Classify the global (Q chunk qc, KV chunk kc) block of an n-way
        sequence split under this mask.  Conservative: never EMPTY unless the
        block is provably fully masked."""
        if seq % n:
            raise ValueError(f"seq={seq} not divisible by n={n}")
        m = seq // n
        if self.kind == "block_sparse":
            if len(self.bitmap) != n:
                raise ValueError(
                    f"block_sparse bitmap is {len(self.bitmap)}x{len(self.bitmap)}, "
                    f"but the sequence is split {n} ways"
                )
            return FULL if self.bitmap[qc][kc] else EMPTY
        band = self._band_visibility(qc, kc, n=n, m=m, layout=layout)
        if self.kind in ("full", "causal"):
            return band
        if self.kind == "segment":
            # runtime ids: the band can still prove emptiness, never fullness
            return band if band == EMPTY else PARTIAL
        # document
        if sum(self.doc_lens) != seq:
            raise ValueError(
                f"document mask covers {sum(self.doc_lens)} tokens, call has seq={seq}"
            )
        if layout == "contiguous":
            doc = self._doc_visibility(qc, kc, m=m)
            if band == EMPTY or doc == EMPTY:
                return EMPTY
            if band == FULL and doc == FULL:
                return FULL
            return PARTIAL
        # striped documents interleave; evaluate exactly when cheap
        if m * m <= _DENSE_CAP:
            return self._dense_visibility(qc, kc, n=n, m=m, layout=layout)
        return band if band == EMPTY else PARTIAL

    # ---- schedule-level classification --------------------------------------

    def block_visibility(
        self, a: int, b: int, *, layout: str = "striped", n: Optional[int] = None,
        seq: Optional[int] = None,
    ) -> Dict[Block, str]:
        """Classify every (u, v) slot block of the (a, b) tile.

        A slot block maps to a different global chunk pair on each device
        (Table 1); the lock-step schedule is shared, so the classification
        quantifies over all devices: EMPTY/FULL only when EMPTY/FULL
        everywhere, PARTIAL otherwise.
        """
        n = n if n is not None else a * b
        if n != a * b:
            raise ValueError(f"n={n} != a*b={a * b}")
        if seq is None:
            seq = n  # m=1: token-level == chunk-level classification
        lay = TileLayout(n, a)
        out: Dict[Block, str] = {}
        for u in range(a):
            for v in range(b):
                classes = {
                    self.chunk_visibility(
                        lay.q_chunk(i, u), lay.kv_chunk(i, v), n=n, seq=seq, layout=layout
                    )
                    for i in range(n)
                }
                if classes == {EMPTY}:
                    out[(u, v)] = EMPTY
                elif classes == {FULL}:
                    out[(u, v)] = FULL
                else:
                    out[(u, v)] = PARTIAL
        return out

    def empty_blocks(
        self, a: int, b: int, *, layout: str = "striped", n: Optional[int] = None,
        seq: Optional[int] = None,
    ) -> frozenset:
        """The prunable slot blocks: empty on every device of the tile."""
        vis = self.block_visibility(a, b, layout=layout, n=n, seq=seq)
        return frozenset(blk for blk, c in vis.items() if c == EMPTY)

    # ---- oracles / analytics -------------------------------------------------

    def dense_mask(self, seq: int, segments: Optional[np.ndarray] = None) -> np.ndarray:
        """[S, S] boolean mask in original (contiguous) token order — the
        ground truth the kernels and the pruned schedules are tested against.
        ``segments`` supplies the runtime ids for kind='segment'."""
        idx = np.arange(seq)
        lo, hi = self.band()
        vis = (idx[:, None] - idx[None, :] >= lo) & (idx[:, None] - idx[None, :] <= hi)
        if self.kind == "document":
            seg = self.segment_array(seq)
            vis &= seg[:, None] == seg[None, :]
        elif self.kind == "segment":
            if segments is None:
                raise ValueError("kind='segment' needs the runtime segment ids")
            seg = np.asarray(segments)
            vis &= seg[:, None] == seg[None, :]
        elif self.kind == "block_sparse":
            nb = len(self.bitmap)
            if seq % nb:
                raise ValueError(f"seq={seq} not divisible by bitmap size {nb}")
            m = seq // nb
            bm = np.asarray(self.bitmap, dtype=bool)
            vis &= np.kron(bm, np.ones((m, m), dtype=bool))
        return vis

    def visible_fraction(self, seq: int) -> float:
        """Fraction of (q, kv) pairs visible — the mask-aware FLOP scaling the
        simulator applies per block (striping spreads it evenly, §3.7)."""
        if self.kind == "full":
            return 1.0

        def causal_pairs(length: int) -> float:
            w = min(self.window or length, length)
            # rows 0..w-1 see i+1 keys; rows w.. see w keys
            return w * (w + 1) / 2.0 + (length - w) * w

        if self.kind in ("causal", "segment"):
            # segment ids are unknown statically; assume one document
            return causal_pairs(seq) / float(seq * seq)
        if self.kind == "document":
            if sum(self.doc_lens) != seq:
                raise ValueError(
                    f"document mask covers {sum(self.doc_lens)} tokens, seq={seq}"
                )
            return sum(causal_pairs(l) for l in self.doc_lens) / float(seq * seq)
        nb = len(self.bitmap)
        return sum(sum(1 for x in row if x) for row in self.bitmap) / float(nb * nb)


def prefix_chunk_visibility(
    q_lo: int, q_hi: int, k_lo: int, k_hi: int, window: Optional[int] = None
) -> str:
    """Classify a continuous-prefill chunk block: queries at absolute
    positions ``[q_lo, q_hi]`` (one prompt chunk) against resident KV
    positions ``[k_lo, k_hi]`` under prefix-causal visibility — pair (p_q,
    p_k) visible iff ``p_k <= p_q`` and, with a sliding window, ``p_k >
    p_q - window``.

    This is the host-side planning mirror of the banded chunk kernel
    (``core.decode_attention.sharded_cache_chunk_decode``): EMPTY blocks are
    what the shard-level window prune skips, FULL blocks need no mask at
    all, PARTIAL blocks hit the band.  All bounds inclusive."""
    if q_hi < q_lo or k_hi < k_lo:
        raise ValueError("empty position range")
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if k_lo > q_hi:  # every key is in the chunk's future
        return EMPTY
    if window is not None and k_hi <= q_lo - window:  # every key fell off
        return EMPTY
    newest_ok = k_hi <= q_lo  # oldest query already sees the newest key
    oldest_ok = window is None or k_lo > q_hi - window  # newest query keeps the oldest key
    if newest_ok and oldest_ok:
        return FULL
    return PARTIAL
