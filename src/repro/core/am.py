"""Assignment-matrix communication model (paper §1, §3.1, §3.8, Table 2).

All volumes are *per device*, expressed either in
  * "chunk units" (1 unit = one Q-sized chunk = (N/n)·d elements — a KV chunk
    is 2 units, matching the paper's Figure-1 arithmetic), or
  * elements (scaled by N·d), via the closed forms of Table 2.

These analytics drive the Table-2 benchmark, the autotuner's cost model and
the tests that pin the implementation's measured communication (counted from
ppermute operands in the lowered HLO) to the theory.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.schedule import validate_comm_overlap
from repro.core.tiling import TileLayout, best_square_a, factorizations

__all__ = [
    "CommModel",
    "ring_volume",
    "ulysses_volume",
    "startrail_volume",
    "mesh_volume",
    "mesh_volume_chunks",
    "commcom_ratio",
    "table2",
    "ppermute_pair_factor",
    "logical_ppermute_steps",
]


def ppermute_pair_factor(comm_overlap: str = "overlap") -> int:
    """HLO collective-permutes emitted per logical ring hop.

    ``bidir`` ships every hop as a half-payload pair (one permute per ring
    direction) whose bytes sum to exactly one hop's traffic, so byte volumes
    stay mode-invariant while the raw op count doubles.  serial/overlap emit
    one permute per hop.
    """
    validate_comm_overlap(comm_overlap)
    return 2 if comm_overlap == "bidir" else 1


def logical_ppermute_steps(op_count: int, comm_overlap: str = "overlap") -> int:
    """Collapse a measured HLO collective-permute op count (see
    ``launch.hlo_analysis.collective_bytes``'s ``collective-permute-count``)
    to logical ring steps: a bidirectional half-payload pair is ONE step's
    traffic — its bytes are summed, its two ops are not two steps.  Keeps the
    measured-vs-theory comparison honest across comm_overlap modes."""
    factor = ppermute_pair_factor(comm_overlap)
    if op_count % factor:
        raise ValueError(
            f"{op_count} collective-permutes cannot be grouped into "
            f"half-payload pairs ({comm_overlap!r} expects multiples of {factor})"
        )
    return op_count // factor


def ring_volume(n: int) -> float:
    """Ring-Attention fwd per-device volume, in units of N*d elements.

    Each device receives n-1 KV chunks of size 2*N*d/n: (2 - 2/n)·N·d.
    """
    return 2.0 - 2.0 / n


def ulysses_volume(n: int) -> float:
    """DS-Ulysses: 4 all-to-alls (Q, K, V, O), each (n-1)/n^2·N·d per device."""
    return 4.0 * (n - 1) / (n * n)


def startrail_volume(n: int, C: Optional[float] = None) -> float:
    """StarTrail with attention-parallel size C (defaults to the paper's
    optimum C = sqrt(n/2)): ((4C-4)/n + 2/C)·N·d."""
    if C is None:
        C = math.sqrt(n / 2.0)
    return (4.0 * C - 4.0) / n + 2.0 / C


def mesh_volume(n: int, a: Optional[int] = None) -> float:
    """Mesh-Attention fwd per-device volume (paper §3.8).

    (a-1) Q chunks + (n/a - 1) KV chunks (x2 for K and V) + (a-1) O chunks,
    each chunk N*d/n elements: (2a/n + 2/a - 4/n)·N·d.
    """
    if a is None:
        a = best_square_a(n)
    b = n // a
    return ((a - 1) + 2.0 * (b - 1) + (a - 1)) / n


def mesh_volume_chunks(n: int, a: int) -> Dict[str, int]:
    """Chunk-count view used by the intro example and the scheduler."""
    return TileLayout(n, a).comm_chunks_per_device()


def commcom_ratio(n: int, a: int) -> float:
    """Communication units per computation block for one device.

    A device computes a*b = n blocks; it communicates (a-1) Q units +
    2*(b-1) KV units + (a-1) O units.  Ring (a=1): 2(n-1)/n — the paper's
    16/9 for n = 9.
    """
    b = n // a
    return ((a - 1) + 2.0 * (b - 1) + (a - 1)) / float(n)


def mesh_backward_volume(n: int, a: int) -> float:
    """Backward pass per-device volume, in units of N*d (paper §3.6).

    Q-group ring carries OdOQ (O, dO, Q: 3 chunk-sized tensors; lse is
    negligible) for a-1 steps; KV-group carries KV (2 units) for b-1 steps;
    dQ (1 unit) is reduced along the Q group (a-1 sends) and dKV (2 units)
    along the KV group (b-1 sends).
    """
    b = n // a
    return (3.0 * (a - 1) + 2.0 * (b - 1) + 1.0 * (a - 1) + 2.0 * (b - 1)) / n


def ring_backward_volume(n: int) -> float:
    """Ring-Attention backward: KV circulates (2 units x (n-1)) and dKV is
    passed around for reduction (2 units x (n-1))."""
    return 4.0 * (n - 1) / n


@dataclasses.dataclass(frozen=True)
class CommModel:
    """Concrete sizes for one attention call.

    seq: global sequence length N; hidden: d = heads*head_dim (Q width);
    kv_hidden: kv_heads*head_dim (K or V width — GQA shrinks KV traffic,
    paper §4.7); bytes: per element.
    """

    seq: int
    hidden: int
    n: int
    kv_hidden: Optional[int] = None
    bytes_per_elem: int = 2
    batch: int = 1

    @property
    def kvh(self) -> int:
        return self.kv_hidden if self.kv_hidden is not None else self.hidden

    def chunk_bytes(self, kind: str) -> int:
        """Bytes of one chunk of the given kind on the wire."""
        base = self.batch * (self.seq // self.n) * self.bytes_per_elem
        if kind in ("q", "o", "dq"):
            return base * self.hidden
        if kind in ("kv", "dkv"):
            return base * 2 * self.kvh
        if kind == "odoq":  # O + dO + Q (lse negligible)
            return base * 3 * self.hidden
        raise ValueError(f"unknown chunk kind {kind!r}")

    def fwd_bytes(self, a: int) -> int:
        b = self.n // a
        return (
            (a - 1) * self.chunk_bytes("q")
            + (b - 1) * self.chunk_bytes("kv")
            + (a - 1) * self.chunk_bytes("o")
        )

    def bwd_bytes(self, a: int) -> int:
        b = self.n // a
        return (
            (a - 1) * self.chunk_bytes("odoq")
            + (b - 1) * self.chunk_bytes("kv")
            + (a - 1) * self.chunk_bytes("dq")
            + (b - 1) * self.chunk_bytes("dkv")
        )

    def ring_fwd_bytes(self) -> int:
        return (self.n - 1) * self.chunk_bytes("kv")

    def ring_bwd_bytes(self) -> int:
        return (self.n - 1) * (self.chunk_bytes("kv") + self.chunk_bytes("dkv"))

    def best_a(self, backward: bool = False) -> int:
        """Divisor of n minimizing the modeled byte volume (GQA shifts the
        optimum away from sqrt(n) because Q and KV chunks have different
        widths — this is the Figure-6 'estimate runtime, pick best' step in
        its pure-communication form)."""
        key = self.bwd_bytes if backward else self.fwd_bytes
        return min((a for a, _ in factorizations(self.n)), key=key)


def table2(n: int) -> Dict[str, float]:
    """Paper Table 2: per-device forward volumes (units of N*d) at size n."""
    return {
        "ring": ring_volume(n),
        "ulysses": ulysses_volume(n),
        "startrail": startrail_volume(n),
        "mesh": mesh_volume(n),
    }
