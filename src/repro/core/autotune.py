"""Tile-shape + schedule autotuning (paper Figure 6).

For a given attention shape and device count n, enumerate every factorization
n = a × b, derive the overlap profile (c_Q, c_KV, …) from the α-β hardware
model (on real hardware: from measurement — the `Profile` type is shared),
generate the greedy schedule, estimate runtime with the event simulator, and
pick the fastest plan.  The result feeds both the benchmarks and the
distributed op, which executes the chosen schedule step-for-step.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core import schedule as S
from repro.core.am import CommModel
from repro.core.masking import MaskSpec
from repro.core.simulator import CostModel, HardwareModel, SimResult, make_cost_model, simulate
from repro.core.tiling import factorizations

__all__ = ["TilePlan", "tune", "plan_for"]


@dataclasses.dataclass(frozen=True)
class TilePlan:
    a: int
    b: int
    fwd: S.Schedule
    bwd: Optional[S.Schedule]
    fwd_sim: SimResult
    bwd_sim: Optional[SimResult]
    profile: S.Profile

    @property
    def total(self) -> float:
        return self.fwd_sim.total + (self.bwd_sim.total if self.bwd_sim else 0.0)

    @property
    def comm_bytes(self) -> int:
        return self.fwd_sim.comm_bytes + (self.bwd_sim.comm_bytes if self.bwd_sim else 0)


def _plan(
    comm: CommModel,
    a: int,
    hw: HardwareModel,
    *,
    causal: bool,
    with_backward: bool,
    allow_concurrent_rings: bool,
    mask: Optional[MaskSpec] = None,
    layout: str = "striped",
    comm_overlap: str = "overlap",
) -> TilePlan:
    b = comm.n // a
    S.validate_comm_overlap(comm_overlap)
    mask = mask if mask is not None else MaskSpec.from_flags(causal)
    # mask-empty slot blocks are pruned from BOTH schedules (their dQ/dKV is
    # zero), which shortens the simulated comm and compute alike.  An
    # analytic seq that does not divide n has no well-defined chunking, so
    # such plans stay unpruned (conservative).
    skip: frozenset = frozenset()
    if comm.seq % comm.n == 0:
        skip = mask.empty_blocks(a, b, layout=layout, n=comm.n, seq=comm.seq)
    # bidir halves t_chunk (per-direction bandwidth), which shrinks the
    # profile's c_* hiding requirements: the greedy search then co-schedules
    # fewer blocks per transfer and prefers tiles whose comm actually hides
    fwd_cost = make_cost_model(
        comm, hw, backward=False, mask=mask, comm_overlap=comm_overlap
    )
    bwd_cost = make_cost_model(
        comm, hw, backward=True, mask=mask, comm_overlap=comm_overlap
    )
    if skip:
        # visible_fraction averages over ALL a*b blocks, but the pruned
        # schedule only runs the survivors — rescale so the per-block time
        # reflects the visible work concentrated in the surviving blocks
        concentrate = (a * b) / (a * b - len(skip))
        fwd_cost = dataclasses.replace(fwd_cost, t_block=fwd_cost.t_block * concentrate)
        bwd_cost = dataclasses.replace(bwd_cost, t_block=bwd_cost.t_block * concentrate)
    fwd_profile = fwd_cost.profile()
    fwd = S.greedy_forward_schedule(
        a, b, fwd_profile, allow_concurrent_rings=allow_concurrent_rings, skip_blocks=skip
    )
    S.validate_schedule(fwd, strict_paper=not allow_concurrent_rings)
    fwd_sim = simulate(fwd, fwd_cost, comm, comm_overlap=comm_overlap)
    bwd = bwd_sim = None
    if with_backward:
        bwd = S.greedy_backward_schedule(
            a, b, bwd_cost.profile(), allow_concurrent_rings=allow_concurrent_rings,
            skip_blocks=skip,
        )
        S.validate_schedule(bwd, strict_paper=not allow_concurrent_rings)
        bwd_sim = simulate(bwd, bwd_cost, comm, comm_overlap=comm_overlap)
    return TilePlan(a=a, b=b, fwd=fwd, bwd=bwd, fwd_sim=fwd_sim, bwd_sim=bwd_sim, profile=fwd_profile)


def tune(
    comm: CommModel,
    hw: HardwareModel = HardwareModel(),
    *,
    causal: bool = False,
    with_backward: bool = True,
    allow_concurrent_rings: bool = False,
    candidates: Optional[List[int]] = None,
    mask: Optional[MaskSpec] = None,
    layout: str = "striped",
    comm_overlap: str = "overlap",
) -> TilePlan:
    """Figure-6 flow: profile -> greedy schedule -> simulate -> argmin.

    ``mask`` supersedes the legacy ``causal`` flag; mask structure changes
    both the per-block cost (visible fraction) and the schedule itself
    (pruned blocks/comm), so it can shift the optimal tile shape.
    ``comm_overlap`` selects the executor's step-cost model (serial |
    overlap | bidir) — hidden comm is free under overlap, so the optimum can
    move relative to the serial model.
    """
    if candidates is None:
        candidates = [a for a, _ in factorizations(comm.n)]
    plans = [
        _plan(
            comm,
            a,
            hw,
            causal=causal,
            with_backward=with_backward,
            allow_concurrent_rings=allow_concurrent_rings,
            mask=mask,
            layout=layout,
            comm_overlap=comm_overlap,
        )
        for a in candidates
    ]
    return min(plans, key=lambda p: p.total)


def plan_for(
    comm: CommModel,
    a: int,
    hw: HardwareModel = HardwareModel(),
    *,
    causal: bool = False,
    with_backward: bool = True,
    allow_concurrent_rings: bool = False,
    mask: Optional[MaskSpec] = None,
    layout: str = "striped",
    comm_overlap: str = "overlap",
) -> TilePlan:
    """Plan for a fixed tile height (a=1 reproduces Ring-Attention)."""
    return _plan(
        comm,
        a,
        hw,
        causal=causal,
        with_backward=with_backward,
        allow_concurrent_rings=allow_concurrent_rings,
        mask=mask,
        layout=layout,
        comm_overlap=comm_overlap,
    )
