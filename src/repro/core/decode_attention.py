"""Distributed flash-decode over a sequence-sharded KV cache.

The paper's locality idea applied to inference: the KV cache is sharded over
the sequence-parallel axis — by *absolute position modulo n* ("striped", the
same striping the causal mask uses for training, §3.7) or contiguously (for
SSM/hybrid archs whose train layout is contiguous).  Each decode step:

  1. the new token's Q is replicated across the axis (it is tiny),
  2. every device computes a partial flash-decode over its local cache slice,
  3. partials are combined with an lse-weighted ``psum`` — per-token
     communication is O(B·H·D), independent of context length.

This replaces head-parallel (Ulysses-style) decode, which is capped at Hkv
devices — with GQA (e.g. kv=8 on a 16-wide model axis) that cap binds, the
sequence-sharded cache does not.  Striping additionally balances appends
(shard t mod n) no matter how long generation runs.

``pos`` may be a scalar (every batch row at the same depth — the static-batch
case) or an int32 ``[B]`` vector of per-slot positions.  The vector form is
what makes continuous batching cheap here: each slot's owner/band math is
independent, so one step serves slots at arbitrary mixed depths with the same
O(B·H·D) per-token combine.

Two cache layouts share the band math:

  * **dense** (``sharded_cache_*``) — each batch row owns a ``[cap/n]``
    local slice; owner shard -> slot row.
  * **paged** (``paged_cache_*``) — rows share one physical page pool
    ``[num_pages, page_size, Hkv, D]`` per device, addressed through an int32
    block table ``[B, max_pages]`` (``serve/kv_pool.py`` owns the allocator);
    owner shard -> (page, offset).  The decode band gathers the row's pages
    into the same local-position order the dense slice has, so the kernel
    call — and therefore the numerics — are identical to the dense path.

Under a sliding window, shards whose whole local slice provably falls outside
every row's window skip the kernel call entirely (``lax.cond``): the skip
branch returns the exact empty-band result (o = 0, lse = NEG_INF), so the
psum combine is bitwise-unchanged.  The bound is shard-uniform — one window
start per shard, rounded down over the batch (min over rows, floored to a
stripe multiple) — so pruning never depends on a single row's depth.

Both decode entries take a ``kernel`` selector:

  * ``"gather"`` / ``"band"`` (the defaults) — the original paths: paged
    gathers the row's pages into a dense local view, then both run the band
    kernel (one vmapped call per row under vector pos).
  * ``"native"`` — the split-K Pallas kernel (``kernels/paged_decode.py``)
    reads the block table in-kernel and indexes the page pool directly; the
    dense cache routes through the SAME kernel by viewing each ``[m]`` row as
    one implicit page run (reshape + identity block table).  Falls back to
    the gather/band oracle under ``REPRO_KERNELS=ref``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import kv_quant
from repro.kernels import ops
from repro.kernels import paged_decode as pk
from repro.kernels.ref import BAND_INF, NEG_INF

__all__ = [
    "sharded_cache_decode",
    "sharded_cache_update",
    "sharded_cache_chunk_update",
    "sharded_cache_chunk_decode",
    "paged_cache_decode",
    "paged_cache_update",
    "paged_cache_chunk_update",
    "paged_cache_chunk_decode",
]


def _owner_slot(pos, i, n: int, m: int, layout: str):
    """(is_owner, slot) for writing global position ``pos``; m = local slots."""
    if layout == "striped":
        return (pos % n) == i, pos // n
    return (pos // m) == i, pos % m


def sharded_cache_update(
    k_cache: jnp.ndarray,  # [B, m, Hkv, D] local slice
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,  # [B, 1, Hkv, D] replicated across the axis
    v_new: jnp.ndarray,
    pos,  # int32 scalar or [B] vector: global position(s) being written
    axis_name: Optional[str],
    n: int,
    layout: str = "striped",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    m = k_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        is_owner, slot = _owner_slot(pos, i, n, m, layout)
        k_upd = lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
        v_upd = lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
        k_cache = jnp.where(is_owner, k_upd, k_cache)
        v_cache = jnp.where(is_owner, v_upd, v_cache)
        return k_cache, v_cache
    # per-slot positions: each batch row scatters into its own slot; rows past
    # capacity (retired slots still ticking) are masked off rather than OOB
    is_owner, slot = _owner_slot(pos, i, n, m, layout)
    write = is_owner & (pos < n * m)
    slot = jnp.clip(slot, 0, m - 1)
    b = jnp.arange(k_cache.shape[0])
    out = []
    for cache, new in ((k_cache, k_new), (v_cache, v_new)):
        cur = cache[b, slot]  # [B, Hkv, D]
        val = jnp.where(write[:, None, None], new[:, 0].astype(cache.dtype), cur)
        out.append(cache.at[b, slot].set(val))
    return out[0], out[1]


def _shard_geometry(i, n: int, m: int, layout: str):
    """(kv_offset, stride) of local slot s -> global position for the band."""
    if layout == "striped":
        return i, n
    return i * m, 1


def _window_nonempty(pos, i, n: int, m: int, layout: str, window: int):
    """Shard-uniform visibility: can ANY local slot of this shard fall inside
    ANY row's window [pos - window + 1, pos]?  The window start is rounded
    DOWN over the batch (min over rows, then floored to a multiple of n) so
    the bound is uniform per shard — conservative: errs toward computing."""
    pos = jnp.asarray(pos, jnp.int32)
    hi_pos = jnp.max(pos)  # newest visible position over the batch
    lo_pos = jnp.maximum(jnp.min(pos) - (window - 1), 0)
    lo_pos = (lo_pos // n) * n  # shard-uniform round-down
    if layout == "striped":
        # shard i holds positions i, i+n, ...: visible iff some j >= 0 with
        # i + n*j in [lo_pos, hi_pos] and j < m
        lo_j = (lo_pos - i + n - 1) // n
        hi_j = (hi_pos - i) // n
        lo_j = jnp.maximum(lo_j, 0)
        return (hi_j >= lo_j) & (lo_j < m) & (hi_pos >= i)
    # contiguous: shard i holds [i*m, (i+1)*m)
    return (i * m <= hi_pos) & ((i + 1) * m - 1 >= lo_pos)


def _psum_combine(o, lse, axis_name: Optional[str], q_dtype):
    """lse-weighted psum of per-shard partials (softmax over disjoint KV)."""
    if axis_name is None:
        return o.astype(q_dtype)
    mx = lax.pmax(lse, axis_name)  # [B, H, 1]
    mx = jnp.maximum(mx, NEG_INF)
    w = jnp.exp(lse - mx)  # zero for empty shards
    num = lax.psum(o.astype(jnp.float32) * w.swapaxes(1, 2)[..., None], axis_name)
    den = lax.psum(w, axis_name)
    den_safe = jnp.where(den > 0, den, 1.0)
    out = num / den_safe.swapaxes(1, 2)[..., None]
    return out.astype(q_dtype)


def _banded_partial(q, k_loc, v_loc, pos, kv_off, stride_kv, hi, scale):
    """Per-shard partial flash-decode; scalar pos batches the kernel call,
    vector pos maps it over rows (the band's q offset differs per row)."""
    if pos.ndim == 0:
        band = jnp.stack(
            [pos, jnp.asarray(kv_off, jnp.int32), jnp.int32(0), jnp.int32(hi)]
        )
        return ops.block_attention(
            q, k_loc, v_loc, band, scale=scale, stride_q=1, stride_kv=stride_kv
        )

    def one(qb, kb, vb, pb):
        band = jnp.stack(
            [pb, jnp.asarray(kv_off, jnp.int32), jnp.int32(0), jnp.int32(hi)]
        )
        ob, lb = ops.block_attention(
            qb[None], kb[None], vb[None], band,
            scale=scale, stride_q=1, stride_kv=stride_kv,
        )
        return ob[0], lb[0]

    return jax.vmap(one)(q, k_loc, v_loc, pos)


def _maybe_pruned(run, q, pos, i, n, m, layout, window, prune):
    """Wrap a shard-partial thunk in the window-prune ``lax.cond``: the kernel
    call is skipped when a sliding window provably hides every local slot.
    The skip branch returns the EXACT empty-band kernel result (o = 0,
    lse = NEG_INF), so downstream combines are bitwise-identical to the
    unpruned program."""
    if not (prune and window):
        return run(None)

    B, S, H = q.shape[0], q.shape[1], q.shape[2]

    def skip(_):
        return (
            jnp.zeros(q.shape, q.dtype),
            jnp.full((B, H, S), NEG_INF, jnp.float32),
        )

    return lax.cond(_window_nonempty(pos, i, n, m, layout, window), run, skip, None)


def _maybe_pruned_partial(
    q, k_loc, v_loc, pos, i, n, m, layout, window, scale, prune,
):
    kv_off, stride_kv = _shard_geometry(i, n, m, layout)
    hi = (window - 1) if window else BAND_INF

    def run(_):
        return _banded_partial(q, k_loc, v_loc, pos, kv_off, stride_kv, hi, scale)

    return _maybe_pruned(run, q, pos, i, n, m, layout, window, prune)


def _native_enabled(kernel: str) -> bool:
    """The split-K kernel serves ``kernel="native"`` except under the pure-jnp
    oracle backend, where the gather/band path (the exact reference the kernel
    is validated against) stands in."""
    if kernel in ("gather", "band"):
        return False
    if kernel != "native":
        raise ValueError(f"unknown decode kernel {kernel!r}")
    return ops.current_backend() != "ref"


def sharded_cache_decode(
    q: jnp.ndarray,  # [B, 1, H, D] new token's query, replicated over the axis
    k_cache: jnp.ndarray,  # [B, m, Hkv, D] local slice
    v_cache: jnp.ndarray,
    pos,  # int32 scalar or [B] vector: current position(s); attends to <= pos
    axis_name: Optional[str],
    n: int,
    *,
    layout: str = "striped",
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prune: bool = True,
    kernel: str = "band",  # band | native (split-K over implicit page runs)
) -> jnp.ndarray:
    """One decode step: partial attention per shard + lse-weighted psum.

    ``kernel="native"`` views each row's dense slice as ONE implicit page run
    (reshape + identity block table) and runs the split-K paged kernel — same
    band math, no per-row vmap, mixed depths spread over the split grid.
    """
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    m = k_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if _native_enabled(kernel):
        B, _, hkv, d = k_cache.shape
        chunk = pk.dense_chunk_for(m)
        chunks = m // chunk
        kv_off, stride_kv = _shard_geometry(i, n, m, layout)
        k_pool = k_cache.reshape(B * chunks, chunk, hkv, d)
        v_pool = v_cache.reshape(B * chunks, chunk, hkv, v_cache.shape[-1])
        bt = jnp.arange(B * chunks, dtype=jnp.int32).reshape(B, chunks)

        def run(_):
            return pk.paged_flash_decode(
                q, k_pool, v_pool, bt, pos, kv_off,
                stride_kv=stride_kv, window=window, scale=scale,
            )

        o, lse = _maybe_pruned(run, q, pos, i, n, m, layout, window, prune)
    else:
        o, lse = _maybe_pruned_partial(
            q, k_cache, v_cache, pos, i, n, m, layout, window, scale, prune
        )
    return _psum_combine(o, lse, axis_name, q.dtype)


# --------------------------------------------------------------------------
# paged cache: physical page pool + block table (serve/kv_pool.py allocator)
# --------------------------------------------------------------------------
#
# Quantized pools: when the pool dtype is int8 / fp8-e4m3 a fp32 scale table
# [num_pages, page_size, Hkv] rides next to each pool, indexed by the SAME
# (page, offset) the pool scatter/gather uses.  Scales are per token per
# kv-head (amax over D only — see core/kv_quant.py), so every write path
# (decode append, chunk prefill, speculative verify) quantizes its new
# tokens independently and never re-quantizes resident positions.  The
# update entries quantize when handed scale tables; the gather oracle
# dequantizes; the native kernel dequantizes in VMEM after each page's DMA.


def _pool_kv_dtype(pool) -> str:
    """Storage mode of a pool array, inferred from its dtype."""
    if pool.dtype == jnp.int8:
        return "int8"
    f8 = kv_quant.fp8_dtype()
    if f8 is not None and pool.dtype == jnp.dtype(f8):
        return "fp8"
    return "fp"


def _page_coords(pos, i, n: int, page_size: int, max_pages: int, layout: str):
    """Owner shard -> (logical page, offset) for global position ``pos``.
    The paged analogue of ``_owner_slot``: the dense local slot j just splits
    into (j // page_size, j % page_size)."""
    m = max_pages * page_size  # virtual local capacity
    is_owner, j = _owner_slot(pos, i, n, m, layout)
    return is_owner & (pos < n * m), j // page_size, j % page_size


def paged_cache_update(
    k_pool: jnp.ndarray,  # [num_pages, page_size, Hkv, D] local page pool
    v_pool: jnp.ndarray,
    k_new: jnp.ndarray,  # [B, 1, Hkv, D] replicated across the axis
    v_new: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32; -1 = unallocated
    pos,  # int32 scalar or [B] vector
    axis_name: Optional[str],
    n: int,
    layout: str = "striped",
    k_scale: Optional[jnp.ndarray] = None,  # [num_pages, page_size, Hkv] f32
    v_scale: Optional[jnp.ndarray] = None,
):
    """Scatter-by-block-table append: owner shard -> (page, offset).  Rows
    past virtual capacity or pointing at unallocated pages are dropped (the
    allocator only hands live slots a writable tail page).  With scale
    tables the new token is quantized to the pool dtype and its per-(token,
    head) scales scatter through the SAME coordinates; returns a 4-tuple
    ``(k_pool, v_pool, k_scale, v_scale)`` in that case."""
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    num_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    max_pages = block_table.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (k_new.shape[0],))
    write, lp, off = _page_coords(pos, i, n, page_size, max_pages, layout)
    lp = jnp.clip(lp, 0, max_pages - 1)
    b = jnp.arange(k_new.shape[0])
    phys = block_table[b, lp]
    write = write & (phys >= 0)
    # out-of-range page index -> scatter drops the row entirely
    page_idx = jnp.where(write, phys, num_pages)
    quantized = k_scale is not None
    kv_dtype = _pool_kv_dtype(k_pool)
    out = []
    for pool, scales, new in ((k_pool, k_scale, k_new), (v_pool, v_scale, v_new)):
        if quantized:
            q, s = kv_quant.quantize(new[:, 0], kv_dtype)
            out.append(pool.at[page_idx, off].set(q, mode="drop"))
            out.append(scales.at[page_idx, off].set(s, mode="drop"))
        else:
            out.append(pool.at[page_idx, off].set(new[:, 0].astype(pool.dtype), mode="drop"))
    if quantized:
        return out[0], out[2], out[1], out[3]
    return out[0], out[1]


def paged_cache_gather(k_pool, v_pool, block_table, k_scale=None, v_scale=None):
    """Materialize each row's dense local view from its pages: [B, m, Hkv, D]
    with m = max_pages * page_size, in the SAME local-position order as the
    dense cache slice (so the band math is shared verbatim).  Unallocated
    pages clamp to page 0 — whatever is there is hidden behind the band.
    Quantized pools (scale tables passed) gather scales through the same
    index and dequantize to fp32 — the reference path for REPRO_KERNELS=ref
    and non-Pallas platforms."""
    num_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    idx = jnp.clip(block_table, 0, num_pages - 1)  # [B, max_pages]
    out = []
    for pool, scales in ((k_pool, k_scale), (v_pool, v_scale)):
        pages = pool[idx]  # [B, max_pages, page_size, Hkv, D]
        if scales is not None:
            pages = kv_quant.dequantize(pages, scales[idx])
        out.append(pages.reshape((idx.shape[0], -1) + pool.shape[2:]))
    return out[0], out[1]


def paged_cache_decode(
    q: jnp.ndarray,  # [B, 1, H, D] replicated over the axis
    k_pool: jnp.ndarray,  # [num_pages, page_size, Hkv, D] local page pool
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32
    pos,  # int32 scalar or [B] vector
    axis_name: Optional[str],
    n: int,
    *,
    layout: str = "striped",
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prune: bool = True,
    kernel: str = "gather",  # gather | native (block table read in-kernel)
    k_scale: Optional[jnp.ndarray] = None,  # [num_pages, page_size, Hkv] f32
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Paged decode partial + psum combine.  ``kernel="gather"`` materializes
    each row's dense local view from its pages and runs the identical banded
    partial the dense path uses (the correctness oracle); ``"native"`` hands
    the pool and the block table straight to the split-K Pallas kernel — no
    gathered intermediate, HBM traffic follows allocated depth.  Quantized
    pools hand their scale tables along: the native kernel dequantizes in
    VMEM after each page's DMA, the gather path dequantizes in the gather."""
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    page_size, max_pages = k_pool.shape[1], block_table.shape[1]
    m = max_pages * page_size
    pos = jnp.asarray(pos, jnp.int32)
    if _native_enabled(kernel):
        kv_off, stride_kv = _shard_geometry(i, n, m, layout)

        def run(_):
            return pk.paged_flash_decode(
                q, k_pool, v_pool, block_table, pos, kv_off,
                stride_kv=stride_kv, window=window, scale=scale,
                k_scale=k_scale, v_scale=v_scale,
            )

        o, lse = _maybe_pruned(run, q, pos, i, n, m, layout, window, prune)
    else:
        k_loc, v_loc = paged_cache_gather(k_pool, v_pool, block_table, k_scale, v_scale)
        o, lse = _maybe_pruned_partial(
            q, k_loc, v_loc, pos, i, n, m, layout, window, scale, prune
        )
    return _psum_combine(o, lse, axis_name, q.dtype)


# --------------------------------------------------------------------------
# chunked prefill: multi-token append + prefix-causal chunk attention
# --------------------------------------------------------------------------
#
# Continuous prefill feeds a prompt into a live slot C tokens at a time.  A
# chunk is just C consecutive decode writes batched into one launch: row b
# scatters positions starts[b] .. starts[b]+lens[b]-1 through the SAME
# owner/stripe math the single-token path uses, and the chunk's attention is
# the same banded partial with a multi-row q — row i of the chunk sits at
# global position starts[b]+i, so band = (starts[b], kv_off, 0, hi) with
# stride_q=1 is exactly prefix-causal over resident positions.  Pad rows
# (i >= lens[b]) compute garbage but never write; softmax is per-row so they
# cannot contaminate real rows.


def sharded_cache_chunk_update(
    k_cache: jnp.ndarray,  # [B, m, Hkv, D] local slice
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,  # [B, C, Hkv, D] replicated across the axis
    v_new: jnp.ndarray,
    starts: jnp.ndarray,  # [B] int32: global position of each row's chunk base
    lens: jnp.ndarray,  # [B] int32: valid tokens per row (0 = inactive row)
    write_starts: jnp.ndarray,  # [B] int32: skip writes below this position
    axis_name: Optional[str],
    n: int,
    layout: str = "striped",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter a C-token chunk per row into the local cache slice.  Positions
    below ``write_starts`` (a shared prefix already resident) and at/after
    ``starts + lens`` are dropped; distinct owned positions of one row map to
    distinct local slots, so the scatter has no duplicate coordinates."""
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    B, C = k_new.shape[0], k_new.shape[1]
    m = k_cache.shape[1]
    starts = jnp.asarray(starts, jnp.int32)
    c = jnp.arange(C, dtype=jnp.int32)
    pos = starts[:, None] + c[None, :]  # [B, C]
    is_owner, slot = _owner_slot(pos, i, n, m, layout)
    write = (
        is_owner
        & (c[None, :] < lens[:, None])
        & (pos >= write_starts[:, None])
        & (pos < n * m)
    )
    slot = jnp.clip(slot, 0, m - 1)
    b = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    # out-of-range batch index -> scatter drops the element entirely
    b_idx = jnp.where(write, b, B)
    out = []
    for cache, new in ((k_cache, k_new), (v_cache, v_new)):
        out.append(cache.at[b_idx, slot].set(new.astype(cache.dtype), mode="drop"))
    return out[0], out[1]


def _chunk_banded_partial(q, k_loc, v_loc, starts, kv_off, stride_kv, hi, scale):
    """Per-shard partial for a [B, C, H, D] chunk: one banded kernel call per
    row, with the band's q offset at that row's chunk base."""

    def one(qb, kb, vb, sb):
        band = jnp.stack(
            [sb, jnp.asarray(kv_off, jnp.int32), jnp.int32(0), jnp.int32(hi)]
        )
        ob, lb = ops.block_attention(
            qb[None], kb[None], vb[None], band,
            scale=scale, stride_q=1, stride_kv=stride_kv,
        )
        return ob[0], lb[0]

    return jax.vmap(one)(q, k_loc, v_loc, starts)


def sharded_cache_chunk_decode(
    q: jnp.ndarray,  # [B, C, H, D] chunk queries, replicated over the axis
    k_cache: jnp.ndarray,  # [B, m, Hkv, D] local slice (chunk already written)
    v_cache: jnp.ndarray,
    starts,  # int32 [B]: global position of each row's chunk base
    axis_name: Optional[str],
    n: int,
    *,
    layout: str = "striped",
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prune: bool = True,
) -> jnp.ndarray:
    """Prefix-causal chunk attention: row i of the chunk attends to global
    positions <= starts + i (within the window).  Same partial + psum combine
    as single-token decode; the window-prune bound widens by C - 1 because the
    oldest row's window starts C - 1 earlier than the newest's."""
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    m = k_cache.shape[1]
    C = q.shape[1]
    starts = jnp.asarray(starts, jnp.int32)
    kv_off, stride_kv = _shard_geometry(i, n, m, layout)
    hi = (window - 1) if window else BAND_INF

    def run(_):
        return _chunk_banded_partial(
            q, k_cache, v_cache, starts, kv_off, stride_kv, hi, scale
        )

    win_eff = (window + C - 1) if window else None
    o, lse = _maybe_pruned(run, q, starts + (C - 1), i, n, m, layout, win_eff, prune)
    return _psum_combine(o, lse, axis_name, q.dtype)


def paged_cache_chunk_update(
    k_pool: jnp.ndarray,  # [num_pages, page_size, Hkv, D] local page pool
    v_pool: jnp.ndarray,
    k_new: jnp.ndarray,  # [B, C, Hkv, D] replicated across the axis
    v_new: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32; -1 = unallocated
    starts: jnp.ndarray,  # [B] int32
    lens: jnp.ndarray,  # [B] int32 (0 = inactive row)
    write_starts: jnp.ndarray,  # [B] int32: skip writes below this position
    axis_name: Optional[str],
    n: int,
    layout: str = "striped",
    k_scale: Optional[jnp.ndarray] = None,  # [num_pages, page_size, Hkv] f32
    v_scale: Optional[jnp.ndarray] = None,
):
    """Chunk append through the block table: the allocator pre-books every
    prompt page at admission, so a chunk never lands on an unallocated page;
    shared-prefix positions (below ``write_starts``) are skipped so CoW pages
    are never touched mid-prefill.  With scale tables (quantized pool) each
    chunk token quantizes independently — per-(token, head) scales mean
    resident positions are never re-quantized — and continuous prefill +
    speculative verify write quantized exactly like decode.  Returns a
    4-tuple ``(k_pool, v_pool, k_scale, v_scale)`` in that case."""
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    num_pages, page_size = k_pool.shape[0], k_pool.shape[1]
    max_pages = block_table.shape[1]
    B, C = k_new.shape[0], k_new.shape[1]
    starts = jnp.asarray(starts, jnp.int32)
    c = jnp.arange(C, dtype=jnp.int32)
    pos = starts[:, None] + c[None, :]  # [B, C]
    write, lp, off = _page_coords(pos, i, n, page_size, max_pages, layout)
    write = write & (c[None, :] < lens[:, None]) & (pos >= write_starts[:, None])
    lp = jnp.clip(lp, 0, max_pages - 1)
    b = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    phys = block_table[b, lp]
    write = write & (phys >= 0)
    page_idx = jnp.where(write, phys, num_pages)
    quantized = k_scale is not None
    kv_dtype = _pool_kv_dtype(k_pool)
    out = []
    for pool, scales, new in ((k_pool, k_scale, k_new), (v_pool, v_scale, v_new)):
        if quantized:
            q, s = kv_quant.quantize(new, kv_dtype)
            out.append(pool.at[page_idx, off].set(q, mode="drop"))
            out.append(scales.at[page_idx, off].set(s, mode="drop"))
        else:
            out.append(pool.at[page_idx, off].set(new.astype(pool.dtype), mode="drop"))
    if quantized:
        return out[0], out[2], out[1], out[3]
    return out[0], out[1]


def paged_cache_chunk_decode(
    q: jnp.ndarray,  # [B, C, H, D] replicated over the axis
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, max_pages] int32
    starts,  # int32 [B]
    axis_name: Optional[str],
    n: int,
    *,
    layout: str = "striped",
    window: Optional[int] = None,
    scale: Optional[float] = None,
    prune: bool = True,
    k_scale: Optional[jnp.ndarray] = None,  # [num_pages, page_size, Hkv] f32
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Paged chunk attention: gather the row's pages into the dense local view
    and run the identical banded chunk partial (chunks are a prefill-side
    path — the split-K decode kernel stays single-token).  Quantized pools
    dequantize in the gather."""
    i = lax.axis_index(axis_name) if axis_name is not None else 0
    page_size, max_pages = k_pool.shape[1], block_table.shape[1]
    m = max_pages * page_size
    C = q.shape[1]
    starts = jnp.asarray(starts, jnp.int32)
    k_loc, v_loc = paged_cache_gather(k_pool, v_pool, block_table, k_scale, v_scale)
    kv_off, stride_kv = _shard_geometry(i, n, m, layout)
    hi = (window - 1) if window else BAND_INF

    def run(_):
        return _chunk_banded_partial(q, k_loc, v_loc, starts, kv_off, stride_kv, hi, scale)

    win_eff = (window + C - 1) if window else None
    o, lse = _maybe_pruned(run, q, starts + (C - 1), i, n, m, layout, win_eff, prune)
    return _psum_combine(o, lse, axis_name, q.dtype)


# backwards-compatible aliases (striped is the default layout)
striped_cache_update = sharded_cache_update
striped_cache_decode = sharded_cache_decode
