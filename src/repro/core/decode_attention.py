"""Distributed flash-decode over a sequence-sharded KV cache.

The paper's locality idea applied to inference: the KV cache is sharded over
the sequence-parallel axis — by *absolute position modulo n* ("striped", the
same striping the causal mask uses for training, §3.7) or contiguously (for
SSM/hybrid archs whose train layout is contiguous).  Each decode step:

  1. the new token's Q is replicated across the axis (it is tiny),
  2. every device computes a partial flash-decode over its local cache slice,
  3. partials are combined with an lse-weighted ``psum`` — per-token
     communication is O(B·H·D), independent of context length.

This replaces head-parallel (Ulysses-style) decode, which is capped at Hkv
devices — with GQA (e.g. kv=8 on a 16-wide model axis) that cap binds, the
sequence-sharded cache does not.  Striping additionally balances appends
(shard t mod n) no matter how long generation runs.

``pos`` may be a scalar (every batch row at the same depth — the static-batch
case) or an int32 ``[B]`` vector of per-slot positions.  The vector form is
what makes continuous batching cheap here: each slot's owner/band math is
independent, so one step serves slots at arbitrary mixed depths with the same
O(B·H·D) per-token combine.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.kernels.ref import BAND_INF, NEG_INF

__all__ = ["sharded_cache_decode", "sharded_cache_update"]


def _owner_slot(pos, i, n: int, m: int, layout: str):
    """(is_owner, slot) for writing global position ``pos``; m = local slots."""
    if layout == "striped":
        return (pos % n) == i, pos // n
    return (pos // m) == i, pos % m


def sharded_cache_update(
    k_cache: jnp.ndarray,  # [B, m, Hkv, D] local slice
    v_cache: jnp.ndarray,
    k_new: jnp.ndarray,  # [B, 1, Hkv, D] replicated across the axis
    v_new: jnp.ndarray,
    pos,  # int32 scalar or [B] vector: global position(s) being written
    axis_name: str,
    n: int,
    layout: str = "striped",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    i = lax.axis_index(axis_name)
    m = k_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        is_owner, slot = _owner_slot(pos, i, n, m, layout)
        k_upd = lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
        v_upd = lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
        k_cache = jnp.where(is_owner, k_upd, k_cache)
        v_cache = jnp.where(is_owner, v_upd, v_cache)
        return k_cache, v_cache
    # per-slot positions: each batch row scatters into its own slot; rows past
    # capacity (retired slots still ticking) are masked off rather than OOB
    is_owner, slot = _owner_slot(pos, i, n, m, layout)
    write = is_owner & (pos < n * m)
    slot = jnp.clip(slot, 0, m - 1)
    b = jnp.arange(k_cache.shape[0])
    out = []
    for cache, new in ((k_cache, k_new), (v_cache, v_new)):
        cur = cache[b, slot]  # [B, Hkv, D]
        val = jnp.where(write[:, None, None], new[:, 0].astype(cache.dtype), cur)
        out.append(cache.at[b, slot].set(val))
    return out[0], out[1]


def sharded_cache_decode(
    q: jnp.ndarray,  # [B, 1, H, D] new token's query, replicated over the axis
    k_cache: jnp.ndarray,  # [B, m, Hkv, D] local slice
    v_cache: jnp.ndarray,
    pos,  # int32 scalar or [B] vector: current position(s); attends to <= pos
    axis_name: str,
    n: int,
    *,
    layout: str = "striped",
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """One decode step: partial attention per shard + lse-weighted psum."""
    i = lax.axis_index(axis_name)
    m = k_cache.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    hi = (window - 1) if window else BAND_INF
    # global position of local slot s: striped: i + n*s; contiguous: i*m + s
    if layout == "striped":
        kv_off, stride_kv = i, n
    else:
        kv_off, stride_kv = i * m, 1
    if pos.ndim == 0:
        band = jnp.stack(
            [
                pos,
                jnp.asarray(kv_off, jnp.int32),
                jnp.int32(0),
                jnp.int32(hi),
            ]
        )
        o, lse = ops.block_attention(
            q, k_cache, v_cache, band, scale=scale, stride_q=1, stride_kv=stride_kv
        )
    else:
        # per-slot depths: the band's q offset differs per batch row, so map
        # the kernel over the batch (the psum combine below stays batched)
        def one(qb, kb, vb, pb):
            band = jnp.stack(
                [pb, jnp.asarray(kv_off, jnp.int32), jnp.int32(0), jnp.int32(hi)]
            )
            ob, lb = ops.block_attention(
                qb[None], kb[None], vb[None], band,
                scale=scale, stride_q=1, stride_kv=stride_kv,
            )
            return ob[0], lb[0]

        o, lse = jax.vmap(one)(q, k_cache, v_cache, pos)
    # combine partials across shards: softmax-weighted by exp(lse - max)
    mx = lax.pmax(lse, axis_name)  # [B, H, 1]
    mx = jnp.maximum(mx, NEG_INF)
    w = jnp.exp(lse - mx)  # zero for empty shards
    num = lax.psum(o.astype(jnp.float32) * w.swapaxes(1, 2)[..., None], axis_name)
    den = lax.psum(w, axis_name)
    den_safe = jnp.where(den > 0, den, 1.0)
    out = num / den_safe.swapaxes(1, 2)[..., None]
    return out.astype(q.dtype)


# backwards-compatible aliases (striped is the default layout)
striped_cache_update = sharded_cache_update
striped_cache_decode = sharded_cache_decode
