"""Unified distributed-attention dispatch: "which attention" is a config.

The paper's pitch is that Mesh-Attention *generalizes* the existing
distributed-attention family — Ring-Attention is the (a=1, b=n) tile, DS-
Ulysses the head-parallel alternative, flash-decode the inference analogue —
so the repo routes every attention call through ONE seam:

    distributed_attention(q, k, v, cfg=plan, ctx=ctx)

``AttentionPlanConfig`` names a backend from the **registry** (``mesh``,
``ring``, ``ulysses``, ``decode``, ``local-flash``) plus the tile/mask/block
knobs; ``plan_from_ctx`` derives one from a ``ParallelCtx`` the way the model
layers used to hand-wire it.  When ``autotune=True`` the (a, b) tile and the
greedy comm/compute schedules come from the Figure-6 flow
(``autotune.plan_for`` / ``autotune.tune`` over the event simulator), with an
on-disk **plan cache** keyed by (shape, dtype, n, hardware profile) so
repeated serve/train launches skip re-tuning.

Layering: this module may import every backend under ``core/`` and the
``compat`` shim; nothing outside ``core/`` (and tests) imports backends
directly anymore.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import autotune
from repro.core import kv_quant
from repro.core import schedule as S
from repro.core.am import CommModel
from repro.core.decode_attention import (
    paged_cache_chunk_decode,
    paged_cache_chunk_update,
    paged_cache_decode,
    paged_cache_update,
    sharded_cache_chunk_decode,
    sharded_cache_chunk_update,
    sharded_cache_decode,
    sharded_cache_update,
)
from repro.core.masking import MaskSpec
from repro.core.mesh_attention import MeshAttentionConfig, mesh_attention, mesh_attention_wire
from repro.core.simulator import HardwareModel
from repro.core.tiling import best_square_a, stripe_permutation
from repro.core.ulysses import ulysses_attention
from repro.kernels import ops
from repro.kernels.ref import BAND_INF

__all__ = [
    "AttentionPlanConfig",
    "Backend",
    "register_backend",
    "get_backend",
    "available_backends",
    "resolve_backend_name",
    "distributed_attention",
    "attention_in_shard_map",
    "decode_attention_step",
    "chunk_attention_step",
    "latent_wire_attention",
    "plan_from_ctx",
    "plan_schedules",
    "plan_cache_dir",
    "clear_plan_cache",
    "HW_PROFILES",
]


# --------------------------------------------------------------------------
# plan config
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionPlanConfig:
    """Declarative selection + configuration of a distributed-attention call.

    ``backend="auto"`` resolves to ``local-flash`` when the sequence axis is
    unsharded (n <= 1) and to ``mesh`` otherwise.  ``a=None`` on the mesh
    backend means: autotune via the simulator when ``autotune`` is set,
    otherwise the sqrt-n heuristic (``best_square_a``).
    """

    backend: str = "auto"
    axis_name: Optional[str] = None
    n: int = 1
    a: Optional[int] = None
    causal: bool = False
    window: Optional[int] = None
    layout: str = "striped"  # striped (§3.7) | contiguous (SSM/hybrid, Ulysses)
    scale: Optional[float] = None
    block_q: int = 128
    block_kv: int = 128
    bwd_wire: str = "qdod"
    allow_concurrent_rings: bool = False
    mask: Optional[MaskSpec] = None  # first-class mask; supersedes causal/window
    # ring-transport mode (schedule.COMM_OVERLAP_MODES): serial pins each
    # step's permutes ahead of its blocks, overlap (default) leaves them in
    # flight during the blocks, bidir splits each hop into a half-payload
    # ppermute pair over both ring directions.  Bitwise-equal; changes the
    # simulated step cost, so it is part of the plan-cache key.
    comm_overlap: str = "overlap"
    paged: bool = False  # decode reads/writes a page pool through a block table
    # decode kernel variant: "auto" -> "native" (the split-K Pallas kernel
    # reading the block table in-kernel, kernels/paged_decode.py) for the
    # paged cache wherever Pallas runs (TPU / REPRO_KERNELS=pallas), the
    # gather/band reference elsewhere; "native"/"gather" force either.
    decode_kernel: str = "auto"
    # KV-pool storage precision (paged only): "fp" keeps the cache dtype;
    # "int8"/"fp8" store pages quantized with fp32 per-(token, kv-head)
    # scale tables dequantized in-kernel (core/kv_quant.py).
    kv_dtype: str = "fp"
    # --- Figure-6 autotuning (simulator-planned tile + schedules) ---
    autotune: bool = False
    with_backward: bool = True
    hw_profile: str = "default"
    plan_cache_dir: Optional[str] = None  # None -> $REPRO_PLAN_CACHE_DIR or ~/.cache

    def __post_init__(self):
        S.validate_comm_overlap(self.comm_overlap)
        if self.mask is not None and (self.causal or self.window is not None):
            raise ValueError("pass either mask= or the legacy causal/window flags, not both")
        if self.decode_kernel not in ("auto", "native", "gather"):
            raise ValueError(
                f"unknown decode_kernel {self.decode_kernel!r}; "
                "expected auto | native | gather"
            )
        if self.kv_dtype not in kv_quant.KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r}; expected "
                + " | ".join(kv_quant.KV_DTYPES)
            )
        if self.kv_dtype != "fp" and not self.paged:
            raise ValueError(
                "kv_dtype quantization stores pages + scale tables; it "
                "requires the paged cache (paged=True)"
            )

    def resolved_backend(self) -> str:
        return resolve_backend_name(self)

    def mask_spec(self) -> MaskSpec:
        if self.mask is not None:
            return self.mask
        return MaskSpec.from_flags(self.causal, self.window)


def _resolve_decode_kernel(kernel: Optional[str], paged: bool) -> str:
    """"auto" -> the split-K native kernel for the paged cache (the gather
    intermediate is exactly what it exists to kill) wherever the backend
    policy actually runs Pallas (TPU, or REPRO_KERNELS=pallas correctness
    runs) — "auto" off-TPU keeps the fast XLA gather/band reference, same
    policy as every other kernel (kernels/ops.py).  Explicit "native" runs
    the kernel interpret-mode off-TPU (except REPRO_KERNELS=ref, where
    ``_native_enabled`` serves it with the gather oracle); "gather" forces
    the oracle.  The dense cache defaults to the band path either way."""
    if kernel in (None, "auto"):
        if paged and ops.pallas_enabled():
            return "native"
        return "gather" if paged else "band"
    if kernel not in ("native", "gather"):
        # every route validates here (the n==1 paths never build a plan
        # config), so a typo'd variant fails loudly instead of silently
        # measuring the default path
        raise ValueError(
            f"unknown decode_kernel {kernel!r}; expected auto | native | gather"
        )
    return "band" if (kernel == "gather" and not paged) else kernel


def plan_from_ctx(
    ctx,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    layout: str = "striped",
    scale: Optional[float] = None,
    backend: Optional[str] = None,
    mask: Optional[MaskSpec] = None,
) -> AttentionPlanConfig:
    """Derive the attention plan a ``ParallelCtx`` implies (the knobs the
    model layers used to wire into ``MeshAttentionConfig`` by hand).
    ``mask`` supersedes the legacy causal/window pair."""
    impl = backend or ctx.attn_impl
    return AttentionPlanConfig(
        backend=impl,
        axis_name=ctx.sp_axis,
        n=ctx.sp_size,
        a=1 if impl == "ring" else ctx.mesh_a,
        causal=causal if mask is None else False,
        window=window if mask is None else None,
        mask=mask,
        layout=layout,
        scale=scale,
        block_q=ctx.block_q,
        block_kv=ctx.block_kv,
        bwd_wire=ctx.bwd_wire,
        allow_concurrent_rings=ctx.allow_concurrent_rings,
        comm_overlap=getattr(ctx, "comm_overlap", "overlap"),
        autotune=getattr(ctx, "attn_autotune", False),
        plan_cache_dir=getattr(ctx, "plan_cache_dir", None),
    )


# --------------------------------------------------------------------------
# backend registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered distributed-attention implementation.

    ``apply`` runs INSIDE ``shard_map`` on device-local chunks (exactly like
    the raw ops in ``core/``); ``step`` is the incremental-decode entry for
    cache-based backends.  Either may be None when the mode is unsupported.
    """

    name: str
    apply: Optional[Callable] = None  # (q, k, v, cfg, seg=None) -> o, local chunks
    step: Optional[Callable] = None  # decode step, see decode_attention_step
    description: str = ""


_REGISTRY: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown attention backend {name!r}; registered backends: "
            f"{', '.join(available_backends())}"
        ) from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


def resolve_backend_name(cfg: AttentionPlanConfig) -> str:
    if cfg.backend == "auto":
        return "local-flash" if cfg.n <= 1 else "mesh"
    get_backend(cfg.backend)  # raise early on unknown names
    return cfg.backend


# --------------------------------------------------------------------------
# simulator-planned schedules + on-disk plan cache
# --------------------------------------------------------------------------

HW_PROFILES: Dict[str, HardwareModel] = {
    "default": HardwareModel(),
    "tpu_v5e": HardwareModel(),
    # the paper's calibrated GPU cluster (also used by benchmarks/common.py)
    "paper_a100": HardwareModel(
        peak_flops=312e12, hbm_bw=2039e9, link_bw=25e9, attn_efficiency=0.45
    ),
}

_MEM_CACHE: Dict[str, Tuple[int, S.Schedule, Optional[S.Schedule]]] = {}


def plan_cache_dir(cfg: Optional[AttentionPlanConfig] = None) -> str:
    if cfg is not None and cfg.plan_cache_dir:
        return cfg.plan_cache_dir
    env = os.environ.get("REPRO_PLAN_CACHE_DIR")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "attention-plans")


def clear_plan_cache(cfg: Optional[AttentionPlanConfig] = None) -> None:
    _MEM_CACHE.clear()
    d = plan_cache_dir(cfg)
    if os.path.isdir(d):
        for fn in os.listdir(d):
            if fn.endswith(".json"):
                os.unlink(os.path.join(d, fn))


def _plan_key(cfg: AttentionPlanConfig, comm: CommModel, hw: HardwareModel) -> Tuple[str, dict]:
    """Cache key over everything the simulated plan depends on: the call's
    shape/dtype geometry, device count, tile request, mask, layout, and
    hardware profile.  The mask signature keeps masked and unmasked plans for
    the same (shape, dtype, n, hw) from ever colliding — mask structure
    changes both block cost and the pruned schedule."""
    desc = {
        "v": 5,
        "n": comm.n,
        "a": cfg.a,
        "seq": comm.seq,
        "hidden": comm.hidden,
        "kv_hidden": comm.kvh,
        "bytes_per_elem": comm.bytes_per_elem,
        "batch": comm.batch,
        "mask": cfg.mask_spec().signature(),
        "layout": cfg.layout,
        # paged and dense decode stacks must never share a plan entry: the
        # paged gather changes the achievable tile/arithmetic intensity
        "paged": cfg.paged,
        # gather and native decode kernels have different HBM traffic models,
        # so their plans must not collide either
        "decode_kernel": _resolve_decode_kernel(cfg.decode_kernel, cfg.paged),
        # quantized pools change per-page HBM bytes (1-byte elements + scale
        # tiles vs fp K/V) — fp and int8/fp8 plans must never collide
        "kv_dtype": cfg.kv_dtype,
        "with_backward": cfg.with_backward,
        "allow_concurrent_rings": cfg.allow_concurrent_rings,
        # overlap modes price steps differently (serial: comm+compute;
        # overlap: max+residual; bidir: per-direction bandwidth), so the
        # tuned tile/schedule may differ per mode — never share entries
        "comm_overlap": cfg.comm_overlap,
        "hw_profile": cfg.hw_profile,
        "hw": dataclasses.asdict(hw),
    }
    blob = json.dumps(desc, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest(), desc


def plan_schedules(
    cfg: AttentionPlanConfig, comm: CommModel
) -> Tuple[int, S.Schedule, Optional[S.Schedule]]:
    """Figure-6 planning through the cache: returns (a, fwd, bwd).

    ``cfg.a`` fixed -> ``autotune.plan_for`` (a=1 degenerates to the ring
    backend's schedule shape); ``cfg.a`` None -> ``autotune.tune`` argmin over
    every factorization of n.  Results are memoized in-process and persisted
    as JSON under :func:`plan_cache_dir` so later launches skip the simulator.
    """
    hw = HW_PROFILES.get(cfg.hw_profile)
    if hw is None:
        raise ValueError(
            f"unknown hw_profile {cfg.hw_profile!r}; known: {sorted(HW_PROFILES)}"
        )
    key, desc = _plan_key(cfg, comm, hw)
    if key in _MEM_CACHE:
        return _MEM_CACHE[key]

    cache_dir = plan_cache_dir(cfg)
    path = os.path.join(cache_dir, f"{key}.json")
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
            fwd = S.schedule_from_json(payload["fwd"])
            bwd = S.schedule_from_json(payload["bwd"]) if payload.get("bwd") else None
            out = (int(payload["a"]), fwd, bwd)
            _MEM_CACHE[key] = out
            return out
        except (KeyError, TypeError, ValueError, json.JSONDecodeError):
            pass  # corrupt entry: fall through and re-plan

    kw = dict(
        mask=cfg.mask_spec(),
        layout=cfg.layout,
        with_backward=cfg.with_backward,
        allow_concurrent_rings=cfg.allow_concurrent_rings,
        comm_overlap=cfg.comm_overlap,
    )
    if cfg.a is not None:
        plan = autotune.plan_for(comm, cfg.a, hw, **kw)
    else:
        plan = autotune.tune(comm, hw, **kw)

    payload = {
        "key": desc,
        "a": plan.a,
        "b": plan.b,
        "fwd": S.schedule_to_json(plan.fwd),
        "bwd": S.schedule_to_json(plan.bwd) if plan.bwd else None,
        "sim": {"total_s": plan.total, "comm_bytes": plan.comm_bytes},
    }
    os.makedirs(cache_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)  # atomic: concurrent launchers race benignly

    out = (plan.a, plan.fwd, plan.bwd)
    _MEM_CACHE[key] = out
    return out


def _comm_model_for(cfg: AttentionPlanConfig, q, k) -> CommModel:
    """CommModel from the call's global-logical shapes (q: [B, S, H, D])."""
    return CommModel(
        seq=int(q.shape[1]),
        hidden=int(q.shape[2] * q.shape[3]),
        n=cfg.n,
        kv_hidden=int(k.shape[2] * k.shape[3]),
        bytes_per_elem=int(jnp.dtype(q.dtype).itemsize),
        batch=int(q.shape[0]),
    )


# --------------------------------------------------------------------------
# backend implementations (run inside shard_map)
# --------------------------------------------------------------------------


def _mesh_cfg(
    cfg: AttentionPlanConfig,
    *,
    a: int,
    fwd: Optional[S.Schedule] = None,
    bwd: Optional[S.Schedule] = None,
) -> MeshAttentionConfig:
    return MeshAttentionConfig(
        axis_name=cfg.axis_name,
        n=cfg.n,
        a=a,
        causal=cfg.causal if cfg.mask is None else False,
        window=cfg.window if cfg.mask is None else None,
        mask=cfg.mask,
        layout=cfg.layout,
        scale=cfg.scale,
        fwd_schedule=fwd,
        bwd_schedule=bwd,
        bwd_wire=cfg.bwd_wire,
        block_q=cfg.block_q,
        block_kv=cfg.block_kv,
        allow_concurrent_rings=cfg.allow_concurrent_rings,
        comm_overlap=cfg.comm_overlap,
    )


def _mesh_apply(q, k, v, cfg: AttentionPlanConfig, seg=None):
    if cfg.autotune and cfg.n > 1:
        # inside shard_map q is the LOCAL chunk, so the CommModel geometry
        # would be wrong by a factor of n; distributed_attention resolves
        # autotuned plans from the global view before entering shard_map
        raise ValueError(
            "autotuned mesh plans must be resolved outside shard_map "
            "(use distributed_attention, or bake schedules via plan_schedules)"
        )
    a = cfg.a if cfg.a is not None else best_square_a(cfg.n)
    return mesh_attention(q, k, v, _mesh_cfg(cfg, a=a), seg=seg)


def _ring_apply(q, k, v, cfg: AttentionPlanConfig, seg=None):
    """Ring-Attention as the (a=1, b=n) special case — one-block-per-step
    ring schedule, identical kernels and ring machinery (paper §2.2)."""
    fwd = S.ring_forward_schedule(cfg.n) if cfg.n > 1 else None
    return mesh_attention(q, k, v, _mesh_cfg(cfg, a=1, fwd=fwd), seg=seg)


def _ulysses_apply(q, k, v, cfg: AttentionPlanConfig, seg=None):
    if cfg.layout != "contiguous":
        raise ValueError("Ulysses requires the contiguous layout")
    spec = cfg.mask_spec()
    if spec.kind == "block_sparse":
        raise ValueError("Ulysses does not support block-sparse masks")
    if spec.needs_segments and seg is None:
        raise ValueError(f"mask kind {spec.kind!r} needs a segment-id operand")
    return ulysses_attention(
        q, k, v, cfg.axis_name, cfg.n,
        causal=spec.is_causal, window=spec.window, scale=cfg.scale, seg=seg,
    )


def _local_flash_apply(q, k, v, cfg: AttentionPlanConfig, seg=None):
    spec = cfg.mask_spec()
    if spec.kind == "block_sparse":
        raise ValueError("block-sparse masks route through the mesh backend")
    if spec.needs_segments and seg is None:
        raise ValueError(f"mask kind {spec.kind!r} needs a segment-id operand")
    return ops.flash_attention(
        q, k, v, causal=spec.is_causal, window=spec.window, scale=cfg.scale,
        seg_q=seg, seg_kv=seg,
    )


def _decode_step_local(
    q, k_new, v_new, k_cache, v_cache, pos, cfg: AttentionPlanConfig,
    bt=None, ks=None, vs=None,
):
    """One decode tick over the local cache slice (inside shard_map).  With
    ``cfg.paged`` the caches are the physical page pool and ``bt`` is the
    block table (owner shard -> (page, offset) instead of -> slot row);
    ``ks``/``vs`` are the quantized pool's local scale tables — present, the
    new token quantizes on write and the step returns them updated (a
    5-tuple instead of 3)."""
    if cfg.paged:
        if ks is not None:
            k_cache, v_cache, ks, vs = paged_cache_update(
                k_cache, v_cache, k_new, v_new, bt, pos, cfg.axis_name, cfg.n,
                layout=cfg.layout, k_scale=ks, v_scale=vs,
            )
        else:
            k_cache, v_cache = paged_cache_update(
                k_cache, v_cache, k_new, v_new, bt, pos, cfg.axis_name, cfg.n,
                layout=cfg.layout,
            )
        o = paged_cache_decode(
            q, k_cache, v_cache, bt, pos, cfg.axis_name, cfg.n,
            layout=cfg.layout, window=cfg.window, scale=cfg.scale,
            kernel=_resolve_decode_kernel(cfg.decode_kernel, paged=True),
            k_scale=ks, v_scale=vs,
        )
        if ks is not None:
            return o, k_cache, v_cache, ks, vs
        return o, k_cache, v_cache
    k_cache, v_cache = sharded_cache_update(
        k_cache, v_cache, k_new, v_new, pos, cfg.axis_name, cfg.n, layout=cfg.layout
    )
    o = sharded_cache_decode(
        q, k_cache, v_cache, pos, cfg.axis_name, cfg.n,
        layout=cfg.layout, window=cfg.window, scale=cfg.scale,
        kernel=_resolve_decode_kernel(cfg.decode_kernel, paged=False),
    )
    return o, k_cache, v_cache


def _decode_apply(q, k, v, cfg: AttentionPlanConfig, seg=None):
    raise ValueError(
        "the 'decode' backend is step-wise (sequence-sharded KV cache); "
        "call repro.core.dispatch.decode_attention_step instead of "
        "distributed_attention"
    )


register_backend(Backend(
    "mesh", apply=_mesh_apply,
    description="Mesh-Attention (a x b tile; autotunable via the simulator)",
))
register_backend(Backend(
    "ring", apply=_ring_apply,
    description="Ring-Attention baseline = mesh with a=1 and the ring schedule",
))
register_backend(Backend(
    "ulysses", apply=_ulysses_apply,
    description="DeepSpeed-Ulysses head-parallel (capped at the KV-head count)",
))
register_backend(Backend(
    "local-flash", apply=_local_flash_apply,
    description="single-device Pallas/reference flash attention (n == 1 fallback)",
))
register_backend(Backend(
    "decode", apply=_decode_apply, step=_decode_step_local,
    description="striped/contiguous sequence-sharded KV-cache flash-decode",
))


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------


def attention_in_shard_map(q, k, v, cfg: AttentionPlanConfig, seg=None):
    """Registry-dispatched local op for callers already inside shard_map.
    ``seg`` is the LOCAL [S/n] int32 segment-id chunk (document masks)."""
    return get_backend(resolve_backend_name(cfg)).apply(q, k, v, cfg, seg=seg)


def _require_ctx(ctx, cfg: AttentionPlanConfig):
    if ctx is None or ctx.mesh is None:
        raise ValueError(
            f"backend {cfg.backend!r} with n={cfg.n} needs a ParallelCtx "
            "carrying a mesh; pass ctx= or use n=1 / backend='local-flash'"
        )


def distributed_attention(q, k, v, *, cfg: AttentionPlanConfig, ctx=None, segments=None):
    """THE attention seam: every workload (train, prefill, benchmarks, tests)
    calls this with a declarative plan.

    q: [B, S, H, D]; k, v: [B, S, Hkv, D] — global-logical views under pjit.
    Causal striped-layout inputs must already be in stripe order (§3.7, the
    data pipeline / serve engine handle the permutation).  ``ctx`` supplies
    the mesh + batch sharding for the ``shard_map`` wrapper; it is optional
    when the plan resolves to the local backend.

    ``segments``: int32 [S] segment-id array for document/segment masks, in
    the SAME order as q/k/v (the caller stripes it with the tokens).  For a
    static ``MaskSpec.document`` mask it is synthesized (and striped) here
    when omitted.
    """
    mask_spec = cfg.mask_spec()
    if segments is None and mask_spec.kind == "document":
        seg_np = mask_spec.segment_array(int(q.shape[1]))
        if cfg.layout == "striped" and cfg.n > 1:
            seg_np = seg_np[stripe_permutation(int(q.shape[1]), cfg.n)]
        segments = jnp.asarray(seg_np)
    if segments is not None:
        segments = jnp.asarray(segments, jnp.int32)

    name = resolve_backend_name(cfg)
    if name == "local-flash" or cfg.n <= 1:
        return _local_flash_apply(q, k, v, cfg, seg=segments)

    backend = get_backend(name)
    if backend.apply is None:
        raise ValueError(f"backend {name!r} does not support the batched-attention mode")
    _require_ctx(ctx, cfg)

    if name == "mesh" and cfg.autotune:
        # plan at trace time (pure python) so the schedule is baked into the
        # hashable MeshAttentionConfig before shard_map tracing begins
        a, fwd, bwd = plan_schedules(cfg, _comm_model_for(cfg, q, k))
        macfg = _mesh_cfg(cfg, a=a, fwd=fwd, bwd=bwd)
        local = lambda q, k, v, seg=None: mesh_attention(q, k, v, macfg, seg=seg)
    else:
        local = lambda q, k, v, seg=None: backend.apply(q, k, v, cfg, seg=seg)

    spec = P(ctx.eff_batch_spec(q.shape[0]), cfg.axis_name, None, None)
    if segments is None:
        f = shard_map(
            local,
            mesh=ctx.shard_map_mesh(), in_specs=(spec, spec, spec), out_specs=spec,
            check_vma=False,
        )
        return f(q, k, v)
    f = shard_map(
        lambda q, k, v, seg: local(q, k, v, seg=seg),
        mesh=ctx.shard_map_mesh(),
        in_specs=(spec, spec, spec, P(cfg.axis_name)),
        out_specs=spec,
        check_vma=False,
    )
    return f(q, k, v, segments)


def decode_attention_step(
    q,  # [B, 1, H, D]
    k_new,  # [B, 1, Hkv, D]
    v_new,
    k_cache,  # [B, cap(/n), Hkv, D]; sharded over the sequence axis — or,
    v_cache,  # paged: the pool [num_pages, n*page_size, Hkv, D]
    pos,  # int32 scalar, or [B] vector of per-slot positions
    ctx,
    *,
    window: Optional[int] = None,
    layout: str = "striped",
    scale: Optional[float] = None,
    block_table=None,  # int32 [B, max_pages]: switches to the paged cache
    decode_kernel: Optional[str] = None,  # None -> ctx.decode_kernel
    k_scale=None,  # [L?, num_pages, n*page_size, Hkv] f32: quantized pool
    v_scale=None,
):
    """One token of cache-based decode through the 'decode' backend.

    Returns (o, new_k_cache, new_v_cache).  n == 1 runs the dense local
    update + flash-decode; otherwise the sequence-sharded cache path.
    Vector ``pos`` serves mixed-depth slots in one step (continuous batching).

    ``k_scale``/``v_scale`` (paged only) mark a QUANTIZED pool: pages hold
    int8/fp8 elements, the fp32 scale tables share the pool's sharding and
    page indexing, writes quantize, reads dequantize (in-kernel on the
    native path), and the step returns ``(o, k_cache, v_cache, k_scale,
    v_scale)``.

    ``block_table`` selects the PAGED cache: k/v are the physical page pool
    (middle axis sharded over the sequence axis exactly like the dense cap
    axis) and each row's pages are resolved through the table.  The pool has
    no batch axis, so the paged step runs batch-REPLICATED over any data
    axes — every device applies the identical pool update (slots are few;
    pages, not rows, carry the memory).

    ``decode_kernel`` (default from ``ctx``) picks the band/gather oracle or
    the split-K native kernel; "auto" resolves paged -> native, dense -> band.
    """
    n = ctx.sp_size
    pos = jnp.asarray(pos, jnp.int32)
    hi = (window - 1) if window else BAND_INF
    if decode_kernel is None:
        decode_kernel = getattr(ctx, "decode_kernel", "auto")
    if k_scale is not None and block_table is None:
        raise ValueError("k_scale/v_scale (quantized pool) require block_table")
    if block_table is not None:
        return _decode_attention_step_paged(
            q, k_new, v_new, k_cache, v_cache, pos, block_table, ctx,
            window=window, layout=layout, scale=scale, decode_kernel=decode_kernel,
            k_scale=k_scale, v_scale=v_scale,
        )
    dense_kernel = _resolve_decode_kernel(decode_kernel, paged=False)
    if n == 1:
        if dense_kernel == "native":
            # one shared update + split-K decode call covers scalar AND
            # vector pos (the kernel's grid is per-row, no vmap needed)
            k_cache, v_cache = sharded_cache_update(
                k_cache, v_cache, k_new, v_new, pos, None, 1, layout=layout
            )
            o = sharded_cache_decode(
                q, k_cache, v_cache, pos, None, 1,
                layout=layout, window=window, scale=scale, kernel="native",
            )
            return o.astype(q.dtype), k_cache, v_cache
        if pos.ndim == 0:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k_new.astype(k_cache.dtype), pos, axis=1
            )
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v_new.astype(v_cache.dtype), pos, axis=1
            )
            band = jnp.stack([pos, jnp.int32(0), jnp.int32(0), jnp.int32(hi)])
            o, _ = ops.block_attention(q, k_cache, v_cache, band, scale=scale)
            return o.astype(q.dtype), k_cache, v_cache
        # per-slot positions: row-wise scatter, then a row-wise band
        cap = k_cache.shape[1]
        write = pos < cap
        slot = jnp.clip(pos, 0, cap - 1)
        b = jnp.arange(k_cache.shape[0])
        caches = []
        for cache, new in ((k_cache, k_new), (v_cache, v_new)):
            cur = cache[b, slot]
            val = jnp.where(write[:, None, None], new[:, 0].astype(cache.dtype), cur)
            caches.append(cache.at[b, slot].set(val))
        k_cache, v_cache = caches

        def one(qb, kb, vb, pb):
            band = jnp.stack([pb, jnp.int32(0), jnp.int32(0), jnp.int32(hi)])
            ob, _ = ops.block_attention(qb[None], kb[None], vb[None], band, scale=scale)
            return ob[0]

        o = jax.vmap(one)(q, k_cache, v_cache, pos)
        return o.astype(q.dtype), k_cache, v_cache

    cfg = AttentionPlanConfig(
        backend="decode", axis_name=ctx.sp_axis, n=n,
        window=window, layout=layout, scale=scale, decode_kernel=decode_kernel,
    )
    step = get_backend("decode").step

    bs = ctx.eff_batch_spec(q.shape[0])
    rep = P(bs, None, None, None)
    cache_spec = P(bs, ctx.sp_axis, None, None)
    pos_spec = P(bs) if pos.ndim else P()

    f = shard_map(
        lambda q, kn, vn, kc, vc, pos: step(q, kn, vn, kc, vc, pos, cfg),
        mesh=ctx.shard_map_mesh(),
        in_specs=(rep, rep, rep, cache_spec, cache_spec, pos_spec),
        out_specs=(rep, cache_spec, cache_spec),
        check_vma=False,
    )
    return f(q, k_new, v_new, k_cache, v_cache, pos)


def _decode_attention_step_paged(
    q, k_new, v_new, k_pool, v_pool, pos, block_table, ctx,
    *, window, layout, scale, decode_kernel="auto", k_scale=None, v_scale=None,
):
    """Paged decode step: the pool's page axis is unsharded, its position
    axis is sharded over the sequence axis; everything else is replicated
    (see ``decode_attention_step``).  Quantized pools thread their scale
    tables with the pool's sharding (the scale's position axis is the pool's
    position axis) and get them back updated."""
    n = ctx.sp_size
    bt = jnp.asarray(block_table, jnp.int32)
    kernel = _resolve_decode_kernel(decode_kernel, paged=True)
    quantized = k_scale is not None
    if n == 1:
        if quantized:
            k_pool, v_pool, k_scale, v_scale = paged_cache_update(
                k_pool, v_pool, k_new, v_new, bt, pos, None, 1, layout=layout,
                k_scale=k_scale, v_scale=v_scale,
            )
        else:
            k_pool, v_pool = paged_cache_update(
                k_pool, v_pool, k_new, v_new, bt, pos, None, 1, layout=layout
            )
        o = paged_cache_decode(
            q, k_pool, v_pool, bt, pos, None, 1,
            layout=layout, window=window, scale=scale, kernel=kernel,
            k_scale=k_scale, v_scale=v_scale,
        )
        if quantized:
            return o, k_pool, v_pool, k_scale, v_scale
        return o, k_pool, v_pool

    cfg = AttentionPlanConfig(
        backend="decode", axis_name=ctx.sp_axis, n=n,
        window=window, layout=layout, scale=scale, paged=True,
        decode_kernel=kernel,
        kv_dtype=("int8" if k_pool.dtype == jnp.int8 else "fp8") if quantized else "fp",
    )
    step = get_backend("decode").step
    rep = P(None, None, None, None)
    pool_spec = P(None, ctx.sp_axis, None, None)
    pos_spec = P(None) if pos.ndim else P()
    if quantized:
        scale_spec = P(None, ctx.sp_axis, None)
        f = shard_map(
            lambda q, kn, vn, kp, vp, pos, bt, ks, vs: step(
                q, kn, vn, kp, vp, pos, cfg, bt=bt, ks=ks, vs=vs
            ),
            mesh=ctx.shard_map_mesh(),
            in_specs=(rep, rep, rep, pool_spec, pool_spec, pos_spec,
                      P(None, None), scale_spec, scale_spec),
            out_specs=(rep, pool_spec, pool_spec, scale_spec, scale_spec),
            check_vma=False,
        )
        return f(q, k_new, v_new, k_pool, v_pool, pos, bt, k_scale, v_scale)
    f = shard_map(
        lambda q, kn, vn, kp, vp, pos, bt: step(q, kn, vn, kp, vp, pos, cfg, bt=bt),
        mesh=ctx.shard_map_mesh(),
        in_specs=(rep, rep, rep, pool_spec, pool_spec, pos_spec, P(None, None)),
        out_specs=(rep, pool_spec, pool_spec),
        check_vma=False,
    )
    return f(q, k_new, v_new, k_pool, v_pool, pos, bt)


def _chunk_step_local(
    q, k_new, v_new, k_cache, v_cache, starts, lens, wstarts,
    cfg: AttentionPlanConfig, bt=None, ks=None, vs=None,
):
    """One prefill chunk over the local cache slice (inside shard_map):
    scatter the chunk's KV by absolute position, then prefix-causal chunk
    attention over everything resident.  ``ks``/``vs`` carry a quantized
    pool's scale tables (chunked prefill and speculative verify write
    quantized exactly like decode); present, the step returns a 5-tuple."""
    if cfg.paged:
        if ks is not None:
            k_cache, v_cache, ks, vs = paged_cache_chunk_update(
                k_cache, v_cache, k_new, v_new, bt, starts, lens, wstarts,
                cfg.axis_name, cfg.n, layout=cfg.layout, k_scale=ks, v_scale=vs,
            )
        else:
            k_cache, v_cache = paged_cache_chunk_update(
                k_cache, v_cache, k_new, v_new, bt, starts, lens, wstarts,
                cfg.axis_name, cfg.n, layout=cfg.layout,
            )
        o = paged_cache_chunk_decode(
            q, k_cache, v_cache, bt, starts, cfg.axis_name, cfg.n,
            layout=cfg.layout, window=cfg.window, scale=cfg.scale,
            k_scale=ks, v_scale=vs,
        )
        if ks is not None:
            return o, k_cache, v_cache, ks, vs
        return o, k_cache, v_cache
    k_cache, v_cache = sharded_cache_chunk_update(
        k_cache, v_cache, k_new, v_new, starts, lens, wstarts,
        cfg.axis_name, cfg.n, layout=cfg.layout,
    )
    o = sharded_cache_chunk_decode(
        q, k_cache, v_cache, starts, cfg.axis_name, cfg.n,
        layout=cfg.layout, window=cfg.window, scale=cfg.scale,
    )
    return o, k_cache, v_cache


def chunk_attention_step(
    q,  # [B, C, H, D] chunk queries (pad rows beyond lens compute garbage)
    k_new,  # [B, C, Hkv, D]
    v_new,
    k_cache,  # [B, cap(/n), Hkv, D] — or, paged: the pool
    v_cache,
    starts,  # int32 [B]: global position of each row's chunk base
    lens,  # int32 [B]: valid tokens per row (0 = inactive row, nothing written)
    write_starts,  # int32 [B]: skip KV writes below this position (shared prefix)
    ctx,
    *,
    window: Optional[int] = None,
    layout: str = "striped",
    scale: Optional[float] = None,
    block_table=None,  # int32 [B, max_pages]: switches to the paged cache
    k_scale=None,  # f32 scale tables: quantized pool (paged only)
    v_scale=None,
):
    """One continuous-prefill chunk: C tokens of row b land at global
    positions ``starts[b] .. starts[b]+lens[b]-1`` and attend prefix-causally
    to every resident position (row i sees <= starts[b]+i, within the
    window).  Returns (o, new_k_cache, new_v_cache) exactly like
    ``decode_attention_step`` — it is the same banded partial + lse psum with
    a multi-row q, so chunked prefill reproduces one-shot prefill bit-for-bit
    on the reference backend.  Chunks always run the band/gather path; the
    split-K native kernel stays single-token.  ``k_scale``/``v_scale``
    (paged) quantize the chunk on write and extend the return to a 5-tuple,
    exactly like ``decode_attention_step``."""
    n = ctx.sp_size
    starts = jnp.asarray(starts, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    write_starts = jnp.asarray(write_starts, jnp.int32)
    if k_scale is not None and block_table is None:
        raise ValueError("k_scale/v_scale (quantized pool) require block_table")
    if block_table is not None:
        bt = jnp.asarray(block_table, jnp.int32)
        quantized = k_scale is not None
        if n == 1:
            if quantized:
                k_cache, v_cache, k_scale, v_scale = paged_cache_chunk_update(
                    k_cache, v_cache, k_new, v_new, bt, starts, lens,
                    write_starts, None, 1, layout=layout,
                    k_scale=k_scale, v_scale=v_scale,
                )
            else:
                k_cache, v_cache = paged_cache_chunk_update(
                    k_cache, v_cache, k_new, v_new, bt, starts, lens,
                    write_starts, None, 1, layout=layout,
                )
            o = paged_cache_chunk_decode(
                q, k_cache, v_cache, bt, starts, None, 1,
                layout=layout, window=window, scale=scale,
                k_scale=k_scale, v_scale=v_scale,
            )
            if quantized:
                return o, k_cache, v_cache, k_scale, v_scale
            return o, k_cache, v_cache
        cfg = AttentionPlanConfig(
            backend="decode", axis_name=ctx.sp_axis, n=n,
            window=window, layout=layout, scale=scale, paged=True,
            kv_dtype=("int8" if k_cache.dtype == jnp.int8 else "fp8")
            if quantized else "fp",
        )
        rep = P(None, None, None, None)
        pool_spec = P(None, ctx.sp_axis, None, None)
        if quantized:
            scale_spec = P(None, ctx.sp_axis, None)
            f = shard_map(
                lambda q, kn, vn, kp, vp, st, ln, ws, bt, ks, vs: _chunk_step_local(
                    q, kn, vn, kp, vp, st, ln, ws, cfg, bt=bt, ks=ks, vs=vs
                ),
                mesh=ctx.shard_map_mesh(),
                in_specs=(rep, rep, rep, pool_spec, pool_spec,
                          P(None), P(None), P(None), P(None, None),
                          scale_spec, scale_spec),
                out_specs=(rep, pool_spec, pool_spec, scale_spec, scale_spec),
                check_vma=False,
            )
            return f(q, k_new, v_new, k_cache, v_cache, starts, lens,
                     write_starts, bt, k_scale, v_scale)
        f = shard_map(
            lambda q, kn, vn, kp, vp, st, ln, ws, bt: _chunk_step_local(
                q, kn, vn, kp, vp, st, ln, ws, cfg, bt=bt
            ),
            mesh=ctx.shard_map_mesh(),
            in_specs=(rep, rep, rep, pool_spec, pool_spec,
                      P(None), P(None), P(None), P(None, None)),
            out_specs=(rep, pool_spec, pool_spec),
            check_vma=False,
        )
        return f(q, k_new, v_new, k_cache, v_cache, starts, lens, write_starts, bt)
    if n == 1:
        k_cache, v_cache = sharded_cache_chunk_update(
            k_cache, v_cache, k_new, v_new, starts, lens, write_starts,
            None, 1, layout=layout,
        )
        o = sharded_cache_chunk_decode(
            q, k_cache, v_cache, starts, None, 1,
            layout=layout, window=window, scale=scale,
        )
        return o, k_cache, v_cache
    cfg = AttentionPlanConfig(
        backend="decode", axis_name=ctx.sp_axis, n=n,
        window=window, layout=layout, scale=scale,
    )
    bs = ctx.eff_batch_spec(q.shape[0])
    rep = P(bs, None, None, None)
    cache_spec = P(bs, ctx.sp_axis, None, None)
    vec = P(bs)
    f = shard_map(
        lambda q, kn, vn, kc, vc, st, ln, ws: _chunk_step_local(
            q, kn, vn, kc, vc, st, ln, ws, cfg
        ),
        mesh=ctx.shard_map_mesh(),
        in_specs=(rep, rep, rep, cache_spec, cache_spec, vec, vec, vec),
        out_specs=(rep, cache_spec, cache_spec),
        check_vma=False,
    )
    return f(q, k_new, v_new, k_cache, v_cache, starts, lens, write_starts)


def latent_wire_attention(
    q, wire, wire_params, kv_transform, *, cfg: AttentionPlanConfig, ctx, segments=None
):
    """Mesh-Attention with a compressed KV wire (beyond-paper §Perf): the
    opaque ``wire`` chunk circulates on the KV ring and ``kv_transform(chunk,
    wire_params) -> (k, v)`` expands it per-head at first use (e.g. MLA's
    latent).  Forward-only; ``wire_params`` stays replicated."""
    _require_ctx(ctx, cfg)
    a = cfg.a if cfg.a is not None else best_square_a(cfg.n)
    macfg = _mesh_cfg(cfg, a=a)

    spec = P(ctx.eff_batch_spec(q.shape[0]), cfg.axis_name, None, None)
    if segments is None:
        def inner(q, wire, wp):
            return mesh_attention_wire(q, wire, macfg, lambda chunk: kv_transform(chunk, wp))

        f = shard_map(
            inner,
            mesh=ctx.shard_map_mesh(), in_specs=(spec, spec, P()), out_specs=spec,
            check_vma=False,
        )
        return f(q, wire, wire_params)

    def inner_seg(q, wire, wp, seg):
        return mesh_attention_wire(
            q, wire, macfg, lambda chunk: kv_transform(chunk, wp), seg=seg
        )

    f = shard_map(
        inner_seg,
        mesh=ctx.shard_map_mesh(),
        in_specs=(spec, spec, P(), P(cfg.axis_name)),
        out_specs=spec,
        check_vma=False,
    )
    return f(q, wire, wire_params, jnp.asarray(segments, jnp.int32))
