"""Event-driven overlap simulator (paper Fig. 6 "estimate runtime" stage).

Because every device in Mesh-Attention executes the identical lock-step
schedule (paper §3.2: the wrap-around mesh is fully symmetric), simulating a
single device's timeline yields the system's timeline.  The step cost is
``comm_overlap``-aware (the executor's knob, ``schedule.COMM_OVERLAP_MODES``):

  serial    step = comm + compute (every byte on the critical path, the
            ppermute-then-compute baseline), exposed = comm;
  overlap   step = max(payload, compute) + launch residual — communication
            issued at step start runs concurrently with the step's compute
            blocks (NCCL-stream / XLA async-collective overlap), only the
            per-step launch cost α can never hide;
  bidir     as overlap, with each hop split across both ring directions, so
            the payload moves at per-direction link bandwidth (half the
            transfer time for the same bytes; ``make_cost_model`` bakes the
            halving into ``t_chunk``).

Ops on different rings within one step always run concurrently
(per-ICI-dimension links).

The simulator powers:
  * the (a, b) autotuner (`core/autotune.py`),
  * the paper-table benchmarks (Tables 3/4, Figs. 8/9) — calibrated with the
    α-β model in `HardwareModel` since this container has no TPU to measure.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import schedule as S
from repro.core.am import CommModel

__all__ = ["HardwareModel", "CostModel", "SimResult", "simulate", "make_cost_model"]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e-class constants (per chip) — the same numbers used for the
    roofline terms in EXPERIMENTS.md."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # B/s
    link_bw: float = 50e9  # B/s per ICI link
    attn_efficiency: float = 0.5  # achievable fraction of peak on flash blocks
    latency: float = 1e-6  # per-message fixed cost (α in α-β)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Seconds per compute block and per chunk transfer."""

    t_block: float
    t_chunk: Dict[str, float]  # comm-op kind -> seconds (launch cost included)
    block_flops: float
    t_launch: float = 0.0  # per-step comm issue cost (α) — never hidden

    def profile(self) -> S.Profile:
        """Convert to the scheduler's c_* constants (blocks per transfer)."""
        g = lambda k: self.t_chunk.get(k, 0.0) / self.t_block
        return S.Profile(
            c_q=g(S.RECV_Q),
            c_kv=g(S.RECV_KV),
            c_o=g(S.SEND_O),
            c_odoq=g(S.RECV_ODOQ),
            c_dq=g(S.SEND_DQ),
            c_dkv=g(S.SEND_DKV),
        )


@dataclasses.dataclass(frozen=True)
class SimResult:
    total: float  # seconds for the whole attention call
    compute: float  # pure compute time (sum of block times)
    comm: float  # pure serialized communication time
    exposed_comm: float  # communication NOT hidden by compute
    steps: int
    comm_bytes: int  # per-device bytes on the wire

    @property
    def overlap_efficiency(self) -> float:
        return self.compute / self.total if self.total else 1.0


def make_cost_model(
    comm: CommModel,
    hw: HardwareModel = HardwareModel(),
    *,
    causal: bool = False,
    backward: bool = False,
    mask=None,  # Optional[MaskSpec]: supersedes the causal flag
    comm_overlap: str = "overlap",
) -> CostModel:
    """α-β cost model for one (N, d, n) attention call.

    One compute block = flash attention between a Q chunk (m tokens) and a KV
    chunk (m tokens), m = batch·N/n: 4·m²·d FLOPs forward (QKᵀ and PV), 2.5×
    that backward (the five flash-backward matmuls), scaled by the mask's
    visible fraction (0.5 for plain causal; striping balances the saving
    across all blocks — paper §3.7; the Pallas kernels skip fully-masked
    sub-blocks with ``pl.when``, recovering it block-wise).

    ``comm_overlap="bidir"`` halves the per-hop transfer time: the executor
    ships each chunk as a half-payload ppermute pair over both ring
    directions, so each half moves at full per-direction link bandwidth
    concurrently (the pair shares one launch).  Total bytes are unchanged.
    """
    S.validate_comm_overlap(comm_overlap)
    m = comm.batch * comm.seq / comm.n
    flops = 4.0 * m * m * comm.hidden
    if backward:
        flops *= 2.5
    if mask is not None:
        flops *= mask.visible_fraction(comm.seq)
    elif causal:
        flops *= 0.5
    t_block = flops / (hw.peak_flops * hw.attn_efficiency)
    eff_bw = hw.link_bw * (2.0 if comm_overlap == "bidir" else 1.0)
    t = lambda kind: hw.latency + comm.chunk_bytes(kind) / eff_bw
    t_chunk = {
        S.RECV_Q: t("q"),
        S.RECV_KV: t("kv"),
        S.SEND_O: t("o"),
        S.RECV_ODOQ: t("odoq"),
        S.SEND_DQ: t("dq"),
        S.SEND_DKV: t("dkv"),
    }
    return CostModel(
        t_block=t_block, t_chunk=t_chunk, block_flops=flops, t_launch=hw.latency
    )


_KIND_TO_CHUNK = {
    S.RECV_Q: "q",
    S.RECV_KV: "kv",
    S.SEND_O: "o",
    S.RECV_ODOQ: "odoq",
    S.SEND_DQ: "dq",
    S.SEND_DKV: "dkv",
}


def simulate(
    sched: S.Schedule,
    cost: CostModel,
    comm: Optional[CommModel] = None,
    comm_overlap: str = "overlap",
) -> SimResult:
    """Walk the lock-step schedule with the mode-dependent step cost.

    ``serial``: step = comm + compute, every transfer fully exposed.
    ``overlap``/``bidir``: step = max(payload, compute) + launch residual;
    exposed = the payload time compute could not cover, plus the residual.
    (``bidir`` also needs a ``make_cost_model(comm_overlap="bidir")`` cost so
    ``t_chunk`` reflects per-direction bandwidth.)
    """
    S.validate_comm_overlap(comm_overlap)
    total = 0.0
    compute_time = 0.0
    comm_time = 0.0
    exposed = 0.0
    comm_bytes = 0
    for step in sched.steps:
        t_comm = max((cost.t_chunk[c] for c in step.comms), default=0.0)
        t_comp = len(step.compute) * cost.t_block
        if comm_overlap == "serial":
            total += t_comm + t_comp
            exposed += t_comm
        else:
            resid = min(cost.t_launch, t_comm) if step.comms else 0.0
            payload = t_comm - resid
            total += max(payload, t_comp) + resid
            exposed += max(0.0, payload - t_comp) + resid
        compute_time += t_comp
        comm_time += sum(cost.t_chunk[c] for c in step.comms)
        if comm is not None:
            comm_bytes += sum(comm.chunk_bytes(_KIND_TO_CHUNK[c]) for c in step.comms)
    return SimResult(
        total=total,
        compute=compute_time,
        comm=comm_time,
        exposed_comm=exposed,
        steps=len(sched.steps),
        comm_bytes=comm_bytes,
    )
