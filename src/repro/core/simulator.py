"""Event-driven overlap simulator (paper Fig. 6 "estimate runtime" stage).

Because every device in Mesh-Attention executes the identical lock-step
schedule (paper §3.2: the wrap-around mesh is fully symmetric), simulating a
single device's timeline yields the system's timeline.  A step's duration is
``max(comm, compute)`` — communication issued at step start runs concurrently
with the step's compute blocks (this models NCCL-stream / XLA
async-collective overlap); ops on different rings within one step also run
concurrently (per-ICI-dimension links).

The simulator powers:
  * the (a, b) autotuner (`core/autotune.py`),
  * the paper-table benchmarks (Tables 3/4, Figs. 8/9) — calibrated with the
    α-β model in `HardwareModel` since this container has no TPU to measure.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import schedule as S
from repro.core.am import CommModel

__all__ = ["HardwareModel", "CostModel", "SimResult", "simulate", "make_cost_model"]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e-class constants (per chip) — the same numbers used for the
    roofline terms in EXPERIMENTS.md."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # B/s
    link_bw: float = 50e9  # B/s per ICI link
    attn_efficiency: float = 0.5  # achievable fraction of peak on flash blocks
    latency: float = 1e-6  # per-message fixed cost (α in α-β)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Seconds per compute block and per chunk transfer."""

    t_block: float
    t_chunk: Dict[str, float]  # comm-op kind -> seconds
    block_flops: float

    def profile(self) -> S.Profile:
        """Convert to the scheduler's c_* constants (blocks per transfer)."""
        g = lambda k: self.t_chunk.get(k, 0.0) / self.t_block
        return S.Profile(
            c_q=g(S.RECV_Q),
            c_kv=g(S.RECV_KV),
            c_o=g(S.SEND_O),
            c_odoq=g(S.RECV_ODOQ),
            c_dq=g(S.SEND_DQ),
            c_dkv=g(S.SEND_DKV),
        )


@dataclasses.dataclass(frozen=True)
class SimResult:
    total: float  # seconds for the whole attention call
    compute: float  # pure compute time (sum of block times)
    comm: float  # pure serialized communication time
    exposed_comm: float  # communication NOT hidden by compute
    steps: int
    comm_bytes: int  # per-device bytes on the wire

    @property
    def overlap_efficiency(self) -> float:
        return self.compute / self.total if self.total else 1.0


def make_cost_model(
    comm: CommModel,
    hw: HardwareModel = HardwareModel(),
    *,
    causal: bool = False,
    backward: bool = False,
    mask=None,  # Optional[MaskSpec]: supersedes the causal flag
) -> CostModel:
    """α-β cost model for one (N, d, n) attention call.

    One compute block = flash attention between a Q chunk (m tokens) and a KV
    chunk (m tokens), m = batch·N/n: 4·m²·d FLOPs forward (QKᵀ and PV), 2.5×
    that backward (the five flash-backward matmuls), scaled by the mask's
    visible fraction (0.5 for plain causal; striping balances the saving
    across all blocks — paper §3.7; the Pallas kernels skip fully-masked
    sub-blocks with ``pl.when``, recovering it block-wise).
    """
    m = comm.batch * comm.seq / comm.n
    flops = 4.0 * m * m * comm.hidden
    if backward:
        flops *= 2.5
    if mask is not None:
        flops *= mask.visible_fraction(comm.seq)
    elif causal:
        flops *= 0.5
    t_block = flops / (hw.peak_flops * hw.attn_efficiency)
    t = lambda kind: hw.latency + comm.chunk_bytes(kind) / hw.link_bw
    t_chunk = {
        S.RECV_Q: t("q"),
        S.RECV_KV: t("kv"),
        S.SEND_O: t("o"),
        S.RECV_ODOQ: t("odoq"),
        S.SEND_DQ: t("dq"),
        S.SEND_DKV: t("dkv"),
    }
    return CostModel(t_block=t_block, t_chunk=t_chunk, block_flops=flops)


_KIND_TO_CHUNK = {
    S.RECV_Q: "q",
    S.RECV_KV: "kv",
    S.SEND_O: "o",
    S.RECV_ODOQ: "odoq",
    S.SEND_DQ: "dq",
    S.SEND_DKV: "dkv",
}


def simulate(sched: S.Schedule, cost: CostModel, comm: Optional[CommModel] = None) -> SimResult:
    """Walk the lock-step schedule: step time = max(slowest ring op, compute)."""
    total = 0.0
    compute_time = 0.0
    comm_time = 0.0
    exposed = 0.0
    comm_bytes = 0
    for step in sched.steps:
        t_comm = max((cost.t_chunk[c] for c in step.comms), default=0.0)
        t_comp = len(step.compute) * cost.t_block
        total += max(t_comm, t_comp)
        compute_time += t_comp
        comm_time += sum(cost.t_chunk[c] for c in step.comms)
        exposed += max(0.0, t_comm - t_comp)
        if comm is not None:
            comm_bytes += sum(comm.chunk_bytes(_KIND_TO_CHUNK[c]) for c in step.comms)
    return SimResult(
        total=total,
        compute=compute_time,
        comm=comm_time,
        exposed_comm=exposed,
        steps=len(sched.steps),
        comm_bytes=comm_bytes,
    )
