"""Mesh-Attention core: the paper's contribution.

Layers:
  tiling        — assignment-matrix tiling, groups, Table-1 chunk maps, striping
  masking       — first-class MaskSpec: kernel band/segment operands, schedule
                  block visibility (FULL/PARTIAL/EMPTY), mask-aware cost terms
  am            — communication-volume analytics (paper Table 2)
  schedule      — greedy intra-tile schedules (Algorithms 2/3)
  simulator     — lock-step overlap simulator (Figure-6 runtime estimation)
  autotune      — tile-shape search (Figure 6)
  mesh_attention— the distributed op (shard_map + ppermute sub-rings)
  ring_attention, ulysses — baselines
  decode_attention — distributed flash-decode over a striped KV cache
  dispatch      — THE seam: backend registry + declarative AttentionPlanConfig
                  + simulator-planned tiles with an on-disk plan cache; the
                  only module the rest of the tree calls attention through
"""

from repro.core.am import CommModel, mesh_volume, ring_volume, table2, ulysses_volume
from repro.core.autotune import TilePlan, plan_for, tune
from repro.core.masking import EMPTY, FULL, PARTIAL, MaskSpec
from repro.core.schedule import (
    Profile,
    Schedule,
    greedy_backward_schedule,
    greedy_forward_schedule,
    naive_forward_schedule,
    ring_forward_schedule,
    schedule_from_json,
    schedule_to_json,
    validate_schedule,
)
from repro.core.simulator import CostModel, HardwareModel, SimResult, make_cost_model, simulate
from repro.core.tiling import (
    TileLayout,
    best_square_a,
    factorizations,
    stripe_permutation,
    striped_causal_offset,
    unstripe_permutation,
)
