"""Mesh-Attention: the distributed attention op (paper §3).

Runs INSIDE ``shard_map``: every array argument is the device-local chunk
(sequence sharded n ways over ``cfg.axis_name``; causal inputs must be in the
*striped* layout of ``core.tiling.stripe_permutation``).  The op executes the
greedy step program from ``core/schedule.py`` verbatim:

  * ``Recv Q`` / ``Recv KV``  -> one ``jax.lax.ppermute`` per step on the
    Q-ring / KV-ring neighbour shifts (``TileLayout.q_shift_perm`` /
    ``kv_shift_perm``).  Chunk u arrives after u hops (Table 1).
  * compute block (u, v)      -> one Pallas flash block between Q slot u and
    KV slot v, accumulated into the row's (o, lse) with the online-softmax
    combine.  Striped-causal masking uses the *global* chunk indices, which
    depend on ``axis_index`` — they enter the kernel as dynamic SMEM scalars.
  * ``Send O``  (step t)      -> ppermute the completed row t+1 partial to
    the lower Q-ring neighbour; fold the received row (t+2 mod a) partial in
    (online softmax as the reduce operator, Alg. 1 line 4).

Backward (Alg. 3) is a custom_vjp at this level — the paper's communication
pattern (circulate OdOQ + KV, reduce dQ along the Q ring and dKV along the
KV ring with plain sums) — so JAX never auto-differentiates the ring code.

``a = 1`` degenerates to Ring-Attention (no Q ring, no O sends): the baseline
is literally a config choice, as in the paper ("covers Ring-Attention as a
special case").

Both executors run each step as an issue/compute/commit pipeline governed by
``cfg.comm_overlap`` (see ``schedule.COMM_OVERLAP_MODES``): the step's ring
permutes are emitted ahead of its flash blocks and only land in their slots
at step end, so in ``overlap`` mode (default) the transfer is in flight while
the blocks run; ``serial`` barriers the blocks on the transfers (the naive
baseline the cost model prices as comm+compute); ``bidir`` splits every hop
into a half-payload ppermute pair over both ring directions (TokenRing,
PAPERS.md).  All three modes are BITWISE-equal — only transport routing and
HLO ordering differ (dist_check ``overlap_exact``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import schedule as S
from repro.core.masking import MaskSpec
from repro.core.tiling import TileLayout
from repro.kernels import ops
from repro.kernels.ref import BAND_INF, NEG_INF

__all__ = ["MeshAttentionConfig", "mesh_attention", "mesh_attention_with_lse"]


@dataclasses.dataclass(frozen=True)
class MeshAttentionConfig:
    """Static configuration (hashable: it is a nondiff custom_vjp argument).

    The mask is a first-class :class:`MaskSpec`; the legacy ``causal`` /
    ``window`` booleans remain as a back-compat construction shim and are
    normalized through :meth:`mask_spec`.
    """

    axis_name: str
    n: int  # devices on the sequence-parallel axis
    a: int  # tile height; b = n // a; a=1 == Ring-Attention
    causal: bool = False
    window: Optional[int] = None  # sliding-window width (causal only)
    layout: str = "striped"  # striped (paper §3.7) | contiguous (SSM/hybrid)
    scale: Optional[float] = None
    fwd_schedule: Optional[S.Schedule] = None
    bwd_schedule: Optional[S.Schedule] = None
    bwd_wire: str = "qdod"  # "odoq" = paper wire (circulates O); "qdod" = Δ-trick
    block_q: int = 128
    block_kv: int = 128
    allow_concurrent_rings: bool = False
    mask: Optional[MaskSpec] = None  # takes precedence over causal/window
    # how each step's ring permutes are ordered against its compute blocks
    # (schedule.COMM_OVERLAP_MODES): serial barriers them onto the critical
    # path, overlap leaves them in flight during the blocks (double-buffered
    # slots), bidir additionally splits each hop into a half-payload pair on
    # both ring directions.  All three are bitwise-equal.
    comm_overlap: str = "overlap"

    def __post_init__(self):
        S.validate_comm_overlap(self.comm_overlap)
        if self.n % self.a:
            raise ValueError(f"a={self.a} must divide n={self.n}")
        if self.mask is not None and (self.causal or self.window is not None):
            raise ValueError("pass either mask= or the legacy causal/window flags, not both")
        if self.window is not None and not self.causal:
            raise ValueError("sliding window requires causal=True")
        if self.bwd_wire not in ("odoq", "qdod"):
            raise ValueError(self.bwd_wire)
        if self.layout not in ("striped", "contiguous"):
            raise ValueError(self.layout)

    @property
    def b(self) -> int:
        return self.n // self.a

    def mask_spec(self) -> MaskSpec:
        if self.mask is not None:
            return self.mask
        return MaskSpec.from_flags(self.causal, self.window)

    def schedules(self, seq: Optional[int] = None) -> Tuple[S.Schedule, S.Schedule]:
        """(fwd, bwd) schedules, mask-pruned when the mask proves slot blocks
        empty on every device.  ``seq`` is the GLOBAL sequence length (needed
        to classify window/document blocks; None skips pruning)."""
        skip: frozenset = frozenset()
        if seq is not None:
            skip = self.mask_spec().empty_blocks(
                self.a, self.b, layout=self.layout, n=self.n, seq=seq
            )
        fwd = self.fwd_schedule or S.greedy_forward_schedule(
            self.a, self.b, allow_concurrent_rings=self.allow_concurrent_rings,
            skip_blocks=skip,
        )
        bwd = self.bwd_schedule or S.greedy_backward_schedule(
            self.a, self.b, allow_concurrent_rings=self.allow_concurrent_rings,
            skip_blocks=skip,
        )
        if (fwd.a, fwd.b) != (self.a, self.b) or (bwd.a, bwd.b) != (self.a, self.b):
            raise ValueError("schedule shape mismatch with (a, b)")
        for sched in (fwd, bwd):
            # a provided schedule may skip fewer blocks (e.g. an unpruned
            # baseline) but never blocks the mask cannot prove empty
            extra = set(sched.skip) - set(skip) if seq is not None else None
            if extra:
                raise ValueError(f"schedule skips non-empty blocks: {sorted(extra)}")
        S.validate_schedule(fwd)
        S.validate_schedule(bwd)
        return fwd, bwd


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _band_for_block(cfg: MeshAttentionConfig, i, u: int, v: int, m_q: int, m_kv: int):
    """Dynamic (axis_index-dependent) band + strides for AM block (u, v).

    striped layout: token t of global chunk c has position c + n*t  (stride n)
    contiguous layout: position c*m + t                              (stride 1)

    The band carries the positional part of the mask (causal / window /
    block-sparse bitmap); segment-id masking composes inside the kernel via
    the seg operands the rings circulate alongside Q and KV.
    """
    spec = cfg.mask_spec()
    if spec.kind == "full":
        band = jnp.asarray([0, 0, -BAND_INF, BAND_INF], jnp.int32)
        return band, 1, 1
    qc = cfg.a * (i // cfg.a) + (i + u) % cfg.a  # global Q chunk (Table 1)
    kc = (i + cfg.a * v) % cfg.n  # global KV chunk (Table 1)
    if spec.kind == "block_sparse":
        # chunk-level bitmap: a visible block is unmasked, an invisible one
        # (kept lock-step because some OTHER device needs it) gets an
        # impossible band (lo > hi) so its partial is exactly empty
        vis = jnp.asarray(spec.bitmap, bool)[qc, kc]
        full = jnp.asarray([0, 0, -BAND_INF, BAND_INF], jnp.int32)
        none = jnp.asarray([0, 0, 1, 0], jnp.int32)
        return jnp.where(vis, full, none), 1, 1
    lo, hi = spec.band()  # causal kinds: 0 <= q_pos - kv_pos (<= window-1)
    if cfg.layout == "striped":
        q_off, kv_off, sq, skv = qc, kc, cfg.n, cfg.n
    else:
        q_off, kv_off, sq, skv = qc * m_q, kc * m_kv, 1, 1
    band = jnp.stack(
        [q_off.astype(jnp.int32), kv_off.astype(jnp.int32), jnp.int32(lo), jnp.int32(hi)]
    )
    return band, sq, skv


def _combine_f32(o1, lse1, o2, lse2):
    """Online-softmax combine with fp32 output accumulators.

    o: [B, S, H, D] fp32; lse: [B, H, S] fp32.
    """
    m = jnp.maximum(jnp.maximum(lse1, lse2), NEG_INF)
    w1 = jnp.exp(lse1 - m)
    w2 = jnp.exp(lse2 - m)
    tot = w1 + w2
    tot_safe = jnp.where(tot > 0, tot, 1.0)
    c1 = (w1 / tot_safe).swapaxes(1, 2)[..., None]
    c2 = (w2 / tot_safe).swapaxes(1, 2)[..., None]
    o = o1 * c1 + o2 * c2
    lse = jnp.where(tot > 0, m + jnp.log(tot_safe), NEG_INF)
    return o, lse


def _merge(acc: Optional[tuple], o, lse):
    o = o.astype(jnp.float32)
    lse = lse.astype(jnp.float32)
    if acc is None:
        return o, lse
    return _combine_f32(acc[0], acc[1], o, lse)


# --------------------------------------------------------------------------
# ring transport (comm_overlap modes)
# --------------------------------------------------------------------------


def _ring_hop(buf, axis_name: str, perm, mode: str):
    """One ring hop of a pytree payload under the comm_overlap mode.

    ``serial``/``overlap``: one ppermute per leaf.  ``bidir``: every leaf is
    split into two half-payloads shipped as a concurrent ppermute pair — the
    TokenRing move (PAPERS.md): two independent transfers the runtime can
    route over both directions of the torus link, so each half moves at full
    per-direction bandwidth.  Reassembly is pure transport
    (``concat(x[..., :h], x[..., h:]) == x``), so downstream compute sees
    bitwise the single-permute payload and total wire bytes are unchanged.
    """
    if mode != "bidir":
        return jax.tree.map(lambda x: lax.ppermute(x, axis_name, perm), buf)

    def hop(x):
        if x.ndim == 0 or x.shape[-1] < 2:  # nothing to split (tiny payload)
            return lax.ppermute(x, axis_name, perm)
        h = x.shape[-1] // 2
        cw = lax.ppermute(x[..., :h], axis_name, perm)
        ccw = lax.ppermute(x[..., h:], axis_name, perm)
        return jnp.concatenate([cw, ccw], axis=-1)

    return jax.tree.map(hop, buf)


def _after_comms(issued, *operands):
    """``serial`` mode: thread compute operands through an optimization
    barrier with the step's in-flight permute results, so XLA must complete
    the transfers before any of the step's blocks run (the naive
    ppermute-then-compute ordering the serial cost model prices).  Identity
    on values — bitwise-neutral by construction."""
    if not issued:
        return operands
    out = lax.optimization_barrier(tuple(operands) + tuple(issued))
    return out[: len(operands)]


# --------------------------------------------------------------------------
# forward program (Algorithm 2 structure)
# --------------------------------------------------------------------------


def _fwd_program(q, k, v, cfg: MeshAttentionConfig, kv_transform=None, seg=None):
    """kv_transform (beyond-paper, §Perf 'latent wire'): when given, ``k`` is
    an opaque wire buffer (e.g. MLA's compressed latent) circulated on the KV
    ring; it is expanded to per-head (k, v) ONCE per received chunk, at first
    use.  Wire bytes drop from 2·Hkv·dk to the latent width.

    ``seg`` (int32 [S/n], the local chunk of the segment-id array) rides the
    rings alongside Q and KV for document/segment masks; mask-pruned blocks
    are simply absent from the (possibly shorter) schedule, with the send
    counters re-based so the surviving ring reduce stays aligned."""
    n, a, b = cfg.n, cfg.a, cfg.b
    lay = TileLayout(n, a)
    i = lax.axis_index(cfg.axis_name)
    scale = cfg.scale if cfg.scale is not None else q.shape[-1] ** -0.5
    sched, _ = cfg.schedules(n * q.shape[1])

    q_perm = lay.q_shift_perm()
    kv_perm = lay.kv_shift_perm()

    # each slot buffer is (payload, seg-or-None): jax.tree.map ppermutes both
    qs: Dict[int, tuple] = {0: (q, seg)}
    kvs: Dict[int, tuple] = {
        0: (k if kv_transform is not None else jnp.stack([k, v]), seg)
    }
    kv_used: Dict[int, tuple] = {}

    def kv_at(slot: int):
        if slot not in kv_used:
            buf, s_kv = kvs[slot]
            if kv_transform is not None:
                kk, vv = kv_transform(buf)
            else:
                kk, vv = buf[0], buf[1]
            kv_used[slot] = (kk, vv, s_kv)
        return kv_used[slot]

    o_acc: Dict[int, Optional[tuple]] = {u: None for u in range(a)}
    nq = nkv = 0
    # leading sends over fully-pruned rows are absent; re-base the counter
    nsend = (a - 1) - sum(1 for c in sched.comm_ops() if c == S.SEND_O)

    mode = cfg.comm_overlap
    for step in sched.steps:
        # phase 1 — ISSUE: emit this step's ring permutes ahead of its
        # blocks.  Under the schedule semantics a transfer issued at step t
        # delivers at the END of t and feeds compute at t+1+ (double-buffered
        # slots), so in overlap/bidir mode the permute pair below rides the
        # wire WHILE the blocks of phase 2 run — XLA's async collectives see
        # no data dependency between them.
        recv_updates = []
        issued: list = []
        for comm in step.comms:
            if comm == S.RECV_Q:
                nxt = _ring_hop(qs[nq], cfg.axis_name, q_perm, mode)
                recv_updates.append(("q", nxt))
                issued += [x for x in jax.tree.leaves(nxt)]
            elif comm == S.RECV_KV:
                nxt = _ring_hop(kvs[nkv], cfg.axis_name, kv_perm, mode)
                recv_updates.append(("kv", nxt))
                issued += [x for x in jax.tree.leaves(nxt)]
            elif comm == S.SEND_O:
                src = nsend + 1  # completed row being forwarded
                dst = (nsend + 2) % a  # row whose partial arrives (Table 1)
                o_r, l_r = _ring_hop(o_acc[src], cfg.axis_name, q_perm, mode)
                o_acc[dst] = _merge(o_acc[dst], o_r, l_r)
                issued += [o_r, l_r]
                nsend += 1
            else:  # pragma: no cover
                raise ValueError(comm)
        # phase 2 — COMPUTE this step's blocks from previously-delivered
        # slots.  serial mode barriers each block's operands on the issued
        # transfers, pinning comm ahead of compute on the critical path.
        for (u, vv) in step.compute:
            band, sq, skv = _band_for_block(cfg, i, u, vv, q.shape[1], k.shape[1])
            q_u, s_q = qs[u]
            kk, vv_t, s_kv = kv_at(vv)
            if mode == "serial":
                q_u, kk, vv_t = _after_comms(issued, q_u, kk, vv_t)
            o_b, l_b = ops.block_attention(
                q_u, kk, vv_t, band,
                scale=scale, stride_q=sq, stride_kv=skv,
                block_q=cfg.block_q, block_kv=cfg.block_kv,
                seg_q=s_q, seg_kv=s_kv,
            )
            o_acc[u] = _merge(o_acc[u], o_b, l_b)
        # phase 3 — COMMIT: the in-flight transfers land in the next slots
        # (the buffer swap of the double buffer), visible from step t+1 on.
        for kind, buf in recv_updates:
            if kind == "q":
                nq += 1
                qs[nq] = buf
            else:
                nkv += 1
                kvs[nkv] = buf

    if o_acc[0] is None:  # every local-row block mask-pruned
        B, m, H = q.shape[0], q.shape[1], q.shape[2]
        return jnp.zeros_like(q), jnp.full((B, H, m), NEG_INF, jnp.float32)
    o_f, lse_f = o_acc[0]
    return o_f.astype(q.dtype), lse_f


# --------------------------------------------------------------------------
# backward program (Algorithm 3 structure)
# --------------------------------------------------------------------------


def _bwd_program(cfg: MeshAttentionConfig, q, k, v, o, lse, do, seg=None):
    n, a, b = cfg.n, cfg.a, cfg.b
    lay = TileLayout(n, a)
    i = lax.axis_index(cfg.axis_name)
    scale = cfg.scale if cfg.scale is not None else q.shape[-1] ** -0.5
    _, sched = cfg.schedules(n * q.shape[1])

    q_perm = lay.q_shift_perm()
    kv_perm = lay.kv_shift_perm()

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # [B,S,H]
    # the Q ring circulates the "OdOQ" bundle (paper wire) or the Δ-trick
    # bundle (beyond-paper: rowsum(dO·O) replaces the full O chunk — 2Nd/n+ε
    # bytes per hop instead of 3Nd/n)
    bundle0 = {"q": q, "do": do, "lse": lse, "delta": delta}
    if cfg.bwd_wire == "odoq":
        bundle0["o"] = o
    if seg is not None:
        bundle0["seg"] = seg

    qb: Dict[int, dict] = {0: bundle0}
    kvs: Dict[int, tuple] = {0: (jnp.stack([k, v]), seg)}
    dq_acc: Dict[int, Optional[jnp.ndarray]] = {u: None for u in range(a)}
    dkv_acc: Dict[int, Optional[jnp.ndarray]] = {u: None for u in range(b)}
    nq = nkv = 0
    # leading sends over fully-pruned rows/cols are absent; re-base counters
    ndq = (a - 1) - sum(1 for c in sched.comm_ops() if c == S.SEND_DQ)
    ndkv = (b - 1) - sum(1 for c in sched.comm_ops() if c == S.SEND_DKV)

    def _add(cur, new):
        new = new.astype(jnp.float32)
        return new if cur is None else cur + new

    mode = cfg.comm_overlap
    for step in sched.steps:
        # same issue/compute/commit pipeline as the forward executor; the
        # dq/dkv accumulation chains are plain float sums whose association
        # order is fixed by the schedule, so the bidir half-payload pairs
        # (each half summed element-wise on the same route) stay bitwise
        recv_updates = []
        issued: list = []
        for comm in step.comms:
            if comm == S.RECV_ODOQ:
                nxt = _ring_hop(qb[nq], cfg.axis_name, q_perm, mode)
                recv_updates.append(("q", nxt))
                issued += [x for x in jax.tree.leaves(nxt)]
            elif comm == S.RECV_KV:
                nxt = _ring_hop(kvs[nkv], cfg.axis_name, kv_perm, mode)
                recv_updates.append(("kv", nxt))
                issued += [x for x in jax.tree.leaves(nxt)]
            elif comm == S.SEND_DQ:
                src, dst = ndq + 1, (ndq + 2) % a
                got = _ring_hop(dq_acc[src], cfg.axis_name, q_perm, mode)
                dq_acc[dst] = _add(dq_acc[dst], got)
                issued.append(got)
                ndq += 1
            elif comm == S.SEND_DKV:
                src, dst = ndkv + 1, (ndkv + 2) % b
                got = _ring_hop(dkv_acc[src], cfg.axis_name, kv_perm, mode)
                dkv_acc[dst] = _add(dkv_acc[dst], got)
                issued.append(got)
                ndkv += 1
            else:  # pragma: no cover
                raise ValueError(comm)
        for (u, vv) in step.compute:
            band, sq, skv = _band_for_block(cfg, i, u, vv, q.shape[1], k.shape[1])
            bu = qb[u]
            kv_buf, s_kv = kvs[vv]
            q_u, do_u, kv_u = bu["q"], bu["do"], kv_buf
            if mode == "serial":
                q_u, do_u, kv_u = _after_comms(issued, q_u, do_u, kv_u)
            dq_b, dk_b, dv_b = ops.block_attention_bwd(
                q_u, kv_u[0], kv_u[1], bu.get("o"), bu["lse"], do_u, band,
                scale=scale, stride_q=sq, stride_kv=skv,
                block_q=cfg.block_q, block_kv=cfg.block_kv, delta=bu["delta"],
                seg_q=bu.get("seg"), seg_kv=s_kv,
            )
            dq_acc[u] = _add(dq_acc[u], dq_b)
            dkv_acc[vv] = _add(dkv_acc[vv], jnp.stack([dk_b, dv_b]))
        for kind, buf in recv_updates:
            if kind == "q":
                nq += 1
                qb[nq] = buf
            else:
                nkv += 1
                kvs[nkv] = buf

    dq = jnp.zeros_like(q) if dq_acc[0] is None else dq_acc[0].astype(q.dtype)
    dkv = dkv_acc[0]
    if dkv is None:
        return dq, jnp.zeros_like(k), jnp.zeros_like(v)
    return dq, dkv[0].astype(k.dtype), dkv[1].astype(v.dtype)


# --------------------------------------------------------------------------
# public op
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _mesh_attention(q, k, v, cfg: MeshAttentionConfig):
    o, _ = _fwd_program(q, k, v, cfg)
    return o


def _mesh_attention_fwd(q, k, v, cfg):
    o, lse = _fwd_program(q, k, v, cfg)
    return o, (q, k, v, o, lse)


def _mesh_attention_bwd(cfg, res, do):
    q, k, v, o, lse = res
    return _bwd_program(cfg, q, k, v, o, lse, do)


_mesh_attention.defvjp(_mesh_attention_fwd, _mesh_attention_bwd)


# variant with a segment-id operand (packed documents): the int32 chunk is a
# traced argument whose cotangent is None
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _mesh_attention_seg(q, k, v, seg, cfg: MeshAttentionConfig):
    o, _ = _fwd_program(q, k, v, cfg, seg=seg)
    return o


def _mesh_attention_seg_fwd(q, k, v, seg, cfg):
    o, lse = _fwd_program(q, k, v, cfg, seg=seg)
    return o, (q, k, v, seg, o, lse)


def _mesh_attention_seg_bwd(cfg, res, do):
    q, k, v, seg, o, lse = res
    dq, dk, dv = _bwd_program(cfg, q, k, v, o, lse, do, seg=seg)
    return dq, dk, dv, None


_mesh_attention_seg.defvjp(_mesh_attention_seg_fwd, _mesh_attention_seg_bwd)


def _local_band(cfg: MeshAttentionConfig):
    """Static band for the n == 1 degenerate path."""
    spec = cfg.mask_spec()
    if spec.kind == "block_sparse":
        if len(spec.bitmap) != cfg.n:
            raise ValueError(
                f"block_sparse bitmap is {len(spec.bitmap)}x{len(spec.bitmap)}, "
                f"but the sequence is split n={cfg.n} ways"
            )
        return (0, 0, -BAND_INF, BAND_INF) if spec.bitmap[0][0] else (0, 0, 1, 0)
    lo, hi = spec.band()
    return (0, 0, lo, hi)


def mesh_attention(q, k, v, cfg: MeshAttentionConfig, seg=None):
    """Distributed attention over the local chunks (call inside shard_map).

    q: [B, S/n, H, D]; k, v: [B, S/n, Hkv, D] -> o: [B, S/n, H, D].
    Causal inputs must be striped (token t on chunk t mod n).  ``seg`` is the
    local [S/n] int32 segment-id chunk for document/segment masks.
    """
    spec = cfg.mask_spec()
    if spec.needs_segments and seg is None:
        raise ValueError(f"mask kind {spec.kind!r} needs a segment-id operand")
    if cfg.n == 1:
        return ops.flash_attention(
            q, k, v, band=_local_band(cfg), scale=cfg.scale,
            seg_q=seg, seg_kv=seg,
        )
    if seg is not None:
        return _mesh_attention_seg(q, k, v, jnp.asarray(seg, jnp.int32), cfg)
    return _mesh_attention(q, k, v, cfg)


def mesh_attention_with_lse(q, k, v, cfg: MeshAttentionConfig, seg=None):
    """Forward-only variant exposing the log-sum-exp (tests, serving)."""
    return _fwd_program(q, k, v, cfg, seg=seg)


def mesh_attention_wire(q, wire, cfg: MeshAttentionConfig, kv_transform, seg=None):
    """Mesh-Attention with a compressed KV wire (beyond-paper, §Perf).

    ``wire``: the per-device chunk of whatever representation should
    circulate on the KV ring (e.g. MLA latent [B, S/n, 1, kvr+rope]);
    ``kv_transform(chunk) -> (k, v)`` expands it per-head at first use.
    Differentiable by plain autodiff (no custom Alg-3 rule on this path);
    intended for forward-only prefill/serving.
    """
    o, _ = _fwd_program(q, wire, None, cfg, kv_transform=kv_transform, seg=seg)
    return o
