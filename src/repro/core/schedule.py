"""Greedy intra-tile scheduling (paper §3.4–§3.6, Algorithms 2 and 3).

A *schedule* is an explicit list of steps; each step carries at most one
communication operation per ring (paper restriction (2)) plus the set of
compute blocks overlapped with it.  The same schedule object drives

  * the event-driven simulator (``core/simulator.py``) that estimates runtime
    for the Fig.-6 autotuning flow and the paper-table benchmarks, and
  * the distributed implementation (``core/mesh_attention.py``), which emits
    one ``jax.lax.ppermute`` + a batch of flash-attention block calls per
    step, in exactly this order, so the *structure* of the comm/compute
    overlap in the lowered HLO is the paper's schedule.

Blocks are identified by local slot coordinates (u, v): Q slot u in [0, a),
KV slot v in [0, b).  Slot 0 is the device's own chunk (Table 1), so block
(0, 0) is the local Q-KV block — the "local Q-KV property" guarantees it is
computable with zero communication.

Semantics of a step (lock-step across all devices, paper §3.2):
  * a ``recv_*`` issued in step s delivers its chunk at the END of step s:
    compute scheduled in step s may only use chunks received in steps < s;
  * a ``send_*`` issued in step s requires its payload complete in steps < s.

Mask-aware pruning: the generators accept ``skip_blocks`` — slot blocks that
a ``MaskSpec`` proved fully masked on EVERY device (``masking.empty_blocks``).
Skipped blocks are never computed, and communication that only feeds skipped
blocks is dropped under the ring constraints:
  * receives are a forwarding pipeline (chunk u arrives after u hops), so only
    the TRAILING recvs past the highest used slot can be dropped;
  * sends are an accumulation chain (send #t carries contributions of rows
    1..t), so only the LEADING sends whose whole prefix of rows is skipped
    can be dropped.
The schedule records its skip set so the executor and validator agree.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "COMM_OVERLAP_MODES",
    "validate_comm_overlap",
    "Profile",
    "Step",
    "Schedule",
    "comm_requirements",
    "greedy_forward_schedule",
    "greedy_backward_schedule",
    "naive_forward_schedule",
    "ring_forward_schedule",
    "validate_schedule",
    "schedule_to_json",
    "schedule_from_json",
]

Block = Tuple[int, int]

# How the executor orders each step's ring permutes against its compute
# blocks, threaded from ParallelCtx/AttentionPlanConfig down to the ring
# programs and the simulator's step-cost model:
#   serial  - every permute completes before the step's blocks run (an
#             optimization barrier pins it on the critical path): the naive
#             ppermute-then-compute baseline, cost = comm + compute per step.
#   overlap - permutes issued at step start stay in flight during the step's
#             blocks and deliver at step end (double-buffered slots), cost =
#             max(comm, compute) + the exposed launch residual.
#   bidir   - overlap, plus every hop's payload is split into a half-payload
#             ppermute pair so both ring directions of the link carry traffic
#             (TokenRing, PAPERS.md): same bytes, per-direction bandwidth.
# All three modes execute the SAME schedule and are bitwise-equal: only the
# transport routing and the modeled step cost differ.
COMM_OVERLAP_MODES = ("serial", "overlap", "bidir")


def validate_comm_overlap(mode: str) -> str:
    if mode not in COMM_OVERLAP_MODES:
        raise ValueError(
            f"unknown comm_overlap {mode!r}; expected "
            + " | ".join(COMM_OVERLAP_MODES)
        )
    return mode


# communication op kinds
RECV_Q = "recv_q"
RECV_KV = "recv_kv"
SEND_O = "send_o"
RECV_ODOQ = "recv_odoq"
SEND_DQ = "send_dq"
SEND_DKV = "send_dkv"

_Q_RING_OPS = frozenset({RECV_Q, SEND_O, RECV_ODOQ, SEND_DQ})
_KV_RING_OPS = frozenset({RECV_KV, SEND_DKV})


@dataclasses.dataclass(frozen=True)
class Profile:
    """Overlap profile: c_<kind> = least number of compute blocks that fully
    hides one chunk transfer of that kind (paper's profiled constants).

    On real hardware these come from measurement; on this container they are
    derived analytically (see ``core/autotune.py``).  Values are floats so
    the simulator can use fractional ratios; the scheduler ceils them.
    """

    c_q: float = 1.0
    c_kv: float = 2.0
    c_o: float = 1.0
    c_odoq: float = 3.0
    c_dq: float = 1.0
    c_dkv: float = 2.0

    def blocks_to_hide(self, kind: str) -> int:
        val = {
            RECV_Q: self.c_q,
            RECV_KV: self.c_kv,
            SEND_O: self.c_o,
            RECV_ODOQ: self.c_odoq,
            SEND_DQ: self.c_dq,
            SEND_DKV: self.c_dkv,
        }[kind]
        return max(1, int(math.ceil(val)))

    def cost(self, kind: str) -> float:
        """Transfer time of one chunk, in units of one compute block."""
        return {
            RECV_Q: self.c_q,
            RECV_KV: self.c_kv,
            SEND_O: self.c_o,
            RECV_ODOQ: self.c_odoq,
            SEND_DQ: self.c_dq,
            SEND_DKV: self.c_dkv,
        }[kind]


@dataclasses.dataclass(frozen=True)
class Step:
    """One lock-step: the communications issued at step start (at most one
    per ring: paper restriction (2) means ``len(comms) <= 1``; the relaxed
    TPU mode allows one Q-ring op and one KV-ring op concurrently) and the
    compute blocks overlapped with them."""

    comms: Tuple[str, ...]
    compute: Tuple[Block, ...]


@dataclasses.dataclass(frozen=True)
class Schedule:
    a: int
    b: int
    direction: str  # "fwd" | "bwd"
    steps: Tuple[Step, ...]
    skip: Tuple[Block, ...] = ()  # mask-pruned blocks (empty on every device)

    @property
    def n(self) -> int:
        return self.a * self.b

    def comm_ops(self) -> List[str]:
        return [c for s in self.steps for c in s.comms]

    def num_steps(self) -> int:
        return len(self.steps)

    def blocks(self) -> List[Block]:
        return [blk for s in self.steps for blk in s.compute]


def _norm_skip(a: int, b: int, skip_blocks) -> Tuple[Block, ...]:
    skip = tuple(sorted((int(u), int(v)) for u, v in (skip_blocks or ())))
    for (u, v) in skip:
        if not (0 <= u < a and 0 <= v < b):
            raise ValueError(f"skip block {(u, v)} out of range for ({a}, {b})")
    if (0, 0) in skip:
        raise ValueError("block (0, 0) is the local Q-KV block and is never empty")
    return skip


def comm_requirements(a: int, b: int, direction: str, skip: Sequence[Block]) -> Dict[str, int]:
    """Expected comm-op counts for a (possibly pruned) schedule.

    Receives: the ring forwards chunks hop by hop, so the number of recvs is
    the highest used slot index.  Sends: send #t carries the accumulated
    contributions of rows (columns) 1..t, so only a leading run of fully
    skipped rows (columns) removes sends.
    """
    skip = set(skip)
    used = [(u, v) for u in range(a) for v in range(b) if (u, v) not in skip]
    max_u = max((u for u, _ in used), default=0)
    max_v = max((v for _, v in used), default=0)

    def lead_empty(total: int, full) -> int:
        t = 0
        while t + 1 < total and full(t + 1):
            t += 1
        return t

    row_empty = lambda u: all((u, v) in skip for v in range(b))
    col_empty = lambda v: all((u, v) in skip for u in range(a))
    t0_rows = lead_empty(a, row_empty)
    t0_cols = lead_empty(b, col_empty)
    if direction == "fwd":
        return {RECV_Q: max_u, RECV_KV: max_v, SEND_O: max(0, a - 1 - t0_rows)}
    return {
        RECV_ODOQ: max_u,
        RECV_KV: max_v,
        SEND_DQ: max(0, a - 1 - t0_rows),
        SEND_DKV: max(0, b - 1 - t0_cols),
    }


# --------------------------------------------------------------------------
# forward (Algorithm 2)
# --------------------------------------------------------------------------


def _fwd_priority_order(a: int, b: int) -> List[Block]:
    """Row-first order with the local row (slot 0) de-prioritized: rows
    1..a-1 are on the critical path (their O must be sent to peers), row 0
    only feeds the device's own output (paper principle 3)."""
    rows = list(range(1, a)) + [0]
    return [(u, v) for u in rows for v in range(b)]


class _TileState:
    """Mutable tile progress shared by the schedule generators.

    ``skip`` blocks are pre-marked done: never emitted as compute, but they
    count toward row/column completion (their contribution is exactly empty).
    """

    def __init__(self, a: int, b: int, order: Sequence[Block], skip: Sequence[Block] = ()):
        self.a, self.b = a, b
        self.have_q = 1  # local slot 0 is present from the start
        self.have_kv = 1
        self.skip = set(skip)
        self.done: set = set(self.skip)
        self.order = list(order)
        used = [blk for blk in ((u, v) for u in range(a) for v in range(b)) if blk not in self.skip]
        # slots actually read by some block: recvs beyond them are pruned
        self.need_q = max((u for u, _ in used), default=0) + 1
        self.need_kv = max((v for _, v in used), default=0) + 1

    def ready(self, blk: Block) -> bool:
        u, v = blk
        return u < self.have_q and v < self.have_kv and blk not in self.done

    def ready_blocks(self) -> List[Block]:
        return [blk for blk in self.order if self.ready(blk)]

    def pop_compute(self, x: int) -> Tuple[Block, ...]:
        out = []
        for blk in self.order:
            if len(out) >= x:
                break
            if self.ready(blk):
                out.append(blk)
                self.done.add(blk)
        return tuple(out)

    def row_done(self, u: int) -> bool:
        return all((u, v) in self.done for v in range(self.b))

    def col_done(self, v: int) -> bool:
        return all((u, v) in self.done for u in range(self.a))

    def all_done(self) -> bool:
        return len(self.done) == self.a * self.b


def greedy_forward_schedule(
    a: int,
    b: int,
    profile: Optional[Profile] = None,
    *,
    allow_concurrent_rings: bool = False,
    skip_blocks: Optional[Iterable[Block]] = None,
) -> Schedule:
    """Paper Algorithm 2.

    Phase 1 — receive everything, maximizing *profit* = unlocked blocks per
    unit transfer cost; overlap "just enough" compute (c_kind blocks).
    Phase 2 — send the a-1 partial O rows in ring order, inserting single
    compute steps while the next row is incomplete.
    Phase 3 — drain the remaining blocks (the de-prioritized local row).

    ``allow_concurrent_rings`` is the beyond-paper TPU relaxation: the Q ring
    and KV ring live on different ICI dimensions, so one recv_q and one
    recv_kv may be issued in the same step (restriction (2) is per-ring).

    ``skip_blocks`` prunes mask-empty blocks and the comm that only feeds
    them (trailing recvs, leading sends over fully skipped rows).
    """
    profile = profile or Profile()
    skip = _norm_skip(a, b, skip_blocks)
    st = _TileState(a, b, _fwd_priority_order(a, b), skip)
    req = comm_requirements(a, b, "fwd", skip)
    steps: List[Step] = []

    # ---- phase 1: Recv Q / Recv KV by profit -------------------------------
    while st.have_q < st.need_q or st.have_kv < st.need_kv:
        comms: List[str] = []
        budget = 0
        # profit of the next recv on each ring: blocks unlocked / cost
        profit_q = (st.have_kv / profile.cost(RECV_Q)) if st.have_q < st.need_q else -1.0
        profit_kv = (st.have_q / profile.cost(RECV_KV)) if st.have_kv < st.need_kv else -1.0
        if allow_concurrent_rings:
            if st.have_q < st.need_q:
                comms.append(RECV_Q)
                budget = max(budget, profile.blocks_to_hide(RECV_Q))
            if st.have_kv < st.need_kv:
                comms.append(RECV_KV)
                budget = max(budget, profile.blocks_to_hide(RECV_KV))
        elif profit_q > profit_kv:
            comms, budget = [RECV_Q], profile.blocks_to_hide(RECV_Q)
        else:
            comms, budget = [RECV_KV], profile.blocks_to_hide(RECV_KV)
        compute = st.pop_compute(budget)  # only already-received slots
        steps.append(Step(tuple(comms), compute))
        if RECV_Q in comms:
            st.have_q += 1
        if RECV_KV in comms:
            st.have_kv += 1

    # ---- phase 2: Send O rows in ring order (leading empty rows pruned) -----
    first_row = a - req[SEND_O]  # rows 1..first_row-1 are fully skipped
    for row in range(first_row, a):
        while not st.row_done(row):  # Send O invalid -> compute-only steps
            steps.append(Step((), st.pop_compute(1)))
        steps.append(Step((SEND_O,), st.pop_compute(profile.blocks_to_hide(SEND_O))))

    # ---- phase 3: drain ------------------------------------------------------
    while not st.all_done():
        steps.append(Step((), st.pop_compute(1)))

    return Schedule(a, b, "fwd", tuple(steps), skip)


def naive_forward_schedule(a: int, b: int) -> Schedule:
    """Figure 5(b): row-first recvs, every unlocked block computed eagerly —
    the un-balanced baseline the greedy algorithm improves on."""
    st = _TileState(a, b, [(u, v) for u in range(a) for v in range(b)])
    steps: List[Step] = []
    for _ in range(a - 1):
        steps.append(Step((RECV_Q,), st.pop_compute(a * b)))
        st.have_q += 1
    for _ in range(b - 1):
        steps.append(Step((RECV_KV,), st.pop_compute(a * b)))
        st.have_kv += 1
    for row in range(1, a):
        while not st.row_done(row):
            steps.append(Step((), st.pop_compute(1)))
        steps.append(Step((SEND_O,), ()))
    while not st.all_done():
        steps.append(Step((), st.pop_compute(1)))
    return Schedule(a, b, "fwd", tuple(steps))


def ring_forward_schedule(n: int) -> Schedule:
    """Ring-Attention = (a=1, b=n): n-1 Recv KV steps each hiding exactly one
    block (Figure 5(a)), then the final block."""
    st = _TileState(1, n, [(0, v) for v in range(n)])
    steps = []
    for _ in range(n - 1):
        steps.append(Step((RECV_KV,), st.pop_compute(1)))
        st.have_kv += 1
    while not st.all_done():
        steps.append(Step((), st.pop_compute(1)))
    return Schedule(1, n, "fwd", tuple(steps))


# --------------------------------------------------------------------------
# backward (Algorithm 3)
# --------------------------------------------------------------------------


def _bwd_row_order(a: int) -> List[int]:
    return list(range(1, a)) + [0]


def _bwd_col_order(b: int) -> List[int]:
    return list(range(1, b)) + [0]


class _BwdChooser:
    """ChooseNextBlock (Alg. 3 lines 1-7): alternate between finishing rows
    (unblocks Send dQ) and columns (unblocks Send dKV) by weighted
    completion proximity."""

    def __init__(self, st: _TileState, profile: Profile):
        self.st, self.profile = st, profile

    def _first_unfinished(self, rows: bool) -> Optional[int]:
        order = _bwd_row_order(self.st.a) if rows else _bwd_col_order(self.st.b)
        for idx in order:
            done = self.st.row_done(idx) if rows else self.st.col_done(idx)
            if not done:
                return idx
        return None

    def next_block(self) -> Optional[Block]:
        ready = self.st.ready_blocks()
        if not ready:
            return None
        r = self._first_unfinished(rows=True)
        c = self._first_unfinished(rows=False)
        n_dq = sum(1 for v in range(self.st.b) if (r, v) not in self.st.done) if r is not None else 0
        n_dkv = sum(1 for u in range(self.st.a) if (u, c) not in self.st.done) if c is not None else 0
        col_first = False
        if n_dq and n_dkv:
            col_first = self.profile.c_dq / n_dq < self.profile.c_dkv / n_dkv
        elif n_dkv:
            col_first = True
        if col_first:
            order = [(u, v) for v in _bwd_col_order(self.st.b) for u in _bwd_row_order(self.st.a)]
        else:
            order = [(u, v) for u in _bwd_row_order(self.st.a) for v in _bwd_col_order(self.st.b)]
        for blk in order:
            if self.st.ready(blk):
                return blk
        return None

    def pop(self, x: int) -> Tuple[Block, ...]:
        out = []
        for _ in range(x):
            blk = self.next_block()
            if blk is None:
                break
            self.st.done.add(blk)
            out.append(blk)
        return tuple(out)


def greedy_backward_schedule(
    a: int,
    b: int,
    profile: Optional[Profile] = None,
    *,
    allow_concurrent_rings: bool = False,
    skip_blocks: Optional[Iterable[Block]] = None,
) -> Schedule:
    """Paper Algorithm 3: Recv OdOQ along the Q ring, Recv KV along the KV
    ring (profit-driven), then alternate Send dQ (after each remote row
    completes) and Send dKV (after each remote column completes).

    ``skip_blocks`` prunes exactly like the forward generator (the dQ/dKV of
    an everywhere-empty block is zero, so the same blocks drop out)."""
    profile = profile or Profile()
    skip = _norm_skip(a, b, skip_blocks)
    st = _TileState(a, b, [(u, v) for u in _bwd_row_order(a) for v in _bwd_col_order(b)], skip)
    req = comm_requirements(a, b, "bwd", skip)
    chooser = _BwdChooser(st, profile)
    steps: List[Step] = []

    # ---- phase 1: receives ---------------------------------------------------
    while st.have_q < st.need_q or st.have_kv < st.need_kv:
        comms: List[str] = []
        budget = 0
        profit_q = (st.have_kv / profile.cost(RECV_ODOQ)) if st.have_q < st.need_q else -1.0
        profit_kv = (st.have_q / profile.cost(RECV_KV)) if st.have_kv < st.need_kv else -1.0
        if allow_concurrent_rings:
            if st.have_q < st.need_q:
                comms.append(RECV_ODOQ)
                budget = max(budget, profile.blocks_to_hide(RECV_ODOQ))
            if st.have_kv < st.need_kv:
                comms.append(RECV_KV)
                budget = max(budget, profile.blocks_to_hide(RECV_KV))
        elif profit_q > profit_kv:
            comms, budget = [RECV_ODOQ], profile.blocks_to_hide(RECV_ODOQ)
        else:
            comms, budget = [RECV_KV], profile.blocks_to_hide(RECV_KV)
        compute = chooser.pop(budget)
        steps.append(Step(tuple(comms), compute))
        if RECV_ODOQ in comms:
            st.have_q += 1
        if RECV_KV in comms:
            st.have_kv += 1

    # ---- phase 2: sends (leading fully-skipped rows/cols pruned) -------------
    first_row = a - req[SEND_DQ]  # first row whose dQ must be sent
    first_col = b - req[SEND_DKV]
    sent_dq, sent_dkv = 0, 0
    while sent_dq < req[SEND_DQ] or sent_dkv < req[SEND_DKV]:
        dq_valid = sent_dq < req[SEND_DQ] and st.row_done(first_row + sent_dq)
        dkv_valid = sent_dkv < req[SEND_DKV] and st.col_done(first_col + sent_dkv)
        if not (dq_valid or dkv_valid):
            steps.append(Step((), chooser.pop(1)))
            continue
        if dq_valid and dkv_valid and allow_concurrent_rings:
            budget = max(profile.blocks_to_hide(SEND_DQ), profile.blocks_to_hide(SEND_DKV))
            steps.append(Step((SEND_DQ, SEND_DKV), chooser.pop(budget)))
            sent_dq += 1
            sent_dkv += 1
        elif dq_valid:
            steps.append(Step((SEND_DQ,), chooser.pop(profile.blocks_to_hide(SEND_DQ))))
            sent_dq += 1
        else:
            steps.append(Step((SEND_DKV,), chooser.pop(profile.blocks_to_hide(SEND_DKV))))
            sent_dkv += 1

    while not st.all_done():
        steps.append(Step((), chooser.pop(1)))

    return Schedule(a, b, "bwd", tuple(steps), skip)


# --------------------------------------------------------------------------
# (de)serialization — the autotuner's on-disk plan cache stores schedules
# --------------------------------------------------------------------------


def schedule_to_json(s: Schedule) -> dict:
    return {
        "a": s.a,
        "b": s.b,
        "direction": s.direction,
        "steps": [
            {"comms": list(st.comms), "compute": [list(blk) for blk in st.compute]}
            for st in s.steps
        ],
        "skip": [list(blk) for blk in s.skip],
    }


def schedule_from_json(d: dict) -> Schedule:
    steps = tuple(
        Step(tuple(st["comms"]), tuple((int(u), int(v)) for u, v in st["compute"]))
        for st in d["steps"]
    )
    skip = tuple((int(u), int(v)) for u, v in d.get("skip", ()))
    return Schedule(int(d["a"]), int(d["b"]), d["direction"], steps, skip)


# --------------------------------------------------------------------------
# validation (used by tests and asserted by the distributed op at trace time)
# --------------------------------------------------------------------------


def validate_schedule(s: Schedule, *, strict_paper: bool = False) -> None:
    """Check every invariant the paper's restrictions imply (including the
    pruning rules when ``s.skip`` is non-empty).  Raises ``ValueError`` on
    the first violation."""
    a, b = s.a, s.b
    fwd = s.direction == "fwd"
    recv_q_kind = RECV_Q if fwd else RECV_ODOQ
    skip = set(_norm_skip(a, b, s.skip))
    expect = comm_requirements(a, b, s.direction, skip)
    # sends over leading fully-skipped rows/cols are pruned; later ones shift
    first_row = a - expect.get(SEND_O if fwd else SEND_DQ, 0)
    first_col = b - expect.get(SEND_DKV, 0)

    have_q, have_kv = 1, 1
    done: set = set(skip)  # skipped blocks complete rows/cols with zero work
    counts: Dict[str, int] = {}
    sent_o = sent_dq = sent_dkv = 0

    for idx, step in enumerate(s.steps):
        if strict_paper and len(step.comms) > 1:
            raise ValueError(f"step {idx}: restriction (2) violated: {step.comms}")
        q_ops = [c for c in step.comms if c in _Q_RING_OPS]
        kv_ops = [c for c in step.comms if c in _KV_RING_OPS]
        if len(q_ops) > 1 or len(kv_ops) > 1:
            raise ValueError(f"step {idx}: >1 op on one ring: {step.comms}")
        # sends must have payload complete BEFORE this step
        for c in step.comms:
            counts[c] = counts.get(c, 0) + 1
            if c == SEND_O or c == SEND_DQ:
                row = first_row + (sent_o if c == SEND_O else sent_dq)
                if not all((row, v) in done for v in range(b)):
                    raise ValueError(f"step {idx}: {c} #{row} before row {row} complete")
                if c == SEND_O:
                    sent_o += 1
                else:
                    sent_dq += 1
            elif c == SEND_DKV:
                col = first_col + sent_dkv
                if not all((u, col) in done for u in range(a)):
                    raise ValueError(f"step {idx}: send_dkv #{col} before col {col} complete")
                sent_dkv += 1
        # compute may only use chunks received in strictly earlier steps
        for (u, v) in step.compute:
            if not (0 <= u < a and 0 <= v < b):
                raise ValueError(f"step {idx}: block {(u, v)} out of range")
            if (u, v) in skip:
                raise ValueError(f"step {idx}: block {(u, v)} is mask-pruned but scheduled")
            if (u, v) in done:
                raise ValueError(f"step {idx}: block {(u, v)} computed twice")
            if u >= have_q or v >= have_kv:
                raise ValueError(
                    f"step {idx}: block {(u, v)} not ready (have_q={have_q}, have_kv={have_kv})"
                )
            done.add((u, v))
        # receives deliver at end of step
        for c in step.comms:
            if c == recv_q_kind:
                have_q += 1
            elif c == RECV_KV:
                have_kv += 1

    if len(done) != a * b:
        raise ValueError(f"{a*b - len(done)} blocks never computed")
    for kind, cnt in expect.items():
        if counts.get(kind, 0) != cnt:
            raise ValueError(f"{kind}: expected {cnt} ops, got {counts.get(kind, 0)}")
    for kind in counts:
        if kind not in expect:
            raise ValueError(f"unexpected op kind {kind} in {s.direction} schedule")
