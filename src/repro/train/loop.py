"""Training loop: jitted step, checkpoint/restart, preemption, stragglers,
elastic re-meshing, optional compressed cross-pod gradient reduction.

Fault-tolerance model (designed for 1000+ nodes, exercised on fake devices):
  * every state mutation goes through the atomic checkpointer; restart
    resumes from the newest *valid* checkpoint (corrupt ones are skipped);
  * SIGTERM/SIGINT set a flag; the loop checkpoints at the next step
    boundary and exits cleanly (preemption handling);
  * the data pipeline is a pure function of (seed, step), so a restarted or
    re-meshed run consumes the identical stream;
  * ``elastic_fit`` rebuilds the mesh from the *live* device set and
    reshards the restored state — a 512-chip run restarts on 256 chips;
  * the StepMonitor's "remesh" escalation flows through the same path.

Cross-pod gradient compression: when enabled and the mesh has a "pod" axis,
the step runs under ``shard_map(axis_names={"pod"})`` — manual over pods,
GSPMD-automatic inside — so per-pod gradients are quantized (int8 + error
feedback) before the slow DCN all-reduce.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map, supports_nested_manual_grad
from repro.configs.base import ModelConfig
from repro.data.pipeline import make_batch
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state
from repro.parallel import sharding as shd
from repro.parallel.compression import CompressionConfig, compressed_psum, init_error_state
from repro.parallel.context import ParallelCtx
from repro.train import checkpoint as ckpt
from repro.train.monitor import StepMonitor, StragglerPolicy

__all__ = ["TrainConfig", "make_train_step", "fit", "elastic_fit"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    seq: int = 128
    batch: int = 8
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    param_dtype: object = jnp.float32
    compression: Optional[CompressionConfig] = None
    docs: Optional[int] = None  # pack N documents per row (segment-mask attention)


def make_train_step(cfg: ModelConfig, ctx: ParallelCtx, opt_cfg: AdamWConfig,
                    compression: Optional[CompressionConfig] = None):
    """Returns jitted (params, opt_state, err, batch) -> (params, opt_state,
    err, metrics)."""

    use_comp = (
        compression is not None
        and compression.kind != "none"
        and ctx.mesh is not None
        and "pod" in ctx.mesh.shape
        and ctx.mesh.shape["pod"] > 1
        # the compressed path differentiates the model INSIDE a manual-pod
        # shard_map; on jax 0.4.x that nesting cannot lower (see compat) and
        # the step falls back to the plain uncompressed all-reduce
        and supports_nested_manual_grad()
    )

    def grads_and_metrics(params, batch, the_ctx):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: tfm.loss_fn(p, cfg, the_ctx, batch), has_aux=True
        )(params)
        return grads, metrics

    if not use_comp:

        def step_fn(params, opt_state, err, batch):
            grads, metrics = grads_and_metrics(params, batch, ctx)
            params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
            metrics.update(om)
            return params, opt_state, err, metrics

    else:
        # inside the manual-pod region, the model must not mention "pod"
        pod_ctx = dataclasses.replace(ctx, batch_axes=tuple(a for a in ctx.batch_axes if a != "pod"))

        def inner(params, opt_state, err, batch):
            # per-pod gradients (batch dim is pod-sharded outside; here each
            # pod sees its slice), then the compressed DCN all-reduce
            grads, metrics = grads_and_metrics(params, batch, pod_ctx)
            grads, err = compressed_psum(grads, "pod", err, compression)
            params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
            metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), metrics)
            metrics.update(om)
            return params, opt_state, err, metrics

        def step_fn(params, opt_state, err, batch):
            # partial-manual shard_map: only the pod axis is manual, so specs
            # may only mention "pod"; data/model sharding of params flows
            # through GSPMD from the arrays' own shardings
            rep = jax.tree.map(lambda _: P(), params)
            orep = OptState(P(), rep, rep)
            bspec = {k: (P() if k in ("positions", "segments") else P("pod")) for k in batch}
            f = shard_map(
                partial(inner),
                mesh=ctx.mesh,
                in_specs=(rep, orep, rep, bspec),
                out_specs=(rep, orep, rep, P()),
                axis_names={"pod"},
                check_vma=False,
            )
            return f(params, opt_state, err, batch)

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def _shard_batch(batch, cfg, ctx: ParallelCtx, kind="train"):
    if ctx.mesh is None:
        return batch
    specs = shd.batch_specs(cfg, ctx, kind=kind, batch=batch["tokens"].shape[0])
    return {
        k: jax.device_put(v, NamedSharding(ctx.mesh, specs[k])) for k, v in batch.items()
    }


class _Preempt:
    def __init__(self):
        self.flag = False

    def install(self):
        def handler(signum, frame):
            self.flag = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not the main thread (tests)
        return self


def fit(
    cfg: ModelConfig,
    ctx: ParallelCtx,
    tcfg: TrainConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    *,
    hooks: Optional[Dict[str, Callable]] = None,
) -> Dict:
    """Train; resume from tcfg.ckpt_dir when a valid checkpoint exists."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=tcfg.steps)
    hooks = hooks or {}
    preempt = _Preempt().install()
    monitor = StepMonitor(StragglerPolicy(action="checkpoint"))

    init = lambda: tfm.init_params(cfg, jax.random.PRNGKey(tcfg.seed), dtype=tcfg.param_dtype, ctx=ctx)
    if ctx.mesh is not None:
        abstract = jax.eval_shape(init)
        shardings = shd.param_shardings(abstract, ctx, "train")
        params = jax.jit(init, out_shardings=shardings)()
    else:
        params = init()
    opt_state = init_opt_state(params)
    err = init_error_state(params) if tcfg.compression else jax.tree.map(lambda _: jnp.zeros(()), {})
    start_step = 0

    if tcfg.ckpt_dir is not None:
        try:
            state_like = {"params": params, "m": opt_state.m, "v": opt_state.v,
                          "step": jnp.zeros((), jnp.int32)}
            restored, ck_step = ckpt.restore(tcfg.ckpt_dir, state_like)
            params = restored["params"]
            opt_state = OptState(step=restored["step"], m=restored["m"], v=restored["v"])
            start_step = ck_step
        except (FileNotFoundError, IOError):
            pass

    step_fn = make_train_step(cfg, ctx, opt_cfg, tcfg.compression)
    saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep) if tcfg.ckpt_dir else None
    history = []
    metrics = {}

    def save_now(step):
        if saver is None:
            return
        saver.save(step, {"params": params, "m": opt_state.m, "v": opt_state.v,
                          "step": opt_state.step})
        saver.wait()

    step = start_step
    for step in range(start_step, tcfg.steps):
        if preempt.flag:
            save_now(step)
            return {"interrupted": True, "step": step, "history": history}
        batch = make_batch(
            cfg, tcfg.seq, tcfg.batch, seed=tcfg.seed, step=step, ctx=ctx, docs=tcfg.docs
        )
        batch = _shard_batch(batch, cfg, ctx)
        t0 = time.perf_counter()
        params, opt_state, err, metrics = step_fn(params, opt_state, err, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        action = monitor.record(dt)
        history.append(float(metrics["loss"]))
        if "on_step" in hooks:
            hooks["on_step"](step, metrics)
        if action == "checkpoint" or (
            tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0
        ):
            save_now(step + 1)
        if "fail_at" in hooks and hooks["fail_at"] == step:
            raise RuntimeError(f"injected failure at step {step}")
    save_now(tcfg.steps)
    return {
        "interrupted": False,
        "step": tcfg.steps,
        "history": history,
        "final_loss": history[-1] if history else None,
        "straggler_events": monitor.events,
        "params": params,
    }


def elastic_fit(make_ctx: Callable[[], ParallelCtx], cfg, tcfg, opt_cfg=None, max_restarts=2):
    """Restart-on-failure wrapper: rebuilds the mesh from the live device set
    (make_ctx) and resumes from the newest valid checkpoint.  A shrunk or
    grown device set reshards transparently at restore."""
    attempts = 0
    while True:
        try:
            return fit(cfg, make_ctx(), tcfg, opt_cfg)
        except RuntimeError:
            attempts += 1
            if attempts > max_restarts:
                raise
