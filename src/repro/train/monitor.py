"""Step-time monitoring and straggler detection.

Mesh-Attention's lock-step symmetric schedule (paper §3.2) removes
*algorithmic* stragglers — every device executes identical work — so any
persistent outlier is a *hardware* straggler.  The monitor keeps an EMA and
EW-variance of step times and flags steps beyond ``k`` sigma; the policy
decides between logging, requesting a checkpoint, or excluding the node and
re-meshing through the elastic-restart path (train/loop.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional

__all__ = ["StragglerPolicy", "StepMonitor"]


@dataclasses.dataclass
class StragglerPolicy:
    sigma: float = 4.0
    patience: int = 3  # consecutive slow steps before escalation
    action: str = "log"  # log | checkpoint | remesh


class StepMonitor:
    def __init__(self, policy: Optional[StragglerPolicy] = None, decay: float = 0.95):
        self.policy = policy or StragglerPolicy()
        self.decay = decay
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.count = 0
        self._consecutive = 0
        self.events: List[dict] = []

    def record(self, dt: float) -> Optional[str]:
        """Record one step time; returns an escalation action or None."""
        self.count += 1
        if self.mean is None:
            self.mean = dt
            return None
        slow = self.is_straggler(dt)
        d = self.decay
        delta = dt - self.mean
        self.mean += (1 - d) * delta
        self.var = d * (self.var + (1 - d) * delta * delta)
        if not slow:
            self._consecutive = 0
            return None
        self._consecutive += 1
        self.events.append({"step": self.count, "dt": dt, "mean": self.mean})
        if self._consecutive >= self.policy.patience:
            self._consecutive = 0
            return self.policy.action
        return None

    def is_straggler(self, dt: float) -> bool:
        if self.mean is None or self.count < 5:
            return False
        sd = math.sqrt(max(self.var, 1e-12))
        return dt > self.mean + self.policy.sigma * max(sd, 0.05 * self.mean)
