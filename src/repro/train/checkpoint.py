"""Fault-tolerant checkpointing: atomic, content-hashed, keep-k, async.

Layout (one directory per step):
    <dir>/step_000123/
        arrays.npz          flattened pytree ("/"-joined paths)
        manifest.json       {step, keys, shapes, dtypes, sha256(arrays.npz)}
    <dir>/step_000123.tmp-* during write; os.replace() makes publish atomic.

Restores verify the manifest hash, skip corrupt/partial checkpoints, and
device_put with the *target* shardings — so a run checkpointed on one mesh
restarts on a different device count (elastic resume; resharding happens at
load).  ``AsyncCheckpointer`` moves serialization off the train loop.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def name(path):
        parts = []
        for e in path:
            if hasattr(e, "key"):
                parts.append(str(e.key))
            elif hasattr(e, "idx"):
                parts.append(str(e.idx))
            else:
                parts.append(str(e))
        return _SEP.join(parts)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[name(path)] = np.asarray(jax.device_get(leaf))
    return flat


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:09d}.tmp-", dir=directory)
    try:
        arrays = _flatten(tree)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **arrays)
        with open(npz_path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "sha256": digest,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int) -> None:
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)


def _list_steps(directory: str) -> List[int]:
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            if _valid(os.path.join(directory, name)):
                out.append(int(name[5:]))
    return out


def _valid(path: str) -> bool:
    man = os.path.join(path, "manifest.json")
    npz = os.path.join(path, "arrays.npz")
    if not (os.path.isfile(man) and os.path.isfile(npz)):
        return False
    try:
        with open(man) as f:
            manifest = json.load(f)
        with open(npz, "rb") as f:
            return hashlib.sha256(f.read()).hexdigest() == manifest["sha256"]
    except (OSError, ValueError, KeyError):
        return False


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, tree_like, *, step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``tree_like``; device_put with target
    shardings (resharding = elastic resume).  Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    if not _valid(path):
        raise IOError(f"checkpoint {path} corrupt")
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat_paths, tdef = jax.tree_util.tree_flatten_with_path(tree_like)

    def name(path_):
        parts = []
        for e in path_:
            parts.append(str(e.key) if hasattr(e, "key") else str(getattr(e, "idx", e)))
        return _SEP.join(parts)

    leaves = []
    shard_flat = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat_paths)
    )
    for (p, like), sh in zip(flat_paths, shard_flat):
        arr = arrays[name(p)]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {name(p)}: {arr.shape} vs {like.shape}")
        arr = arr.astype(like.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree.unflatten(tdef, leaves), step


class AsyncCheckpointer:
    """Serialize checkpoints on a background thread; at most one in flight
    (the next save waits), and ``wait()`` blocks until published."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                save(self.directory, step, host_tree, keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
