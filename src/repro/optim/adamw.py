"""AdamW in pure JAX pytrees (fp32 moments), with global-norm clipping and
wsd/cosine learning-rate schedules.  No optax dependency — the optimizer
state sharding must follow parallel/sharding rules exactly."""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update", "make_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"  # constant | cosine | wsd
    warmup_steps: int = 100
    total_steps: int = 10000


class OptState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    m: object  # pytree like params (fp32)
    v: object  # pytree like params (fp32)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def make_schedule(cfg: AdamWConfig) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "wsd":
            # warmup-stable-decay: linear decay over the last 10%
            tail = 0.9 * cfg.total_steps
            decay = jnp.clip(1.0 - (step - tail) / jnp.maximum(0.1 * cfg.total_steps, 1), 0.1, 1.0)
        else:  # cosine
            frac = jnp.clip(step / jnp.maximum(cfg.total_steps, 1), 0.0, 1.0)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * decay

    return sched


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params, grads, state: OptState, cfg: AdamWConfig
) -> Tuple[object, OptState, dict]:
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    step = state.step + 1
    lr = make_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(p, mm, vv):
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, OptState(step=step, m=m, v=v), {"grad_norm": gnorm, "lr": lr}
