"""Batched serving over the distributed striped KV cache.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_batch.py [--arch minicpm3-4b]

Prefills a batch of prompts with Mesh-Attention (the striped prefill chunks
land directly in the decode cache — the paper's locality property carried
into serving), then decodes greedily with per-token lse-combined partial
attention.  Verifies distributed generation equals single-device.
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.parallel.context import ParallelCtx
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)

    single = ServeEngine(cfg, params, max_seq=128)
    out_single = single.generate(prompts, max_new_tokens=args.new_tokens)

    if jax.device_count() >= 8:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                          block_q=8, block_kv=8)
        dist = ServeEngine(cfg, params, ctx=ctx, max_seq=128)
        out_dist = dist.generate(prompts, max_new_tokens=args.new_tokens)
        assert (out_single == out_dist).all(), "distributed != single-device"
        print(f"distributed == single-device across {jax.device_count()} devices")

    for i, row in enumerate(out_single):
        print(f"request {i}: prompt {prompts[i][:6].tolist()}... -> {row.tolist()}")
    print("serve_batch OK")


if __name__ == "__main__":
    main()
