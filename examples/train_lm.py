"""End-to-end training driver.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 50

Presets:
  tiny   — ~1M params, finishes on this CPU container in ~a minute
  100m   — ~100M-param llama-style model (the assignment's end-to-end size;
           run on real hardware or be patient)
  arch   — any assigned architecture's reduced config: --preset arch --arch ID

Demonstrates the full substrate: Mesh-Attention context parallelism over the
model axis, FSDP param sharding, AdamW, deterministic data, checkpointing
(resume with the same command), and the straggler monitor.
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.context import ParallelCtx
from repro.train.loop import TrainConfig, fit

PRESETS = {
    "tiny": ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512,
    ),
    "100m": ModelConfig(
        name="llama-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, d_ff=2048, vocab_size=32000,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m", "arch"])
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--single-device", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced() if args.preset == "arch" else PRESETS[args.preset]

    if args.single_device or jax.device_count() < 8:
        ctx = ParallelCtx()
    else:
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, batch_axes=("data",), sp_axis="model",
                          block_q=16, block_kv=16)
    print(f"devices={jax.device_count()} mesh={'none' if ctx.mesh is None else dict(ctx.mesh.shape)}")

    tcfg = TrainConfig(steps=args.steps, seq=args.seq, batch=args.batch,
                       ckpt_dir=args.ckpt_dir, ckpt_every=20)
    out = fit(cfg, ctx, tcfg, AdamWConfig(lr=3e-3, total_steps=args.steps, warmup_steps=10),
              hooks={"on_step": lambda s, m: (s % 10 == 0) and print(
                  f"step {s}: loss {float(m['loss']):.4f} gnorm {float(m['grad_norm']):.2f}")})
    hist = out["history"]
    print(f"\nloss {hist[0]:.4f} -> {hist[-1]:.4f} over {len(hist)} steps"
          f" (resumed from checkpoint)" if out["step"] != len(hist) else "")
    assert hist[-1] < hist[0], "training did not reduce loss"
    print("train_lm OK")


if __name__ == "__main__":
    main()
