"""Quickstart: Mesh-Attention in 60 seconds.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py

1. builds the 2-D tiled assignment matrix and the greedy schedule (paper
   Algorithms 2/3),
2. runs the distributed op on 8 (fake) devices and checks it against the
   single-device oracle,
3. autotunes the tile shape for a communication-bound cluster.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

try:
    import repro  # noqa: F401
except ImportError:  # running from a checkout without `pip install -e .`
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    )

import jax
import jax.numpy as jnp

from repro.core.am import CommModel, table2
from repro.core.autotune import tune
from repro.core.dispatch import distributed_attention, plan_from_ctx
from repro.core.schedule import greedy_forward_schedule
from repro.core.simulator import HardwareModel
from repro.core.tiling import TileLayout, stripe_permutation, unstripe_permutation
from repro.kernels import ref
from repro.parallel.context import ParallelCtx


def main():
    n, a = 8, 2  # 8 devices, 2x4 tiles

    # --- 1. the assignment matrix & schedule --------------------------------
    lay = TileLayout(n, a)
    print("assignment matrix (AM[q_chunk][kv_chunk] = device):")
    print(lay.assignment_matrix())
    sched = greedy_forward_schedule(a, n // a)
    print(f"\ngreedy forward schedule ({sched.num_steps()} steps):")
    for i, step in enumerate(sched.steps):
        print(f"  step {i}: comm={list(step.comms)} compute={list(step.compute)}")

    # --- 2. distributed vs single-device (via the dispatch seam) ------------
    mesh = jax.make_mesh((n,), ("sp",))
    B, S, H, D = 2, n * 32, 4, 16
    q, k, v = (
        jax.random.normal(kk, (B, S, H, D))
        for kk in jax.random.split(jax.random.PRNGKey(0), 3)
    )
    ctx = ParallelCtx(mesh=mesh, sp_axis="sp", mesh_a=a, block_q=32, block_kv=32)
    cfg = plan_from_ctx(ctx, causal=True)  # backend + tile as config
    f = jax.jit(lambda q, k, v: distributed_attention(q, k, v, cfg=cfg, ctx=ctx))
    perm = stripe_permutation(S, n)
    inv = unstripe_permutation(S, n)
    o = f(q[:, perm], k[:, perm], v[:, perm])[:, inv]
    o_ref, _ = ref.attention_ref(q, k, v, band=ref.causal_band())
    err = float(jnp.max(jnp.abs(o - o_ref)))
    print(f"\ndistributed vs oracle max |err| = {err:.2e}")
    assert err < 2e-5

    # --- 3. tile-shape autotuning (paper Figure 6) --------------------------
    hw = HardwareModel(peak_flops=989e12, link_bw=25e9, attn_efficiency=0.35)
    for nn in (64, 256):
        plan = tune(CommModel(seq=1 << 20, hidden=4096, n=nn), hw, causal=True)
        ring = table2(nn)["ring"]
        mesh_v = table2(nn)["mesh"]
        print(
            f"n={nn:4d}: best tile a x b = {plan.a} x {plan.b}, "
            f"simulated fwd+bwd {plan.total*1e3:.1f} ms, "
            f"theoretical comm {mesh_v:.3f} Nd vs ring {ring:.3f} Nd"
        )
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
