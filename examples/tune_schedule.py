"""Tile-shape + schedule autotuning — the paper's Figure-6 flow.

    PYTHONPATH=src python examples/tune_schedule.py --n 64 --seq 1048576 [--gqa 8]

Enumerates every factorization n = a x b, derives the overlap profile from
the hardware model, generates the greedy schedule (Algorithm 2/3), simulates
the lock-step runtime, and prints the ranking — plus the effect of GQA on
the byte-optimal tile (paper §4.7 / EXPERIMENTS.md §Perf B2).
"""

import argparse

from repro.core.am import CommModel
from repro.core.autotune import plan_for
from repro.core.simulator import HardwareModel
from repro.core.tiling import factorizations


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--seq", type=int, default=1 << 20)
    ap.add_argument("--hidden", type=int, default=4096)
    ap.add_argument("--gqa", type=int, default=1, help="query heads per kv head")
    ap.add_argument("--tpu", action="store_true", help="use the v5e model instead of the paper cluster")
    args = ap.parse_args()

    hw = (
        HardwareModel()
        if args.tpu
        else HardwareModel(peak_flops=989e12, link_bw=25e9, attn_efficiency=0.35, latency=100e-6)
    )
    comm = CommModel(
        seq=args.seq, hidden=args.hidden, n=args.n,
        kv_hidden=args.hidden // args.gqa,
    )
    print(f"n={args.n} seq={args.seq} hidden={args.hidden} gqa={args.gqa}")
    print(f"{'a x b':>10s} {'fwd+bwd (ms)':>14s} {'exposed comm':>14s} {'wire bytes/dev':>15s}")
    plans = []
    for a, b in factorizations(args.n):
        p = plan_for(comm, a, hw, causal=True)
        plans.append(p)
        exposed = p.fwd_sim.exposed_comm + p.bwd_sim.exposed_comm
        print(f"{a:>5d} x {b:<4d} {p.total*1e3:>12.1f} {exposed*1e3:>12.1f}ms {p.comm_bytes/1e9:>13.2f}GB")
    best = min(plans, key=lambda p: p.total)
    print(f"\nbest tile: {best.a} x {best.b}  "
          f"(a=1 is Ring-Attention; sqrt(n) is the paper's MHA optimum; "
          f"GQA flattens the optimum toward smaller a)")
    print(f"byte-optimal a from the GQA-aware model: {comm.best_a()}")


if __name__ == "__main__":
    main()
